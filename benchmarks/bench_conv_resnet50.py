"""Paper Figures 7/8 (+ Table 2): ResNet-50 convolution layers, forward /
backward-data / weight-update via the batch-reduce building block.

CPU-scale minibatch (paper uses N=28 on 28 cores; we use N=2 on 1 core)
and reports per-layer GFLOP/s plus the weighted-efficiency aggregate the
paper defines in Sec. 4.1.2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro
from benchmarks.common import RESNET50_LAYERS, conv_flops, emit, timeit
from repro.kernels.conv2d import conv2d

N = 2
# layer -> occurrences in the full 53-conv topology (paper Sec. 4.1.2)
REPEATS = {1: 1, 2: 1, 3: 3, 4: 3, 5: 3, 6: 1, 7: 1, 8: 4, 9: 4, 10: 4,
           11: 1, 12: 1, 13: 6, 14: 6, 15: 6, 16: 1, 17: 1, 18: 3, 19: 3,
           20: 3}


def run():
    with repro.use(backend="xla"):
        _run()


def _run():
    rng = np.random.default_rng(0)
    weighted_fl, weighted_t = 0.0, 0.0
    for (lid, c, k, h, w_, r, s, st) in RESNET50_LAYERS:
        x = jnp.asarray(rng.normal(size=(N, h, w_, c)), jnp.float32)
        wt = jnp.asarray(rng.normal(size=(r, s, c, k)) * 0.05, jnp.float32)
        pad = r // 2
        fl = conv_flops(N, c, k, h, w_, r, s, st)

        fwd = jax.jit(lambda x, w: conv2d(x, w, stride=st, padding=pad))
        us = timeit(fwd, x, wt, iters=3)
        emit(f"fig7_rn50_fwd_layer{lid}", us, f"{fl / us / 1e3:.1f}GFLOPs")
        weighted_fl += REPEATS[lid] * fl
        weighted_t += REPEATS[lid] * us

        bwd = jax.jit(jax.grad(
            lambda x, w: (conv2d(x, w, stride=st, padding=pad) ** 2).sum(),
            argnums=(0, 1)))
        us_b = timeit(bwd, x, wt, iters=3)
        emit(f"fig8_rn50_bwdupd_layer{lid}", us_b,
             f"{2 * fl / us_b / 1e3:.1f}GFLOPs")

    emit("fig7_rn50_fwd_weighted", weighted_t,
         f"{weighted_fl / weighted_t / 1e3:.1f}GFLOPs_weighted")


if __name__ == "__main__":
    run()
