"""Flash-attention timings: fused forward, fused backward, and the
fwd+bwd train-step path through the custom VJP.

``attn_fwd_*``   — the forward flash kernel (prefill/serving hot path),
``attn_bwd_*``   — the fused backward alone (dQ/dK/dV from saved
                   residuals; the vjp closure is jitted so only the three
                   backward kernels are timed),
``attn_train_*`` — value_and_grad through the attention op: forward with
                   residual emission plus the fused backward, the shape of
                   one attention layer inside a train step.

Derived column reports achieved GFLOP/s on the standard attention flop
model (4*B*H*Tq*Tk*D forward; the backward re-does the two forward GEMMs
plus three gradient GEMMs, ~2.5x)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels.flash_attention import flash_attention

CASES = [
    # (B, H, T, D, causal, window)
    (1, 4, 256, 64, True, None),
    (1, 4, 256, 64, True, 128),
    (1, 4, 512, 64, True, None),
    (1, 4, 256, 64, False, None),
]


def _gflops(fl, us):
    return f"{fl / (us * 1e-6) / 1e9:.1f}GFLOP/s"


def run():
    rng = np.random.default_rng(0)
    for (b, h, t, d, causal, window) in CASES:
        q = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
        tag = f"b{b}h{h}t{t}d{d}" + ("c" if causal else "") + (
            f"w{window}" if window else "")
        fwd_fl = 4 * b * h * t * t * d * (0.5 if causal else 1.0)

        def attn(q_, k_, v_):
            return flash_attention(q_, k_, v_, causal=causal, window=window)

        fwd = jax.jit(attn)
        us = timeit(fwd, q, k, v)
        emit(f"attn_fwd_{tag}", us, _gflops(fwd_fl, us))

        # Backward alone: residuals are computed once outside the timer.
        _, f_vjp = jax.vjp(attn, q, k, v)
        dy = jnp.ones_like(q)
        bwd = jax.jit(f_vjp)
        us = timeit(bwd, dy)
        emit(f"attn_bwd_{tag}", us, _gflops(2.5 * fwd_fl, us))

        train = jax.jit(jax.value_and_grad(
            lambda q_: (attn(q_, k, v) * v).sum()))
        us = timeit(train, q)
        emit(f"attn_train_{tag}", us, _gflops(3.5 * fwd_fl, us))
