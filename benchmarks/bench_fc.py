"""Paper Figure 9: fully-connected layers FWD / BWD / UPD.

Paper shapes: N=1344 minibatch, C=K in {256, 512, 1024}; scaled minibatch
for the CPU budget.  All three passes route through the batch-reduce GEMM
(BWD reduces over K, UPD reduces over the minibatch — paper Sec. 4.1.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro
from benchmarks.common import emit, timeit
from repro.layers import linear

N = 256
SIZES = (256, 512, 1024)


def run():
    # The XLA reference path is the CPU-benchmark baseline; scope it once
    # through the execution context instead of threading backend= kwargs.
    with repro.use(backend="xla"):
        _run()


def _run():
    rng = np.random.default_rng(0)
    for ck in SIZES:
        p = linear.init(jax.random.PRNGKey(0), ck, ck)
        x = jnp.asarray(rng.normal(size=(N, ck)), jnp.float32)
        fl = 2 * N * ck * ck

        fwd = jax.jit(lambda p, x: linear.apply(p, x, activation="relu"))
        us = timeit(fwd, p, x)
        emit(f"fig9_fc_fwd_{ck}", us, f"{fl / us / 1e3:.1f}GFLOPs")

        bwd = jax.jit(jax.grad(
            lambda p, x: (linear.apply(p, x, activation="relu") ** 2).sum(),
            argnums=(0, 1)))
        us = timeit(bwd, p, x)
        emit(f"fig9_fc_bwdupd_{ck}", us, f"{2 * fl / us / 1e3:.1f}GFLOPs")


if __name__ == "__main__":
    run()
