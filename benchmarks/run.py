"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Mapping to the paper:
  fig1_*   Figure 1  convolution implementation strategies
  sec2_*   Section 2 batch-reduce vs batched vs looped GEMM
  fig6_*   Figure 6  LSTM cell fwd / bwd+upd
  fig7/8_* Figures 7-8 + Table 2: ResNet-50 convolutions
  fig9_*   Figure 9  fully-connected layers
  fig10_*  Figure 10 distributed-scaling proxy (collective footprint)
  tune_*   heuristic vs measured-autotune tiles (``--compare-policies``)
  serve_*  continuous-batching vs static-batching serving throughput
  quant_*  bf16 vs int8 quantized GEMM + int8-decode serving throughput
  obs_*    roofline accounting (achieved GFLOP/s vs arithmetic
           intensity per op) + traced autotune span counts

``--json out.json`` additionally persists every record (plus platform /
dispatch metadata) so the BENCH_*.json perf trajectory can be diffed
across commits.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None, choices=("xla", "pallas"),
                    help="force a dispatch backend for every benchmark "
                         "(overridden by per-benchmark explicit choices)")
    ap.add_argument("--blocks-policy", default=None,
                    choices=("heuristic", "autotune"),
                    help="block-selection policy for every benchmark")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write all records as JSON to this path")
    ap.add_argument("--compare-policies", action="store_true",
                    help="run the heuristic-vs-autotune tile comparison "
                         "(pays a measured search per op/shape)")
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="with --compare-policies: also compare global-"
                         "shape vs per-shard (local-shape) tuning under a "
                         "device-free mesh of this shape (e.g. 2x4)")
    ap.add_argument("--only", default=None, metavar="SUBSTR[,SUBSTR...]",
                    help="run only benchmark modules whose name contains "
                         "one of these comma-separated substrings "
                         "(e.g. --only attention, --only quant,serving)")
    args = ap.parse_args()

    import jax

    import repro
    from benchmarks import (bench_attention, bench_autotune, bench_brgemm,
                            bench_conv_resnet50, bench_conv_strategies,
                            bench_distributed_proxy, bench_fc, bench_lstm,
                            bench_obs, bench_quant, bench_serving, common)

    mods = [bench_brgemm, bench_conv_strategies, bench_lstm, bench_fc,
            bench_conv_resnet50, bench_attention, bench_distributed_proxy,
            bench_serving, bench_quant, bench_obs]
    if args.compare_policies:
        mods.append(bench_autotune)
    elif args.mesh:
        ap.error("--mesh requires --compare-policies")
    if args.only:
        wanted = [s for s in args.only.split(",") if s]
        mods = [m for m in mods
                if any(s in m.__name__ for s in wanted)]
        if not mods:
            ap.error(f"--only {args.only!r} matches no benchmark module")
        if args.mesh and bench_autotune not in mods:
            ap.error(f"--mesh runs inside bench_autotune, which --only "
                     f"{args.only!r} filtered out (use --only autotune)")

    print("name,us_per_call,derived")
    ok = True
    # use(backend=None, ...) leaves every field unset — a no-op context.
    with repro.use(backend=args.backend, blocks_policy=args.blocks_policy):
        for mod in mods:
            try:
                if mod is bench_autotune and args.mesh:
                    mod.run(mesh=args.mesh)
                else:
                    mod.run()
            except Exception:
                ok = False
                print(f"# ERROR in {mod.__name__}", file=sys.stderr)
                traceback.print_exc()

    if args.json:
        payload = {
            "platform": jax.default_backend(),
            "backend": args.backend,
            "blocks_policy": args.blocks_policy,
            "ok": ok,
            "records": common.RECORDS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(common.RECORDS)} records to {args.json}",
              file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
