"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Mapping to the paper:
  fig1_*   Figure 1  convolution implementation strategies
  sec2_*   Section 2 batch-reduce vs batched vs looped GEMM
  fig6_*   Figure 6  LSTM cell fwd / bwd+upd
  fig7/8_* Figures 7-8 + Table 2: ResNet-50 convolutions
  fig9_*   Figure 9  fully-connected layers
  fig10_*  Figure 10 distributed-scaling proxy (collective footprint)
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None, choices=("xla", "pallas"),
                    help="force a dispatch backend for every benchmark "
                         "(overridden by per-benchmark explicit choices)")
    args = ap.parse_args()

    import repro
    from benchmarks import (bench_brgemm, bench_conv_resnet50,
                            bench_conv_strategies, bench_distributed_proxy,
                            bench_fc, bench_lstm)
    print("name,us_per_call,derived")
    ok = True
    # use(backend=None) leaves every field unset — a no-op context.
    with repro.use(backend=args.backend):
        for mod in (bench_brgemm, bench_conv_strategies, bench_lstm,
                    bench_fc, bench_conv_resnet50, bench_distributed_proxy):
            try:
                mod.run()
            except Exception:
                ok = False
                print(f"# ERROR in {mod.__name__}", file=sys.stderr)
                traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
