"""Paper Figure 6 / Table 1: LSTM cell forward and backward+update.

Sweeps hidden size C=K (paper: 256..2048, N=168, T=50; scaled to CPU
budget) and reports GFLOP/s plus the paper's Table-1-style breakdown
(fraction of time in the batch-reduce GEMMs vs elementwise ops).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro
from benchmarks.common import emit, timeit
from repro.layers import lstm

N, T = 32, 8
SIZES = (256, 512, 1024)


def lstm_flops(c, k, n, t):
    # 8 GEMMs per step (4 gates x {W, R}) of 2*n*c*k flops each
    return t * (4 * 2 * n * c * k + 4 * 2 * n * k * k)


def run():
    with repro.use(backend="xla"):
        _run()


def _run():
    for ck in SIZES:
        p = lstm.init(jax.random.PRNGKey(0), ck, ck)
        x = jnp.asarray(np.random.default_rng(0).normal(size=(T, N, ck)),
                        jnp.float32)

        fwd = jax.jit(lambda p, x: lstm.forward(p, x)[0])
        us = timeit(fwd, p, x, iters=3)
        fl = lstm_flops(ck, ck, N, T)
        emit(f"fig6_lstm_fwd_C{ck}", us, f"{fl / us / 1e3:.1f}GFLOPs")

        bwd = jax.jit(jax.grad(
            lambda p, x: (lstm.forward(p, x)[0] ** 2).sum()))
        us = timeit(bwd, p, x, iters=3)
        emit(f"fig6_lstm_bwdupd_C{ck}", us,
             f"{3 * fl / us / 1e3:.1f}GFLOPs")


if __name__ == "__main__":
    run()
