"""Paper Figure 1: convolution implementation strategies.

Compares (on a representative subset of ResNet-50 layers, minibatch 1):
  * im2col + large GEMM         (paper's yellow line, strategy (i)),
  * batched GEMM, one GEMM per (r, s) with separate accumulation
    (paper's green line — no output-register reuse),
  * batch-reduce formulation: single accumulation chain over (r, s, c_b)
    (the paper's contribution; XLA path of our kernel on CPU — the Pallas
    kernel itself targets TPU and is validated by allclose in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESNET50_LAYERS, conv_flops, emit, timeit

SUBSET = (2, 4, 8, 13, 18)


def im2col_conv(x, w, stride):
    r, s, c, k = w.shape
    n, h, wi, _ = x.shape
    pad = r // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    p = (h + 2 * pad - r) // stride + 1
    q = (wi + 2 * pad - s) // stride + 1
    cols = []
    for i in range(r):
        for j in range(s):
            cols.append(jax.lax.slice(
                xp, (0, i, j, 0),
                (n, i + (p - 1) * stride + 1, j + (q - 1) * stride + 1, c),
                (1, stride, stride, 1)))
    col = jnp.concatenate(cols, axis=-1).reshape(n * p * q, r * s * c)
    return (col @ w.transpose(0, 1, 2, 3).reshape(r * s * c, k)).reshape(
        n, p, q, k)


def batched_gemm_conv(x, w, stride):
    """One GEMM per (r, s); outputs accumulated *after* each GEMM —
    the strided-batch-gemm baseline without the reduce."""
    r, s, c, k = w.shape
    n, h, wi, _ = x.shape
    pad = r // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    p = (h + 2 * pad - r) // stride + 1
    q = (wi + 2 * pad - s) // stride + 1
    out = jnp.zeros((n * p * q, k), jnp.float32)
    for i in range(r):
        for j in range(s):
            xs = jax.lax.slice(
                xp, (0, i, j, 0),
                (n, i + (p - 1) * stride + 1, j + (q - 1) * stride + 1, c),
                (1, stride, stride, 1)).reshape(n * p * q, c)
            out = out + xs @ w[i, j]          # separate store/load of C
    return out.reshape(n, p, q, k)


def brgemm_conv(x, w, stride):
    """Batch-reduce formulation: XLA fuses the (r, s) chain into one
    accumulation (this is what lax.conv lowers to for direct conv)."""
    r = w.shape[0]
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), ((r // 2, r // 2), (r // 2, r // 2)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def run():
    rng = np.random.default_rng(0)
    for (lid, c, k, h, w_, r, s, st) in RESNET50_LAYERS:
        if lid not in SUBSET:
            continue
        x = jnp.asarray(rng.normal(size=(1, h, w_, c)), jnp.float32)
        wt = jnp.asarray(rng.normal(size=(r, s, c, k)) * 0.1, jnp.float32)
        fl = conv_flops(1, c, k, h, w_, r, s, st)
        for name, fn in (("im2col", im2col_conv),
                         ("batched_gemm", batched_gemm_conv),
                         ("brgemm", brgemm_conv)):
            f = jax.jit(lambda x, w, fn=fn: fn(x, w, st))
            us = timeit(f, x, wt, iters=5)
            emit(f"fig1_conv_layer{lid}_{name}", us,
                 f"{fl / us / 1e3:.1f}GFLOPs")


if __name__ == "__main__":
    run()
