"""Offline serving throughput: continuous batching vs static batching,
and the 1-replica vs 2-replica cluster router.

A mixed prompt/output-length workload is served two ways on the same
reduced decoder config:

  * static:     requests grouped into fixed batches in arrival order,
                prompts right-padded to the group max, each group decoded
                until its *longest* request finishes (shorter requests ride
                along as waste — the stall continuous batching removes),
  * continuous: the same requests through ``ContinuousEngine`` (slot pool
                of the same size; bucketed prefill), joining mid-stream as
                slots free up.

Both paths count only *useful* tokens (each request's own output length),
so tokens/s is aggregate goodput.  Engines are warmed on the identical
workload first so jit compilation never enters the timed run.

The cluster section (also standalone: ``bench_serving.py --cluster``)
routes the same mixed workload through ``EngineRouter`` with one vs two
engine replicas (same per-replica pool size, so two replicas are twice
the slot capacity) and reports aggregate goodput plus wall-clock TTFT
p50/p99 — read from the engines' own bounded-bucket latency histograms
(``ServeMetrics.ttft_hist``), i.e. the same numbers the Prometheus
export reports in production, not a benchmark-only percentile pass.

``--paged`` compares the paged KV pool against the slotted pool at
*equal KV memory* on a heavy-tailed prompt mix: same device bytes, 2x
the slots, page budget set by live tokens — reporting concurrent
requests per GB, preemption/chunk counts, greedy parity, and wall-clock
TTFT p50/p99 from the engines' own histograms.

``--trace out.json`` serves the continuous workload under an installed
``repro.obs.Tracer``, reports the tracing-enabled overhead against the
untraced pass, verifies every request span's TTFT breakdown telescopes,
and exports the Chrome trace (load it in Perfetto / chrome://tracing).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import configs
from repro.models import api
from repro.serve import (
    ContinuousEngine,
    Engine,
    EngineReplica,
    EngineRouter,
    PoolConfig,
    Request,
    ServeConfig,
    ServeMetrics,
)

MAX_LEN = 48
PROMPT_LENS = (4, 11, 6, 16, 5, 9, 13, 7)           # cycled over requests
OUT_LENS = (2, 3, 2, 14, 3, 2, 12, 3)               # heavy-tail mix


def _workload(cfg, n_requests: int):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab,
                            PROMPT_LENS[i % len(PROMPT_LENS)]).tolist()
               for i in range(n_requests)]
    outs = [OUT_LENS[i % len(OUT_LENS)] for i in range(n_requests)]
    return prompts, outs


def _run_static(eng, prompts, outs, batch_size: int) -> int:
    """Serve in arrival-order groups; returns decode+prefill step count."""
    steps = 0
    for i in range(0, len(prompts), batch_size):
        group = prompts[i:i + batch_size]
        group_outs = outs[i:i + batch_size]
        lmax = max(len(p) for p in group)
        tokens = np.zeros((len(group), lmax), np.int32)
        for j, p in enumerate(group):
            tokens[j, :len(p)] = p
        n = max(group_outs)               # the whole batch stalls on this
        jax.block_until_ready(
            eng.generate({"tokens": jnp.asarray(tokens)}, n_tokens=n,
                         stop_tokens=()))
        steps += n
    return steps


def _run_continuous(ce, prompts, outs):
    out = ce.serve([Request(prompt=p, max_tokens=n, stop_tokens=())
                    for p, n in zip(prompts, outs)])
    assert all(len(v) for v in out.values())


def _run_cluster(engines, prompts, outs):
    """One full workload pass through a fresh router over ``engines``.

    The router is rebuilt per pass (its ticket book is append-only) but the
    engines — and their jit caches — persist across passes.  Returns the
    router so the caller can read per-ticket wall-clock TTFT.
    """
    router = EngineRouter(
        [EngineReplica(f"r{i}", eng) for i, eng in enumerate(engines)],
        max_waiting=len(prompts))
    out = router.serve([Request(prompt=p, max_tokens=n, stop_tokens=())
                        for p, n in zip(prompts, outs)])
    assert all(len(v) for v in out.values())
    return router


def _serve_tracked(eng, prompts, outs):
    """Serve the workload, tracking peak concurrent running requests."""
    ids = [eng.submit(Request(prompt=p, max_tokens=n, stop_tokens=()))
           for p, n in zip(prompts, outs)]
    peak = 0
    while eng.has_work():
        eng.step()
        peak = max(peak, len(eng.scheduler.running))
    out = {rid: list(eng.scheduler.finished[rid].generated) for rid in ids}
    assert all(len(v) for v in out.values())
    return out, peak


def run_paged():
    """Paged vs slotted KV pool at equal KV memory, mixed prompt lengths.

    The slotted pool reserves ``max_len`` positions per slot, so its
    concurrency is bound by worst-case request length; the paged pool
    budgets the *same device bytes* as pages and lets live tokens set
    concurrency.  Both serve the identical mixed-length workload (greedy
    parity asserted); the capacity row reports concurrent requests per
    GB of KV at equal memory — the paged pool must sustain >= 2x — and
    wall-clock TTFT p50/p99 from the engines' own histograms shows the
    page-gather decode does not regress latency.
    """
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    n_requests, slots, page = 24, 4, 4
    # heavier-tailed prompt mix than the module workload: the long
    # prompts exercise chunked prefill (> prefill_chunk) while the short
    # ones keep the live-token average far below max_len — the regime
    # where paging's per-token budgeting pays
    lens = (4, 11, 6, 28, 5, 9, 36, 7)
    # outputs long enough that requests overlap — concurrency is then
    # bound by KV capacity (slots or pages), not admission latency
    out_lens = (8, 10, 6, 10, 9, 8, 4, 11)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, lens[i % len(lens)]).tolist()
               for i in range(n_requests)]
    outs = [out_lens[i % len(out_lens)] for i in range(n_requests)]
    useful = sum(outs)

    slotted = ContinuousEngine(
        cfg, params, PoolConfig(n_slots=slots, max_len=MAX_LEN))
    # same page budget as the slotted pool's token capacity, spread over
    # 2x the slots: equal KV bytes, concurrency set by live tokens
    paged = ContinuousEngine(
        cfg, params,
        PoolConfig(n_slots=2 * slots, max_len=MAX_LEN, page_size=page,
                   n_pages=slots * MAX_LEN // page, prefill_chunk=16))
    assert paged.paged, "paged pool unexpectedly fell back to slotted"
    gb_slotted = slotted.pool.kv_bytes() / 1e9
    gb_paged = paged.pool.kv_bytes() / 1e9

    results = {}
    for name, eng in (("slotted", slotted), ("paged", paged)):
        _serve_tracked(eng, prompts, outs)           # warm the jits
        eng.metrics = ServeMetrics()                 # drop warmup samples
        best, out, peak = float("inf"), None, 0
        for _ in range(3):
            t0 = time.perf_counter()
            out, p = _serve_tracked(eng, prompts, outs)
            best = min(best, time.perf_counter() - t0)
            peak = max(peak, p)
        results[name] = (best, out, peak)
        gb = gb_slotted if name == "slotted" else gb_paged
        hist = eng.metrics.ttft_hist
        p50, p99 = hist.quantile(0.5), hist.quantile(0.99)
        emit(f"serve_paged_{name}_r{n_requests}", best * 1e6,
             f"{useful / best:.1f}tok/s peak_concurrent={peak} "
             f"kv_gb={gb:.4f} req_per_gb={peak / gb:.0f} "
             f"ttft_p50={p50 * 1e3:.1f}ms p99={p99 * 1e3:.1f}ms "
             f"preempt={eng.metrics.preemptions} "
             f"chunks={eng.metrics.prefill_chunks}")

    (dt_s, out_s, peak_s), (dt_p, out_p, peak_p) = (results["slotted"],
                                                    results["paged"])
    parity = sum(out_p[k] == out_s[k] for k in out_s)
    per_gb_s, per_gb_p = peak_s / gb_slotted, peak_p / gb_paged
    emit(f"serve_paged_capacity_r{n_requests}", 0.0,
         f"{per_gb_p / per_gb_s:.2f}x concurrent-req/GB paged/slotted "
         f"parity={parity}/{n_requests} "
         f"kv_mem_ratio={gb_paged / gb_slotted:.2f}")


def run_cluster():
    """Cluster goodput + TTFT: 1 replica vs 2 replicas, same workload."""
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    n_requests, slots = 24, 4
    prompts, outs = _workload(cfg, n_requests)
    useful = sum(outs)

    pool = lambda: PoolConfig(n_slots=slots, max_len=MAX_LEN,  # noqa: E731
                              prefill_bucket=8)
    engines = [ContinuousEngine(cfg, params, pool()) for _ in range(2)]

    goodput = {}
    for n_rep in (1, 2):
        reps = engines[:n_rep]
        _run_cluster(reps, prompts, outs)            # warm the jits
        for eng in reps:
            eng.metrics = ServeMetrics()             # drop warmup samples
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            _run_cluster(reps, prompts, outs)
            dt = time.perf_counter() - t0
            best = min(best, dt)
        # percentiles from the engines' own latency histograms (merged
        # across replicas) — the numbers the Prometheus export reports
        hist = reps[0].metrics.ttft_hist
        for eng in reps[1:]:
            hist = hist + eng.metrics.ttft_hist
        p50, p99 = hist.quantile(0.5), hist.quantile(0.99)
        goodput[n_rep] = useful / best
        emit(f"serve_cluster_rep{n_rep}_r{n_requests}", best * 1e6,
             f"{useful / best:.1f}tok/s "
             f"ttft_p50={p50 * 1e3:.1f}ms p99={p99 * 1e3:.1f}ms")
    emit(f"serve_cluster_scaling_r{n_requests}", 0.0,
         f"{goodput[2] / goodput[1]:.2f}x goodput 2rep/1rep")


def run_chaos():
    """Goodput + availability under a fixed fault schedule vs fault-free.

    The same 24-request workload runs through a 2-replica router twice:
    clean, then with a seeded ``FaultInjector`` firing one transient
    fault (survived by in-place retry) and one fatal fault (replica
    quarantined mid-service, requests requeued, replica re-admitted from
    a pre-warmed spare engine via health probes).  Availability is the
    completed fraction; the goodput ratio is the price of the recovery
    machinery plus the capacity lost while quarantined.  Retry backoff
    and probe scheduling run on an injected clock so simulated waits
    never pollute the wall-clock measurement.
    """
    from repro.serve import (FaultClock, FaultInjector, FaultSpec,
                             HealthConfig, RetryPolicy)

    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    n_requests, slots = 24, 4
    prompts, outs = _workload(cfg, n_requests)
    useful = sum(outs)
    pool = lambda: PoolConfig(n_slots=slots, max_len=MAX_LEN,  # noqa: E731
                              prefill_bucket=8)
    engines = [ContinuousEngine(cfg, params, pool()) for _ in range(2)]

    _run_cluster(engines, prompts, outs)             # warm the jits
    t0 = time.perf_counter()
    _run_cluster(engines, prompts, outs)
    dt_clean = time.perf_counter() - t0
    emit(f"serve_chaos_baseline_r{n_requests}", dt_clean * 1e6,
         f"{useful / dt_clean:.1f}tok/s availability=1.00")

    spare = ContinuousEngine(cfg, params, pool())    # pre-warmed hot spare
    _run_continuous(spare, prompts[:2], outs[:2])
    clk = FaultClock()
    inj = FaultInjector([
        FaultSpec(site="step", target="r1", at=3, kind="transient"),
        FaultSpec(site="step", target="r1", at=6, kind="fatal"),
    ], clock=clk)
    inj.instrument(engines[1], "r1")
    router = EngineRouter(
        [EngineReplica("r0", engines[0]),
         EngineReplica("r1", engines[1], factory=lambda: spare)],
        max_waiting=n_requests, clock=clk, sleep=clk.advance,
        retry=RetryPolicy(max_retries=2, backoff_s=0.01, seed=0),
        health=HealthConfig(probe_interval_s=0.5, probes_to_readmit=1))
    t0 = time.perf_counter()
    out = router.serve([Request(prompt=p, max_tokens=n, stop_tokens=())
                        for p, n in zip(prompts, outs)])
    for _ in range(8):                               # re-admit the spare
        if all(r.healthy for r in router.replicas):
            break
        clk.advance(1.0)
        router.step()
    dt_chaos = time.perf_counter() - t0
    completed = sum(1 for tid in out
                    if router.tickets[tid].status == "completed")
    c = router.counters
    emit(f"serve_chaos_faulted_r{n_requests}", dt_chaos * 1e6,
         f"{useful / dt_chaos:.1f}tok/s "
         f"availability={completed / n_requests:.2f} "
         f"retries={c['retries']} requeued={c['requests_requeued']} "
         f"readmitted={c['replicas_readmitted']}")
    emit(f"serve_chaos_goodput_ratio_r{n_requests}", 0.0,
         f"{(useful / dt_chaos) / (useful / dt_clean):.2f}x "
         f"goodput vs fault-free")


def run_traced(trace_out: str):
    """Traced continuous pass: overhead vs untraced + Chrome export.

    The same workload runs untraced (best of 3) and then under an
    installed ``Tracer`` (best of 3) on the *same* warm engine, so the
    ratio is pure tracing overhead.  Every request span's TTFT breakdown
    is checked to telescope before the trace is exported.
    """
    from repro import obs

    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    n_requests, slots = 16, 4
    prompts, outs = _workload(cfg, n_requests)
    useful = sum(outs)
    eng = ContinuousEngine(
        cfg, params,
        PoolConfig(n_slots=slots, max_len=MAX_LEN, prefill_bucket=8))

    def one_pass():
        t0 = time.perf_counter()
        _run_continuous(eng, prompts, outs)
        return time.perf_counter() - t0

    one_pass()                                       # warm the jits
    dt_off = min(one_pass() for _ in range(3))

    tracer = obs.Tracer()
    prev = obs.install(tracer)
    try:
        dt_on = min(one_pass() for _ in range(3))
    finally:
        obs.install(prev)

    for state in eng.scheduler.finished.values():
        bd = state.ttft_breakdown
        if bd is not None and state.ttft_s is not None:
            assert abs(sum(bd.values()) - state.ttft_s) < 1e-6, \
                (state.request_id, bd, state.ttft_s)
    n_events = obs.export_chrome(tracer, trace_out)
    obs.chrome.validate(obs.chrome.load(trace_out))

    emit(f"serve_untraced_r{n_requests}", dt_off * 1e6,
         f"{useful / dt_off:.1f}tok/s")
    emit(f"serve_traced_r{n_requests}", dt_on * 1e6,
         f"{useful / dt_on:.1f}tok/s {dt_on / dt_off:.3f}x-vs-untraced "
         f"chrome_events={n_events} trace={trace_out}")


def run():
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    n_requests, batch = 16, 4
    prompts, outs = _workload(cfg, n_requests)
    useful = sum(outs)

    static_eng = Engine(cfg, params, ServeConfig(max_len=MAX_LEN))
    cont_eng = ContinuousEngine(
        cfg, params,
        PoolConfig(n_slots=batch, max_len=MAX_LEN, prefill_bucket=8))

    def best_of(fn, repeats=3):
        """Best-of-N full-workload pass (first call also warms the jits)."""
        fn()
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    static_steps = _run_static(static_eng, prompts, outs, batch)
    dt_static = best_of(
        lambda: _run_static(static_eng, prompts, outs, batch))
    emit(f"serve_static_r{n_requests}b{batch}", dt_static * 1e6,
         f"{useful / dt_static:.1f}tok/s")

    m = cont_eng.metrics
    d0, s0, c0 = m.decode_steps, m.slot_steps, m.slot_capacity_steps
    _run_continuous(cont_eng, prompts, outs)   # warm + count one pass
    cont_steps = m.decode_steps - d0
    occ = (m.slot_steps - s0) / max(1, m.slot_capacity_steps - c0)
    dt_cont = best_of(lambda: _run_continuous(cont_eng, prompts, outs))
    emit(f"serve_cont_r{n_requests}b{batch}", dt_cont * 1e6,
         f"{useful / dt_cont:.1f}tok/s")
    emit(f"serve_cont_occupancy_r{n_requests}b{batch}",
         dt_cont * 1e6 / max(1, cont_steps), f"occ={occ:.2f}")
    emit(f"serve_cont_vs_static_r{n_requests}b{batch}", dt_cont * 1e6,
         f"{dt_static / dt_cont:.2f}x "
         f"steps={cont_steps}vs{static_steps}")

    # int8 decode tier: same workload, weights calibrated offline so the
    # decode GEMMs run the quantized building block (see bench_quant for
    # the isolated GEMM comparison at production weight shapes).
    from repro.core.quantize import calibrate_params
    int8_eng = ContinuousEngine(
        cfg, calibrate_params(params, "int8"),
        PoolConfig(n_slots=batch, max_len=MAX_LEN, prefill_bucket=8))
    dt_int8 = best_of(lambda: _run_continuous(int8_eng, prompts, outs))
    emit(f"serve_cont_int8_decode_r{n_requests}b{batch}", dt_int8 * 1e6,
         f"{useful / dt_int8:.1f}tok/s {dt_cont / dt_int8:.2f}x-vs-fp32")

    run_paged()
    run_cluster()


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cluster", action="store_true",
                    help="only the 1- vs 2-replica router section")
    ap.add_argument("--paged", action="store_true",
                    help="only the paged vs slotted KV pool section "
                         "(equal-memory capacity + TTFT percentiles)")
    ap.add_argument("--chaos", action="store_true",
                    help="goodput + availability under a fixed fault "
                         "schedule vs the fault-free baseline")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="traced continuous pass: tracing overhead vs "
                         "untraced + Chrome trace export to this path")
    cli = ap.parse_args()
    print("name,us_per_call,derived")
    if cli.trace:
        run_traced(cli.trace)
    elif cli.chaos:
        run_chaos()
    elif cli.paged:
        run_paged()
    elif cli.cluster:
        run_cluster()
    else:
        run()
