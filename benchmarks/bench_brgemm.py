"""Kernel-level benchmark: batch-reduce GEMM vs batched GEMM vs looped
GEMMs (the paper's Section 2 claim at the kernel interface).

The XLA path is timed (CPU); the Pallas kernel is the TPU target and is
held to allclose-parity with this exact computation in tests/.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels.brgemm import ref as R

CASES = [
    # (batch, m, k, n)  — reduce-heavy shapes like conv/LSTM inner loops
    (16, 64, 64, 64),
    (32, 128, 128, 128),
    (64, 64, 256, 64),
]


def looped(a, b):
    out = jnp.zeros((a.shape[1], b.shape[2]), jnp.float32)
    for i in range(a.shape[0]):
        out = out + a[i] @ b[i]       # C stored/reloaded every step
    return out


def run():
    rng = np.random.default_rng(0)
    for (nb, m, k, n) in CASES:
        a = jnp.asarray(rng.normal(size=(nb, m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(nb, k, n)), jnp.float32)
        fl = 2 * nb * m * k * n

        br = jax.jit(lambda a, b: R.brgemm_ref(a, b))
        us = timeit(br, a, b)
        emit(f"sec2_brgemm_{nb}x{m}x{k}x{n}", us,
             f"{fl / us / 1e3:.1f}GFLOPs")

        bg = jax.jit(lambda a, b: R.batched_matmul_ref(a, b).sum(0))
        us = timeit(bg, a, b)
        emit(f"sec2_batchedgemm_{nb}x{m}x{k}x{n}", us,
             f"{fl / us / 1e3:.1f}GFLOPs")

        lp = jax.jit(looped)
        us = timeit(lp, a, b)
        emit(f"sec2_loopedgemm_{nb}x{m}x{k}x{n}", us,
             f"{fl / us / 1e3:.1f}GFLOPs")


if __name__ == "__main__":
    run()
