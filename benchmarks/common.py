"""Benchmark helpers: timing, CSV output, ResNet-50 layer table (paper
Table 2)."""
from __future__ import annotations

import time

import jax


def timeit(fn, *args, iters: int = 5, warmup: int = 2):
    """Median wall time per call in microseconds (jit-compiled fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


# Every emit() also lands here so run.py --json can persist the full
# trajectory (BENCH_*.json) without re-parsing its own stdout.
RECORDS: list[dict] = []


def emit(name: str, us: float, derived: str):
    RECORDS.append({"name": name, "us_per_call": round(us, 1),
                    "derived": derived})
    print(f"{name},{us:.1f},{derived}", flush=True)


# Paper Table 2: ResNet-50 convolution layer specifications.
RESNET50_LAYERS = [
    # id, C, K, H, W, R, S, stride
    (1, 3, 64, 224, 224, 7, 7, 2),
    (2, 64, 256, 56, 56, 1, 1, 1),
    (3, 64, 64, 56, 56, 1, 1, 1),
    (4, 64, 64, 56, 56, 3, 3, 1),
    (5, 256, 64, 56, 56, 1, 1, 1),
    (6, 256, 512, 56, 56, 1, 1, 2),
    (7, 256, 128, 56, 56, 1, 1, 2),
    (8, 128, 128, 28, 28, 3, 3, 1),
    (9, 128, 512, 28, 28, 1, 1, 1),
    (10, 512, 128, 28, 28, 1, 1, 1),
    (11, 512, 1024, 28, 28, 1, 1, 2),
    (12, 512, 256, 28, 28, 1, 1, 2),
    (13, 256, 256, 14, 14, 3, 3, 1),
    (14, 256, 1024, 14, 14, 1, 1, 1),
    (15, 1024, 256, 14, 14, 1, 1, 1),
    (16, 1024, 2048, 14, 14, 1, 1, 2),
    (17, 1024, 512, 14, 14, 1, 1, 2),
    (18, 512, 512, 7, 7, 3, 3, 1),
    (19, 512, 2048, 7, 7, 1, 1, 1),
    (20, 2048, 512, 7, 7, 1, 1, 1),
]


def conv_flops(n, c, k, h, w, r, s, stride):
    p = (h + 2 * (r // 2) - r) // stride + 1
    q = (w + 2 * (s // 2) - s) // stride + 1
    return 2 * n * k * p * q * c * r * s
