"""Paper Figure 10 proxy: distributed scaling of GNMT-style LSTM / CNN
training.

With one physical core, wall-time scaling is meaningless; the honest
CPU-measurable quantity is the *communication footprint* of the SPMD
program as the mesh grows — the thing that determines the paper's strong
scaling.  For data-parallel meshes of 2/4/8 devices this lowers the smollm
train step and reports all-reduce bytes per device per step (the gradient
volume), which is the Fig-10 x-axis driver, plus the model-flops per
device (perfect-scaling numerator).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_CODE = """
import json
import jax, jax.numpy as jnp
from repro import configs
from repro.configs.shapes import ShapeCfg
from repro.launch.mesh import make_mesh
from repro.sharding import rules
from repro.sharding.annotate import use_rules
from repro.train import optimizer as opt, train_step as ts
from repro.launch.dryrun import collective_bytes

cfg = configs.get("smollm-135m").reduced()
shape = ShapeCfg("t", "train", 128, 8)
ocfg = opt.AdamWCfg()
ndev = {ndev}
mesh = make_mesh((ndev, 1), ("data", "model"))
with mesh, use_rules(rules.activation_rules(mesh)):
    state = ts.abstract_state(cfg, ocfg)
    import repro.models.api as api
    batch = api.input_specs(cfg, shape)
    st_sh = rules.param_shardings(state, mesh)
    b_sh = rules.batch_shardings(batch, mesh)
    lowered = jax.jit(ts.make_train_step(cfg, ocfg),
                      in_shardings=(st_sh, b_sh)).lower(state, batch)
    compiled = lowered.compile()
    coll = collective_bytes(compiled.as_text())
    cost = compiled.cost_analysis() or {{}}
    print(json.dumps({{"coll": coll.get("total", 0),
                       "ar": coll.get("all-reduce", 0),
                       "flops": cost.get("flops")}}))
"""


def run():
    for ndev in (2, 4, 8):
        env = {**os.environ,
               "XLA_FLAGS": f"--xla_force_host_platform_device_count={ndev}",
               "PYTHONPATH": "src"}
        r = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_CODE.format(ndev=ndev))],
            capture_output=True, text=True, env=env)
        if r.returncode != 0:
            emit(f"fig10_dp{ndev}", 0.0, f"ERROR:{r.stderr[-120:]}")
            continue
        data = json.loads(r.stdout.strip().splitlines()[-1])
        emit(f"fig10_dp{ndev}_collective", 0.0,
             f"{data['coll'] / 1e6:.1f}MB/dev/step")
        emit(f"fig10_dp{ndev}_flops", 0.0,
             f"{(data['flops'] or 0) / 1e9:.1f}GFLOP/dev/step")


if __name__ == "__main__":
    run()
