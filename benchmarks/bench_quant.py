"""Quantized building block: bf16 vs int8 at matched shapes.

Two sections, both through the public ``matmul``/serve surfaces (the same
dispatch path production code takes):

  * GEMM — decode-shaped problems (small m, large k x n), where the GEMM
    is weight-streaming-bound and int8 storage halves the bytes per
    weight panel.  Weights are *calibrated offline*
    (``quantize_weight`` -> ``QuantizedTensor``) exactly as a serving
    deployment would ship them; only the per-row activation absmax is
    dynamic.  Compute-bound shapes (large m) are deliberately absent: on
    CPU XLA the int8 dot is slower than bf16 there, and the quant tier is
    a decode-time lever, not a prefill one.
  * serve — the same reduced smollm workload as ``bench_serving``, decoded
    once with full-precision params and once with a calibrated int8 param
    tree through ``ContinuousEngine`` — the tokens/s delta of int8 decode.

On CPU this is a proxy (XLA int8 dot vs bf16 dot); on TPU the same calls
route to the fused-dequant Pallas kernels.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_serving import MAX_LEN, _run_continuous, _workload
from benchmarks.common import emit
from repro import configs
from repro.core import brgemm
from repro.core.quantize import calibrate_params, quantize_weight
from repro.models import api
from repro.serve import ContinuousEngine, PoolConfig

# (m, n, k) single-token decode projections (m=1 is the canonical decode
# row) — the weight-streaming-bound regime where int8's halved panel
# bytes pay off.
DECODE_SHAPES = ((1, 1024, 1024), (1, 2048, 1024))
REPEATS = 5


def _best_of(fn, *args, repeats=REPEATS):
    jax.block_until_ready(fn(*args))  # warm / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run_gemm():
    rng = np.random.default_rng(0)
    for m, n, k in DECODE_SHAPES:
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w32 = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        flops = 2.0 * m * n * k

        xb = x.astype(jnp.bfloat16)
        wb = w32.astype(jnp.bfloat16)
        f_bf16 = jax.jit(lambda xx, ww: brgemm.matmul(xx, ww, backend="xla"))
        dt_bf16 = _best_of(f_bf16, xb, wb)
        emit(f"quant_gemm_bf16_{m}x{n}x{k}", dt_bf16 * 1e6,
             f"{flops / dt_bf16 / 1e9:.1f}GF/s")

        qw = quantize_weight(w32, "int8")
        f_int8 = jax.jit(lambda xx, ww: brgemm.matmul(xx, ww, backend="xla"))
        dt_int8 = _best_of(f_int8, x, qw)
        emit(f"quant_gemm_int8_{m}x{n}x{k}", dt_int8 * 1e6,
             f"{flops / dt_int8 / 1e9:.1f}GF/s")

        emit(f"quant_gemm_int8_vs_bf16_{m}x{n}x{k}", dt_int8 * 1e6,
             f"{dt_bf16 / dt_int8:.2f}x")


def run_serve():
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    n_requests, slots = 16, 4
    prompts, outs = _workload(cfg, n_requests)
    useful = sum(outs)

    pool = lambda: PoolConfig(n_slots=slots, max_len=MAX_LEN,  # noqa: E731
                              prefill_bucket=8)
    results = {}
    for name, p in (("fp32", params),
                    ("int8", calibrate_params(params, "int8"))):
        eng = ContinuousEngine(cfg, p, pool())
        _run_continuous(eng, prompts, outs)  # warm the jits
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            _run_continuous(eng, prompts, outs)
            best = min(best, time.perf_counter() - t0)
        results[name] = best
        emit(f"quant_serve_{name}_decode_r{n_requests}", best * 1e6,
             f"{useful / best:.1f}tok/s")
    emit(f"quant_serve_int8_vs_fp32_r{n_requests}",
         results["int8"] * 1e6,
         f"{results['fp32'] / results['int8']:.2f}x")


def run():
    run_gemm()
    run_serve()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
