"""Roofline accounting for the building-block ops, plus a traced
autotune demo.

For each op at a representative shape the XLA reference path is timed
(the CPU-benchmark baseline, as everywhere in benchmarks/) and combined
with ``repro.obs.op_cost`` — the analytic FLOP count and minimal byte
traffic of one execution — into the two roofline coordinates:

  * achieved GFLOP/s   (FLOPs / measured seconds)
  * arithmetic intensity (FLOPs / byte — the roofline x-axis)

High-intensity ops (big GEMMs, prefill attention) should sit near the
compute roof; low-intensity ones (decode-shaped GEMV-ish matmuls) are
bandwidth-bound no matter the kernel — the accounting makes the regime
of every op legible next to its measured rate.

The ``obs_autotune_traced`` row runs one measured block search under an
installed tracer and reports how many ``autotune.measure`` spans (one
per candidate, each carrying its own GFLOP/s estimate) it recorded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro
from benchmarks.common import emit, timeit
from repro import obs
from repro.core.blocking import ConvGeometry
from repro.kernels.brgemm.ops import brgemm, matmul
from repro.kernels.conv2d.ops import conv2d
from repro.kernels.flash_attention.ops import flash_attention


def _roofline(name: str, us: float, cost: obs.OpCost) -> None:
    gflops = cost.flops / (us * 1e-6) / 1e9
    emit(name, us, f"{gflops:.1f}GFLOPs "
                   f"intensity={cost.intensity:.1f}flop/byte")


def run():
    with repro.use(backend="xla"):
        _run()


def _run():
    rng = np.random.default_rng(0)
    f32 = jnp.float32

    # matmul: a compute-heavy square and a decode-shaped skinny one —
    # the two ends of the serving roofline
    for m, n, k in ((256, 256, 256), (4, 1024, 1024)):
        a = jnp.asarray(rng.normal(size=(m, k)), f32)
        b = jnp.asarray(rng.normal(size=(k, n)), f32)
        us = timeit(jax.jit(lambda a, b: matmul(a, b)), a, b)
        _roofline(f"obs_roofline_matmul_{m}x{n}x{k}", us,
                  obs.op_cost("matmul", m, n, k, f32))

    nb, m, n, k = 16, 64, 64, 64
    a = jnp.asarray(rng.normal(size=(nb, m, k)), f32)
    b = jnp.asarray(rng.normal(size=(nb, k, n)), f32)
    us = timeit(jax.jit(lambda a, b: brgemm(a, b)), a, b)
    _roofline(f"obs_roofline_brgemm_{nb}x{m}x{n}x{k}", us,
              obs.op_cost("brgemm", m, n, k, f32, batch=nb))

    # conv2d: ResNet-ish 3x3 (NHWC x RSCK)
    bsz, h, w, c, kk, r, s, stride = 2, 28, 28, 64, 64, 3, 3, 1
    x = jnp.asarray(rng.normal(size=(bsz, h, w, c)), f32)
    wgt = jnp.asarray(rng.normal(size=(r, s, c, kk)), f32) * 0.1
    us = timeit(jax.jit(lambda x, w: conv2d(x, w, stride=stride,
                                            padding=r // 2)), x, wgt)
    # canonical conv triple: (q, c, k) per output row, batch = N * P rows
    p_out = (h + 2 * (r // 2) - r) // stride + 1
    q_out = (w + 2 * (s // 2) - s) // stride + 1
    _roofline(f"obs_roofline_conv2d_{c}x{kk}x{h}x{w}", us,
              obs.op_cost("conv2d", q_out, c, kk, f32,
                          geometry=ConvGeometry(stride=stride, r=r, s=s),
                          batch=bsz * p_out))

    # flash attention: prefill-shaped (batch 1, 4 heads)
    bh, t, d = 4, 128, 64
    q = jnp.asarray(rng.normal(size=(1, bh, t, d)), f32)
    kv = jnp.asarray(rng.normal(size=(1, bh, t, d)), f32)
    us = timeit(jax.jit(lambda q, k, v: flash_attention(q, k, v)),
                q, kv, kv)
    _roofline(f"obs_roofline_flash_attention_{bh}x{t}x{d}", us,
              obs.op_cost("flash_attention", t, t, d, f32, batch=bh))

    # traced measured search: every candidate measurement is a span
    tracer = obs.Tracer()
    a = jnp.asarray(rng.normal(size=(128, 128)), f32)
    b = jnp.asarray(rng.normal(size=(128, 128)), f32)
    with repro.use(backend="pallas", interpret=True,
                   blocks_policy="autotune", tracer=tracer):
        jax.block_until_ready(matmul(a, b))
    measures = tracer.spans("autotune.measure")
    searches = tracer.spans("autotune.search")
    emit("obs_autotune_traced_128", 0.0,
         f"searches={len(searches)} measured_spans={len(measures)}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
