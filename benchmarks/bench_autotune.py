"""Heuristic vs measured-autotune block selection, per op family.

For each op's canonical tuning triple this times the Pallas kernel (the
autotuner's own proxy problem) twice — once with the static heuristic tile,
once with the tile the measured search picked — and emits both rows plus
the relative delta.  This is the PolyDL claim made measurable: the
remaining performance lives in the loop tiling around the one kernel.

Opt-in via ``run.py --compare-policies`` (the search itself costs a
compile-and-run per candidate, so it is not part of the default sweep).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import autotune, blocking, dispatch

CASES = [
    # (op, canonical (m, n, k)) — one representative shape per family
    ("matmul", (256, 256, 256)),
    ("conv2d", (28, 128, 128)),          # ResNet-50 28x28 layer row
    ("flash_attention", (128, 128, 64)),
    ("flash_attention_bwd", (128, 128, 64)),  # the training hot path
]


def _fmt(blocks) -> str:
    return "blocks=" + "x".join(str(v) for v in blocks.astuple())


def run():
    interpret = dispatch.resolve_interpret()
    for op, (m, n, k) in CASES:
        heur = blocking.default_blocks(op, m, n, k, jnp.float32)
        with dispatch.use(blocks_policy="autotune"):
            tuned = dispatch.resolve_blocks(op, m, n, k, jnp.float32,
                                            backend="pallas")
        us_h = timeit(autotune.proxy_runner(op, m, n, k, jnp.float32,
                                            heur, interpret))
        us_t = timeit(autotune.proxy_runner(op, m, n, k, jnp.float32,
                                            tuned, interpret))
        delta = (us_h - us_t) / us_h * 100.0
        emit(f"tune_{op}_{m}x{n}x{k}_heuristic", us_h, _fmt(heur))
        emit(f"tune_{op}_{m}x{n}x{k}_autotune", us_t,
             f"{_fmt(tuned)};delta={delta:+.1f}%")
