"""Heuristic vs measured-autotune block selection, per op family.

For each op's canonical tuning triple this times the Pallas kernel (the
autotuner's own proxy problem) twice — once with the static heuristic tile,
once with the tile the measured search picked — and emits both rows plus
the relative delta.  This is the PolyDL claim made measurable: the
remaining performance lives in the loop tiling around the one kernel.

``run.py --compare-policies --mesh DATAxMODEL`` adds the sharded
comparison: for each case the *local* (per-shard) problem is timed twice —
once with the tile the autotuner picked for the **global** shape (what a
mesh-unaware cache would serve every device) and once with the tile tuned
for the **local** shape through ``use(mesh=...)``.  The delta is the cost
of tuning for a problem no device runs.

Opt-in via ``run.py --compare-policies`` (the search itself costs a
compile-and-run per candidate, so it is not part of the default sweep).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import autotune, blocking, dispatch
from repro.sharding import local as shlocal

CASES = [
    # (op, canonical (m, n, k)) — one representative shape per family
    ("matmul", (256, 256, 256)),
    ("conv2d", (28, 128, 128)),          # ResNet-50 28x28 layer row
    ("flash_attention", (128, 128, 64)),
    ("flash_attention_bwd", (128, 128, 64)),  # the training hot path
]

# the --mesh sweep's GEMM cases: big enough that sharding moves the local
# problem, small enough to measure in interpret mode on CPU (on TPU, scale
# these up alongside the BENCH_*.json trajectory)
MESH_CASES = [
    ("matmul", (512, 256, 512)),
    ("brgemm", (256, 256, 512)),
]


def _fmt(blocks) -> str:
    return "blocks=" + "x".join(str(v) for v in blocks.astuple())


def _paired_timeit(fn_a, fn_b, iters: int = 5, warmup: int = 2):
    """Median us per call for two runners, measured *interleaved*.

    A fixed a-then-b ordering lets cold-start bias and interpret-mode
    jitter masquerade as a tuning delta; alternating every iteration
    exposes both runners to the same noise distribution."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    ta.sort()
    tb.sort()
    return ta[len(ta) // 2] * 1e6, tb[len(tb) // 2] * 1e6


def _parse_mesh(spec: str):
    """"2x4" -> a device-free (data, model) AbstractMesh."""
    try:
        data, model = (int(v) for v in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"--mesh expects DATAxMODEL (e.g. 2x4), got "
                         f"{spec!r}") from None
    return shlocal.abstract_mesh((data, model), ("data", "model"))


def run_mesh(mesh_spec: str):
    """Global-shape vs local-shape tuning under a mesh."""
    mesh = _parse_mesh(mesh_spec)
    interpret = dispatch.resolve_interpret()
    for op, (m, n, k) in MESH_CASES:
        with dispatch.use(blocks_policy="autotune"):
            tuned_global = dispatch.resolve_blocks(op, m, n, k, jnp.float32,
                                                   backend="pallas")
            with dispatch.use(mesh=mesh):
                tuned_local = dispatch.resolve_blocks(
                    op, m, n, k, jnp.float32, backend="pallas")
        lm, ln, lk = shlocal.local_problem(op, m, n, k, mesh)
        # both tiles run the *local* problem — the shard a device executes
        us_g, us_l = _paired_timeit(
            autotune.proxy_runner(op, lm, ln, lk, jnp.float32,
                                  tuned_global, interpret),
            autotune.proxy_runner(op, lm, ln, lk, jnp.float32,
                                  tuned_local, interpret))
        delta = (us_g - us_l) / us_g * 100.0
        tag = f"{m}x{n}x{k}@{mesh_spec}"
        emit(f"tune_mesh_{op}_{tag}_globaltile", us_g,
             f"{_fmt(tuned_global)};local={lm}x{ln}x{lk}")
        emit(f"tune_mesh_{op}_{tag}_localtile", us_l,
             f"{_fmt(tuned_local)};delta={delta:+.1f}%")


def run(mesh: str | None = None):
    interpret = dispatch.resolve_interpret()
    for op, (m, n, k) in CASES:
        heur = blocking.default_blocks(op, m, n, k, jnp.float32)
        with dispatch.use(blocks_policy="autotune"):
            tuned = dispatch.resolve_blocks(op, m, n, k, jnp.float32,
                                            backend="pallas")
        us_h = timeit(autotune.proxy_runner(op, m, n, k, jnp.float32,
                                            heur, interpret))
        us_t = timeit(autotune.proxy_runner(op, m, n, k, jnp.float32,
                                            tuned, interpret))
        delta = (us_h - us_t) / us_h * 100.0
        emit(f"tune_{op}_{m}x{n}x{k}_heuristic", us_h, _fmt(heur))
        emit(f"tune_{op}_{m}x{n}x{k}_autotune", us_t,
             f"{_fmt(tuned)};delta={delta:+.1f}%")
    if mesh:
        run_mesh(mesh)
