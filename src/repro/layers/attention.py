"""Attention layers: GQA/MQA (+ sliding window) and MLA (DeepSeek-V3).

All projections route through the batch-reduce GEMM building block; the
attention inner loop uses the flash kernel (itself a batch-reduce GEMM with
online-softmax epilogue) on the Pallas backend, or the jnp oracle on XLA.

Four modes:
  * train         — full causal sequence, no cache,
  * prefill       — train-compute + returns the KV cache,
  * prefill_chunk — one chunk of a longer prompt: queries live at absolute
    positions ``pos .. pos+T-1``, attend causally to everything already in
    the cache (``q_offset``), and append their KV at ``pos``.  Chaining
    chunks reproduces one-shot prefill exactly (the causal mask zeroes the
    not-yet-written tail bit-for-bit: ``exp(-1e30 - max) == 0``).
  * decode        — one token against a (padded) cache; GQA caches (k, v),
    MLA caches the *compressed* (c_kv, k_rope) and uses the
    absorbed-matmul formulation (the memory win that motivates MLA).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import brgemm
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import mha_ref
from repro.layers import norms
from repro.layers.rope import apply_rope


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int | None = None
    rope_theta: float = 10000.0
    window: int | None = None          # sliding-window size (None = full)
    # --- MLA (used when mla=True) ---
    mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    xla_impl: str = "naive"       # XLA-path attention: naive | chunked
    unroll: bool = False

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


def _lin(key, cin, cout, dtype):
    return (jax.random.normal(key, (cin, cout), jnp.float32)
            * (1.0 / cin) ** 0.5).astype(dtype)


def init(key, cfg: AttnCfg, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    if not cfg.mla:
        dh = cfg.dh
        return {
            "wq": _lin(ks[0], cfg.d_model, cfg.n_heads * dh, dtype),
            "wk": _lin(ks[1], cfg.d_model, cfg.n_kv_heads * dh, dtype),
            "wv": _lin(ks[2], cfg.d_model, cfg.n_kv_heads * dh, dtype),
            "wo": _lin(ks[3], cfg.n_heads * dh, cfg.d_model, dtype),
        }
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq_a": _lin(ks[0], cfg.d_model, cfg.q_lora_rank, dtype),
        "q_norm": norms.rmsnorm_init(cfg.q_lora_rank, dtype),
        "wq_b": _lin(ks[1], cfg.q_lora_rank, cfg.n_heads * qk_dim, dtype),
        "wkv_a": _lin(ks[2], cfg.d_model,
                      cfg.kv_lora_rank + cfg.qk_rope_dim, dtype),
        "kv_norm": norms.rmsnorm_init(cfg.kv_lora_rank, dtype),
        "wkv_b": _lin(ks[3], cfg.kv_lora_rank,
                      cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim),
                      dtype),
        "wo": _lin(ks[4], cfg.n_heads * cfg.v_head_dim, cfg.d_model, dtype),
    }


def init_cache(cfg: AttnCfg, batch: int, max_len: int, dtype=jnp.float32):
    if cfg.mla:
        return {
            "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        }
    dh = cfg.dh
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, max_len, dh), dtype),
        "v": jnp.zeros((batch, cfg.n_kv_heads, max_len, dh), dtype),
    }


def _split_heads(x, n_heads):
    b, t, _ = x.shape
    return x.reshape(b, t, n_heads, -1).transpose(0, 2, 1, 3)  # (B,H,T,dh)


def _merge_heads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------

def _gqa_qkv(params, x, cfg, positions, backend):
    q = _split_heads(brgemm.matmul(x, params["wq"], backend=backend),
                     cfg.n_heads)
    k = _split_heads(brgemm.matmul(x, params["wk"], backend=backend),
                     cfg.n_kv_heads)
    v = _split_heads(brgemm.matmul(x, params["wv"], backend=backend),
                     cfg.n_kv_heads)
    q = apply_rope(q, positions, theta=cfg.rope_theta)
    k = apply_rope(k, positions, theta=cfg.rope_theta)
    return q, k, v


def _gqa_train(params, x, cfg, backend):
    positions = jnp.arange(x.shape[1])
    q, k, v = _gqa_qkv(params, x, cfg, positions, backend)
    o = flash_attention(q, k, v, causal=True, window=cfg.window,
                        backend=backend, xla_impl=cfg.xla_impl,
                        unroll=cfg.unroll)
    return brgemm.matmul(_merge_heads(o), params["wo"], backend=backend)


def _gqa_prefill(params, x, cfg, cache, backend):
    positions = jnp.arange(x.shape[1])
    q, k, v = _gqa_qkv(params, x, cfg, positions, backend)
    o = flash_attention(q, k, v, causal=True, window=cfg.window,
                        backend=backend, xla_impl=cfg.xla_impl,
                        unroll=cfg.unroll)
    t = x.shape[1]
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    y = brgemm.matmul(_merge_heads(o), params["wo"], backend=backend)
    return y, cache


def _gqa_prefill_chunk(params, x, cfg, cache, pos, backend):
    """One prompt chunk at absolute positions ``pos .. pos+T-1``.

    The chunk's queries see the whole cache causally (earlier chunks plus
    this one); its K/V land at ``pos``.  Runs on the masked reference
    attention — the fused kernel has no ``q_offset`` — which is exact, not
    approximate, so chunked == one-shot prefill holds bit-for-bit on the
    reference path.
    """
    positions = pos + jnp.arange(x.shape[1])
    q, k, v = _gqa_qkv(params, x, cfg, positions, backend)
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, pos, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, pos, 0))
    o = mha_ref(q, cache["k"], cache["v"], causal=True, window=cfg.window,
                q_offset=pos, kv_len=pos + x.shape[1])
    y = brgemm.matmul(_merge_heads(o), params["wo"], backend=backend)
    return y, cache


def _gqa_decode(params, x, cfg, cache, pos, backend):
    positions = jnp.full((x.shape[1],), pos)
    q, k, v = _gqa_qkv(params, x, cfg, positions, backend)
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, pos, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, pos, 0))
    o = mha_ref(q, cache["k"], cache["v"], causal=False, window=cfg.window,
                q_offset=pos, kv_len=pos + 1)
    y = brgemm.matmul(_merge_heads(o), params["wo"], backend=backend)
    return y, cache


# --------------------------------------------------------------------------
# MLA
# --------------------------------------------------------------------------

def _mla_q(params, x, cfg, positions, backend):
    b, t, _ = x.shape
    cq = norms.rmsnorm(params["q_norm"],
                       brgemm.matmul(x, params["wq_a"], backend=backend))
    q = brgemm.matmul(cq, params["wq_b"], backend=backend)
    q = q.reshape(b, t, cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q = q.transpose(0, 2, 1, 3)
    q_nope, q_rope = (q[..., :cfg.qk_nope_dim],
                      q[..., cfg.qk_nope_dim:])
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)
    return q_nope, q_rope


def _mla_compressed_kv(params, x, cfg, positions, backend):
    ckv_full = brgemm.matmul(x, params["wkv_a"], backend=backend)
    c_kv = norms.rmsnorm(params["kv_norm"],
                         ckv_full[..., :cfg.kv_lora_rank])
    k_rope = ckv_full[..., cfg.kv_lora_rank:]          # (B, T, rope)
    k_rope = apply_rope(k_rope[:, None], positions,
                        theta=cfg.rope_theta)[:, 0]
    return c_kv, k_rope


def _mla_full(params, x, cfg, backend):
    """Train/prefill: expand the compressed KV to per-head K/V."""
    b, t, _ = x.shape
    positions = jnp.arange(t)
    q_nope, q_rope = _mla_q(params, x, cfg, positions, backend)
    c_kv, k_rope = _mla_compressed_kv(params, x, cfg, positions, backend)

    kv = brgemm.matmul(c_kv, params["wkv_b"], backend=backend)
    kv = kv.reshape(b, t, cfg.n_heads, cfg.qk_nope_dim + cfg.v_head_dim)
    kv = kv.transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :cfg.qk_nope_dim], kv[..., cfg.qk_nope_dim:]

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None],
                                  (b, cfg.n_heads, t, cfg.qk_rope_dim))],
        axis=-1)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    o = flash_attention(q, k, v, causal=True, scale=scale, backend=backend,
                        xla_impl=cfg.xla_impl, unroll=cfg.unroll)
    y = brgemm.matmul(_merge_heads(o), params["wo"], backend=backend)
    return y, c_kv, k_rope


def _mla_decode(params, x, cfg, cache, pos, backend):
    """Absorbed-matmul decode against the compressed cache."""
    b, t, _ = x.shape
    positions = jnp.full((t,), pos)
    q_nope, q_rope = _mla_q(params, x, cfg, positions, backend)
    c_kv_new, k_rope_new = _mla_compressed_kv(params, x, cfg, positions,
                                              backend)
    cache = dict(cache)
    cache["c_kv"] = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    cache["k_rope"] = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype),
        (0, pos, 0))

    wkv_b = params["wkv_b"].reshape(
        cfg.kv_lora_rank, cfg.n_heads, cfg.qk_nope_dim + cfg.v_head_dim)
    w_uk = wkv_b[..., :cfg.qk_nope_dim]    # (L, H, nope)
    w_uv = wkv_b[..., cfg.qk_nope_dim:]    # (L, H, v)

    q_eff = jnp.einsum("bhqn,lhn->bhql", q_nope, w_uk)
    s = (jnp.einsum("bhql,bsl->bhqs", q_eff, cache["c_kv"],
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhqr,bsr->bhqs", q_rope, cache["k_rope"],
                      preferred_element_type=jnp.float32))
    s = s * (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    kv_len = pos + 1
    mask = jnp.arange(cache["c_kv"].shape[1])[None, None, None] < kv_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o_c = jnp.einsum("bhqs,bsl->bhql", p, cache["c_kv"])
    o = jnp.einsum("bhql,lhv->bhqv", o_c, w_uv)
    y = brgemm.matmul(_merge_heads(o), params["wo"], backend=backend)
    return y, cache


def _mla_prefill_chunk(params, x, cfg, cache, pos, backend):
    """One prompt chunk through the absorbed-matmul path.

    Same cache layout and score math as ``_mla_decode``, generalized to
    ``Tq > 1`` queries at absolute positions ``pos .. pos+T-1`` with a
    causal mask against the compressed cache (earlier chunks + this one).
    """
    b, t, _ = x.shape
    positions = pos + jnp.arange(t)
    q_nope, q_rope = _mla_q(params, x, cfg, positions, backend)
    c_kv_new, k_rope_new = _mla_compressed_kv(params, x, cfg, positions,
                                              backend)
    cache = dict(cache)
    cache["c_kv"] = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    cache["k_rope"] = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype),
        (0, pos, 0))

    wkv_b = params["wkv_b"].reshape(
        cfg.kv_lora_rank, cfg.n_heads, cfg.qk_nope_dim + cfg.v_head_dim)
    w_uk = wkv_b[..., :cfg.qk_nope_dim]
    w_uv = wkv_b[..., cfg.qk_nope_dim:]

    q_eff = jnp.einsum("bhqn,lhn->bhql", q_nope, w_uk)
    s = (jnp.einsum("bhql,bsl->bhqs", q_eff, cache["c_kv"],
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhqr,bsr->bhqs", q_rope, cache["k_rope"],
                      preferred_element_type=jnp.float32))
    s = s * (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    q_pos = pos + jnp.arange(t)[:, None]                      # (Tq, 1)
    s_pos = jnp.arange(cache["c_kv"].shape[1])[None, :]       # (1, S)
    mask = s_pos <= q_pos                                     # causal
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o_c = jnp.einsum("bhqs,bsl->bhql", p, cache["c_kv"])
    o = jnp.einsum("bhql,lhv->bhqv", o_c, w_uv)
    y = brgemm.matmul(_merge_heads(o), params["wo"], backend=backend)
    return y, cache


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

def apply(params, x, cfg: AttnCfg, *, mode: str = "train", cache=None,
          pos=0, backend: str | None = None):
    """x: (B, T, D). Returns y for train, (y, cache) for prefill/decode."""
    if cfg.mla:
        if mode == "train":
            y, _, _ = _mla_full(params, x, cfg, backend)
            return y
        if mode == "prefill":
            y, c_kv, k_rope = _mla_full(params, x, cfg, backend)
            cache = dict(cache)
            cache["c_kv"] = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
            cache["k_rope"] = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                (0, 0, 0))
            return y, cache
        if mode == "prefill_chunk":
            return _mla_prefill_chunk(params, x, cfg, cache, pos, backend)
        if mode == "decode":
            return _mla_decode(params, x, cfg, cache, pos, backend)
        raise ValueError(mode)
    if mode == "train":
        return _gqa_train(params, x, cfg, backend)
    if mode == "prefill":
        return _gqa_prefill(params, x, cfg, cache, backend)
    if mode == "prefill_chunk":
        return _gqa_prefill_chunk(params, x, cfg, cache, pos, backend)
    if mode == "decode":
        return _gqa_decode(params, x, cfg, cache, pos, backend)
    raise ValueError(mode)
