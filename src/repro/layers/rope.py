"""Rotary position embeddings (supports position offsets for decode)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, *, theta: float = 10000.0):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim // 2,)


def apply_rope(x, positions, *, theta: float = 10000.0):
    """x: (..., T, d) with d even; positions: (T,) or (..., T)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta=theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    # Expand cos/sin to broadcast over any head dims between batch and T.
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)
