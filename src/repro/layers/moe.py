"""Mixture-of-Experts layer: top-k routing + capacity-based dispatch.

Expert FFNs execute as *batched* GEMMs over the expert dimension through the
batch-reduce building block (`batched_matmul`), so EP sharding of the expert
axis turns the dispatch scatter into an all-to-all under pjit.

Dispatch is GShard-style with capacity + token dropping (overflow tokens fall
into a discard slot); the combine re-gathers with the (renormalized) router
gates.  Aux outputs: load-balance loss and router z-loss.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import brgemm, dispatch
from repro.layers import mlp as mlp_layer
from repro.sharding.annotate import constrain


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    d_ff: int                 # per-expert hidden size
    n_experts: int
    top_k: int
    n_shared: int = 0         # DeepSeek-style always-on shared experts
    capacity_factor: float = 1.25
    activation: str = "silu"
    renormalize: bool = True
    # GShard-style grouped dispatch: one routing group per batch row, so
    # the dispatch buffers/scatters/expert-GEMM slots shard over the DP
    # axis instead of being redundantly computed on every DP shard.
    # (§Perf iteration 1: 16x expert-FLOP reduction on the 16x16 mesh.)
    grouped: bool = True


def init(key, cfg: MoECfg, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    s_in, s_out = (1.0 / d) ** 0.5, (1.0 / f) ** 0.5
    params = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * s_in
                   ).astype(dtype),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * s_in
                   ).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * s_in
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * s_out
                   ).astype(dtype),
    }
    if cfg.n_shared:
        params["shared"] = mlp_layer.init(
            ks[4], d, f * cfg.n_shared, gated=True, dtype=dtype)
    return params


def capacity(cfg: MoECfg, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    c = max(8, ((c + 3) // 4) * 4)
    # an expert can receive at most one assignment per token
    return min(c, ((n_tokens + 3) // 4) * 4)


def _shmap_over_dp(fn, g_: int):
    """Run fn shard_map'ed over the dp axes of the installed mesh (first
    arg dims sharded on dp); identity wrapper when no mesh is active."""
    from repro.sharding.annotate import current_mesh

    mesh = current_mesh()
    if mesh is None:
        return fn
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if not dp or size <= 1 or g_ % size != 0:
        return fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    spec = P(dp)
    return shard_map(fn, mesh=mesh, in_specs=(spec, spec), out_specs=spec,
                     check_rep=False)


def _route(params, xg, cfg: MoECfg, cap: int, backend):
    """Shared routing math. xg: (G, N, D) -> dispatch indices + gates.

    Capacity is enforced per group; with one group per batch row the
    position cumsum, scatter and combine all stay local to a DP shard.
    """
    g_, n, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = brgemm.matmul(
        xg, params["router"], out_dtype=jnp.float32, backend=backend)
    probs = jax.nn.softmax(logits, axis=-1)            # (G, N, E)
    gate_vals, ids = jax.lax.top_k(probs, k)           # (G, N, k)
    if cfg.renormalize:
        gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    flat_ids = ids.reshape(g_, n * k)
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)  # (G, N*k, E)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap)               # overflow -> discard
    return logits, probs, gate_vals, flat_ids, keep, safe_pos


def apply(params, x, cfg: MoECfg, *, backend: str | None = None):
    """x: (B, T, D) -> (y, aux). Routed experts + optional shared expert."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    if cfg.grouped and t > 1:
        g_, n = b, t                                   # group = batch row
        xg = x
    else:
        # decode (t == 1): per-row groups would pad capacity ~e/k-fold;
        # a single global group keeps the buffer at ~n_tokens (§Perf 1d)
        g_, n = 1, b * t
        xg = x.reshape(1, b * t, d)
    cap = capacity(cfg, n)

    logits, probs, gate_vals, flat_ids, keep, safe_pos = _route(
        params, xg, cfg, cap, backend)
    xg = constrain(xg, "activation")

    # Dispatch scatter, *local per dp shard*.  GSPMD cannot shard a scatter
    # whose leading dim is addressed by explicit index arrays (it gathered
    # 240 GB/dev of activations — §Perf iteration 1c), so the scatter runs
    # under shard_map over the dp axes; the subsequent constrain to
    # (dp, model-on-E) is the canonical MoE dispatch all-to-all.
    x_rep = jnp.repeat(xg, k, axis=1)                  # (G, N*k, D)
    slot = flat_ids * (cap + 1) + safe_pos             # (G, N*k)

    def _local_scatter(xr, sl):
        gi = jnp.broadcast_to(jnp.arange(xr.shape[0])[:, None], sl.shape)
        b_ = jnp.zeros((xr.shape[0], e * (cap + 1), d), xr.dtype)
        return b_.at[gi, sl].set(xr)

    def _local_gather(of, sl):
        gi = jnp.broadcast_to(jnp.arange(of.shape[0])[:, None], sl.shape)
        return of[gi, sl]

    buf = _shmap_over_dp(_local_scatter, g_)(x_rep, slot)
    buf = constrain(buf.reshape(g_, e, cap + 1, d), "moe_dispatch")
    expert_in = buf[:, :, :cap]                        # (G, E, cap, D)

    # expert FFN as batched GEMMs over (G, E).  Keeping the 4-D form (no
    # transpose/reshape across the dp-sharded group dim!) lets GSPMD keep
    # groups on dp and experts on model with no re-layout all-gathers
    # (§Perf iteration 1b).  On the Pallas path this is vmap-over-groups of
    # the batched brgemm; the XLA path writes the same contraction directly.
    def expert_gemm(lhs, w, activation="none"):
        if dispatch.resolve("batched_matmul", backend) == "xla":
            out = jnp.einsum("gecd,edf->gecf", lhs, w,
                             preferred_element_type=jnp.float32)
            from repro.core import fusion
            return fusion.apply(activation, out).astype(lhs.dtype)
        return jax.vmap(
            lambda l: brgemm.batched_matmul(
                l, w, activation=activation, backend=backend))(lhs)

    gt = expert_gemm(expert_in, params["w_gate"], cfg.activation)
    u = expert_gemm(expert_in, params["w_up"])
    out = expert_gemm(constrain(gt * u, "moe_dispatch"), params["w_down"])

    # combine all-to-all: bring expert outputs back to dp-local layout so
    # the gather below never crosses the model axis
    out_pad = jnp.pad(out, ((0, 0), (0, 0), (0, 1), (0, 0)))
    out_flat = constrain(out_pad.reshape(g_, e * (cap + 1), d),
                         "activation")
    y_tok = _shmap_over_dp(_local_gather, g_)(out_flat, slot)  # (G, N*k, D)
    w = (gate_vals.reshape(g_, n * k) * keep).astype(x.dtype)
    y = (y_tok * w[..., None]).reshape(g_, n, k, d).sum(axis=2)

    if cfg.n_shared:
        y = y + mlp_layer.apply(params["shared"], xg,
                                activation=cfg.activation, backend=backend)

    # aux losses (GShard load-balance + z-loss)
    me = probs.reshape(-1, e).mean(axis=0)             # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[flat_ids.reshape(-1)].add(
        1.0) / (g_ * n * k)
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce),
        "router_z_loss": jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_fraction": 1.0 - keep.mean(),
    }
    return y.reshape(b, t, d), aux
