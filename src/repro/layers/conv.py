"""Convolution layer — paper Algorithm 4 wrapped as a parametrized layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.conv2d import conv2d


def init(key, c: int, k: int, r: int, s: int, *, use_bias: bool = True,
         dtype=jnp.float32):
    fan_in = c * r * s
    params = {"w": (jax.random.normal(key, (r, s, c, k), jnp.float32)
                    * (2.0 / fan_in) ** 0.5).astype(dtype)}
    if use_bias:
        params["b"] = jnp.zeros((k,), dtype)
    return params


def apply(params, x, *, stride: int = 1, padding: int = 0,
          activation: str = "none", backend: str | None = None):
    return conv2d(
        x, params["w"], params.get("b"), stride=stride, padding=padding,
        activation=activation, backend=backend)
