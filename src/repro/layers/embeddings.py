"""Token embedding table + (optionally tied) output head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import brgemm


def init(key, vocab: int, d: int, *, dtype=jnp.float32):
    emb = jax.random.normal(key, (vocab, d), jnp.float32) * (1.0 / d) ** 0.5
    return {"table": emb.astype(dtype)}


def encode(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def decode(params, x, *, backend: str | None = None):
    """Logits = x @ table^T via the building block. x: (..., d)."""
    return brgemm.matmul(
        x, params["table"].T, out_dtype=jnp.float32, backend=backend)
