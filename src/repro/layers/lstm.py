"""LSTM cell via batch-reduce GEMM — paper Algorithm 2 / Equations 1-6.

The data-flow structure is the paper's: for each gate g in (i, c, f, o),

    pre_g = W_g . x_t                      (batch-reduce GEMM over C blocks)
    g_t   = act( R_g . h_{t-1} + pre_g + b_g )

where the second call *chains onto the first accumulator* (c0/beta=1) and
fuses the bias + sigma/tanh epilogue on the still-hot output block —
Alg 2 lines 6-17 verbatim.  The time-step loop (Alg 2 line 3, with its
all-thread barrier) becomes a ``lax.scan``: on TPU the barrier is implied by
the scan-carried dependency on h_{t-1}.

Tensor shapes follow the paper: x[T][N][C], h/s[T][N][K]; weights are stored
stacked (C, 4K)/(K, 4K) with gate order (i, c, f, o) — the per-gate blocked
layout W[Kb][Cb][bc][bk] is realized by the kernel's BlockSpec tiling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import brgemm

GATES = ("i", "c", "f", "o")
_GATE_ACT = {"i": "sigmoid", "c": "tanh", "f": "sigmoid", "o": "sigmoid"}


def init(key, c: int, k: int, *, dtype=jnp.float32, forget_bias: float = 1.0):
    kw, kr = jax.random.split(key)
    sw = (1.0 / c) ** 0.5
    sr = (1.0 / k) ** 0.5
    b = jnp.zeros((4, k), jnp.float32)
    b = b.at[GATES.index("f")].set(forget_bias)  # standard LSTM trick
    return {
        "w": (jax.random.normal(kw, (4, c, k), jnp.float32) * sw).astype(dtype),
        "r": (jax.random.normal(kr, (4, k, k), jnp.float32) * sr).astype(dtype),
        "b": b.astype(dtype),
    }


def cell_step(params, x_t, h_prev, s_prev, *, backend: str | None = None):
    """One LSTM time-step. x_t: (N, C); h_prev, s_prev: (N, K)."""
    gates = []
    for gi, g in enumerate(GATES):
        # pre = W_g . x_t        (Alg 2 lines 9-12)
        pre = brgemm.matmul(
            x_t, params["w"][gi], out_dtype=jnp.float32, backend=backend)
        # g_t = act(R_g . h_{t-1} + pre + b_g)   (lines 13-17, fused epilogue)
        gates.append(brgemm.matmul(
            h_prev, params["r"][gi], params["b"][gi], c0=pre, beta=1.0,
            activation=_GATE_ACT[g], backend=backend))
    i_t, c_t, f_t, o_t = gates
    s_t = f_t * s_prev + i_t * c_t              # Eq. 5 (line 19)
    h_t = o_t * jnp.tanh(s_t)                   # Eq. 6 (line 20)
    return h_t.astype(x_t.dtype), s_t.astype(x_t.dtype)


def forward(params, x, h0=None, s0=None, *, backend: str | None = None):
    """Full forward pass. x: (T, N, C) -> h, s: (T, N, K)."""
    t, n, _ = x.shape
    k = params["r"].shape[-1]
    h0 = h0 if h0 is not None else jnp.zeros((n, k), x.dtype)
    s0 = s0 if s0 is not None else jnp.zeros((n, k), x.dtype)

    def step(carry, x_t):
        h_prev, s_prev = carry
        h_t, s_t = cell_step(params, x_t, h_prev, s_prev, backend=backend)
        return (h_t, s_t), (h_t, s_t)

    (_, _), (h, s) = jax.lax.scan(step, (h0, s0), x)
    return h, s
