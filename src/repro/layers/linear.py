"""Fully-connected layer via batch-reduce GEMM — paper Algorithm 5.

The paper blocks W[K][C] -> W[Kb][Cb][bc][bk] so the microkernel sees
unit-stride panels; on TPU that blocking *is* the BlockSpec tiling of the
Pallas kernel (the logical parameter layout stays (C, K) and Mosaic handles
physical tiling).  The activation is fused on the VMEM-resident accumulator
(Alg 5 line 10: "while the output block Y is still hot in cache").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import brgemm


def init(key, c: int, k: int, *, use_bias: bool = True,
         dtype=jnp.float32, scale: float | None = None):
    wkey, _ = jax.random.split(key)
    scale = scale if scale is not None else (1.0 / c) ** 0.5
    params = {"w": (jax.random.normal(wkey, (c, k), jnp.float32) * scale
                    ).astype(dtype)}
    if use_bias:
        params["b"] = jnp.zeros((k,), dtype)
    return params


def apply(params, x, *, activation: str = "none", backend: str | None = None):
    """y = act(x @ W + b).  x: (..., C) -> (..., K)."""
    return brgemm.matmul(
        x, params["w"], params.get("b"), activation=activation,
        backend=backend)
