"""Normalization layers (fp32 statistics regardless of param dtype)."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, *, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mean = x32.mean(axis=-1, keepdims=True)
    var = ((x32 - mean) ** 2).mean(axis=-1, keepdims=True)
    y = (x32 - mean) * jnp.reciprocal(jnp.sqrt(var + eps))
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32)
    return y.astype(x.dtype)
