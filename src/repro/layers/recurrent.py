"""Recurrent layers: RG-LRU (Griffin/RecurrentGemma), mLSTM and sLSTM (xLSTM).

These are the paper's LSTM lineage (Sec. 3.1) carried to the 2024 assigned
architectures: every projection is a batch-reduce GEMM; the recurrences are
the fused-elementwise epilogues.

  * RG-LRU: diagonal linear recurrence -> parallel ``associative_scan`` for
    train/prefill, O(1) step for decode.
  * mLSTM: matrix-memory recurrence with exponential gating.  The naive
    per-step scan stores T copies of the (dk x dv) state in backward — fatal
    at seq 4k — so training uses the *chunkwise-parallel* form (inter-chunk
    state recurrence + intra-chunk attention-like compute), validated against
    the scan oracle in tests.
  * sLSTM: scalar-memory recurrence with block-diagonal (per-head) recurrent
    weights; genuinely sequential (the architecture's semantics), via scan.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import brgemm
from repro.layers import norms

_LOG_EPS = -1e30


# ==========================================================================
# RG-LRU
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class RGLRUCfg:
    d_model: int
    d_rnn: int
    conv_width: int = 4
    c: float = 8.0


def rglru_init(key, cfg: RGLRUCfg, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    d, dr = cfg.d_model, cfg.d_rnn
    s, sr = (1.0 / d) ** 0.5, (1.0 / dr) ** 0.5

    def lin(k_, ci, co):
        return (jax.random.normal(k_, (ci, co), jnp.float32)
                * (1.0 / ci) ** 0.5).astype(dtype)

    # Lambda init so a = sigmoid(lam) in [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u / (1 - u))
    return {
        "w_gelu": lin(ks[0], d, dr),
        "w_rnn_in": lin(ks[1], d, dr),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, dr), jnp.float32)
                   * sr).astype(dtype),
        "w_rgate": lin(ks[3], dr, dr),
        "b_rgate": jnp.zeros((dr,), dtype),
        "w_igate": lin(ks[4], dr, dr),
        "b_igate": jnp.zeros((dr,), dtype),
        "lam": lam.astype(dtype),
        "w_out": lin(ks[6], dr, d),
    }


def _causal_depthwise_conv(v, conv_w, prefix=None):
    """v: (B, T, d); conv_w: (W, d). prefix: (B, W-1, d) carried context."""
    w = conv_w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((v.shape[0], w - 1, v.shape[2]), v.dtype)
    vp = jnp.concatenate([prefix, v], axis=1)
    out = sum(vp[:, i:i + v.shape[1]] * conv_w[i] for i in range(w))
    return out, vp[:, -(w - 1):]


def _rglru_gates(params, v, cfg):
    r = brgemm.matmul(v, params["w_rgate"], params["b_rgate"],
                      activation="sigmoid")
    i = brgemm.matmul(v, params["w_igate"], params["b_igate"],
                      activation="sigmoid")
    log_a = (-cfg.c * jax.nn.softplus(params["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) input normalizer (Griffin Eq. 4)
    norm = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = norm * (i.astype(jnp.float32) * v.astype(jnp.float32))
    return a, b


def rglru_apply(params, x, cfg: RGLRUCfg, *, state=None,
                backend: str | None = None):
    """x: (B, T, D) -> (y, state). state = {"h", "conv"} for decode."""
    u = brgemm.matmul(x, params["w_gelu"], activation="gelu",
                      backend=backend)
    v = brgemm.matmul(x, params["w_rnn_in"], backend=backend)
    prefix = state["conv"] if state is not None else None
    v, conv_state = _causal_depthwise_conv(v, params["conv_w"], prefix)
    a, b = _rglru_gates(params, v, cfg)

    if x.shape[1] == 1 and state is not None:      # decode step
        h = a[:, 0] * state["h"] + b[:, 0]
        h_seq = h[:, None]
    else:                                          # parallel scan
        if state is not None:                      # inject carried h0
            b = b.at[:, 0].add(a[:, 0] * state["h"])
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br
        _, h_seq = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = h_seq[:, -1]

    y = brgemm.matmul((u.astype(jnp.float32) * h_seq).astype(x.dtype),
                      params["w_out"], backend=backend)
    return y, {"h": h, "conv": conv_state}


# ==========================================================================
# mLSTM
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class MLSTMCfg:
    d_model: int
    n_heads: int
    dk: int
    dv: int
    chunk: int = 128
    unroll: bool = False


def mlstm_init(key, cfg: MLSTMCfg, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    d, h = cfg.d_model, cfg.n_heads

    def lin(k_, ci, co):
        return (jax.random.normal(k_, (ci, co), jnp.float32)
                * (1.0 / ci) ** 0.5).astype(dtype)

    return {
        "wq": lin(ks[0], d, h * cfg.dk),
        "wk": lin(ks[1], d, h * cfg.dk),
        "wv": lin(ks[2], d, h * cfg.dv),
        "wi": lin(ks[3], d, h), "bi": jnp.zeros((h,), dtype),
        "wf": lin(ks[4], d, h),
        # forget bias init positive -> long memory at init (xLSTM paper)
        "bf": jnp.full((h,), 3.0, dtype),
        "wo": lin(ks[5], d, h * cfg.dv),
        "head_norm": norms.rmsnorm_init(cfg.dv, dtype),
        "w_out": lin(ks[6], h * cfg.dv, d),
    }


def mlstm_scan(q, k, v, logi, logf):
    """Stabilized per-step scan oracle.

    q,k: (B,H,T,dk); v: (B,H,T,dv); logi,logf: (B,H,T). -> h: (B,H,T,dv)
    """
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    c0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    n0 = jnp.zeros((b, h, dk), jnp.float32)
    m0 = jnp.full((b, h), _LOG_EPS, jnp.float32)

    def step(carry, xs):
        c, n, m = carry
        q_t, k_t, v_t, li, lf = xs
        m_new = jnp.maximum(lf + m, li)
        i_p = jnp.exp(li - m_new)[..., None]
        f_p = jnp.exp(lf + m - m_new)[..., None]
        n_new = f_p * n + i_p * k_t
        c_new = f_p[..., None] * c + i_p[..., None] * (
            k_t[..., :, None] * v_t[..., None, :])
        num = jnp.einsum("bhk,bhkv->bhv", q_t, c_new)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", q_t, n_new))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (c_new, n_new, m_new), num / den

    xs = (q.transpose(2, 0, 1, 3).astype(jnp.float32),
          k.transpose(2, 0, 1, 3).astype(jnp.float32),
          v.transpose(2, 0, 1, 3).astype(jnp.float32),
          logi.transpose(2, 0, 1), logf.transpose(2, 0, 1))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    return hs.transpose(1, 2, 0, 3), (c, n, m)


def mlstm_chunkwise(q, k, v, logi, logf, *, chunk: int = 128, state=None,
                    unroll: bool = False):
    """Chunkwise-parallel stabilized mLSTM (training path).

    Splits T into chunks; inter-chunk (C, n, m) recurrence via scan over
    chunks, intra-chunk compute is attention-like (L x L) — so backward
    stores only per-chunk states, not per-step matrix memories.
    """
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    l = min(chunk, t)
    assert t % l == 0, (t, l)
    nc = t // l

    def to_chunks(x):
        return x.reshape(b, h, nc, l, *x.shape[4:] if x.ndim > 4 else
                         x.shape[4:]) if False else x

    qc = q.reshape(b, h, nc, l, dk).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    kc = k.reshape(b, h, nc, l, dk).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    vc = v.reshape(b, h, nc, l, dv).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    lic = logi.reshape(b, h, nc, l).transpose(2, 0, 1, 3)
    lfc = logf.reshape(b, h, nc, l).transpose(2, 0, 1, 3)

    if state is None:
        c0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
        m0 = jnp.full((b, h), _LOG_EPS, jnp.float32)
    else:
        c0, n0, m0 = state

    tri = jnp.tril(jnp.ones((l, l), bool))

    def chunk_step(carry, xs):
        c, n, m = xs_c = carry
        q_t, k_t, v_t, li, lf = xs          # (B,H,L,*), (B,H,L)
        bcum = jnp.cumsum(lf, axis=-1)       # inclusive cumsum of log f
        g_tot = bcum[..., -1:]               # (B,H,1)

        # intra-chunk log-decay scores s[t, tau] = b_t - b_tau + li_tau
        s = (bcum[..., :, None] - bcum[..., None, :] + li[..., None, :])
        s = jnp.where(tri, s, _LOG_EPS)      # causal within chunk
        a_state = bcum + m[..., None]        # state-path log weight (B,H,L)

        m_t = jnp.maximum(a_state, s.max(axis=-1))         # (B,H,L)
        p = jnp.exp(s - m_t[..., None])                    # (B,H,L,L)
        state_w = jnp.exp(a_state - m_t)                   # (B,H,L)

        qk = jnp.einsum("bhtd,bhsd->bhts", q_t, k_t)
        num = (state_w[..., None] * jnp.einsum("bhtd,bhdv->bhtv", q_t, c)
               + jnp.einsum("bhts,bhts,bhsv->bhtv", p, qk, v_t))
        den = (state_w * jnp.einsum("bhtd,bhd->bht", q_t, n)
               + jnp.einsum("bhts,bhts->bht", p, qk))
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        h_out = num / den

        # end-of-chunk state update
        w_tok = g_tot - bcum + li                          # (B,H,L)
        m_new = jnp.maximum(g_tot[..., 0] + m, w_tok.max(axis=-1))
        carry_w = jnp.exp(g_tot[..., 0] + m - m_new)
        tok_w = jnp.exp(w_tok - m_new[..., None])
        c_new = (carry_w[..., None, None] * c
                 + jnp.einsum("bhs,bhsd,bhsv->bhdv", tok_w, k_t, v_t))
        n_new = carry_w[..., None] * n + jnp.einsum(
            "bhs,bhsd->bhd", tok_w, k_t)
        return (c_new, n_new, m_new), h_out

    (c, n, m), hs = jax.lax.scan(chunk_step, (c0, n0, m0),
                                 (qc, kc, vc, lic, lfc), unroll=unroll)
    hs = hs.transpose(1, 2, 0, 3, 4).reshape(b, h, t, dv)
    return hs, (c, n, m)


def mlstm_step(q1, k1, v1, li1, lf1, state):
    """Single decode step. q1,k1: (B,H,dk); v1: (B,H,dv); li1,lf1: (B,H)."""
    c, n, m = state
    m_new = jnp.maximum(lf1 + m, li1)
    i_p = jnp.exp(li1 - m_new)[..., None]
    f_p = jnp.exp(lf1 + m - m_new)[..., None]
    n_new = f_p * n + i_p * k1
    c_new = f_p[..., None] * c + i_p[..., None] * (
        k1[..., :, None] * v1[..., None, :])
    num = jnp.einsum("bhk,bhkv->bhv", q1, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q1, n_new)),
                      jnp.exp(-m_new))[..., None]
    return num / den, (c_new, n_new, m_new)


def mlstm_apply(params, x, cfg: MLSTMCfg, *, state=None,
                backend: str | None = None):
    """x: (B, T, D) -> (y, state)."""
    b, t, _ = x.shape
    h = cfg.n_heads

    def heads(y, dh):
        return y.reshape(b, t, h, dh).transpose(0, 2, 1, 3)

    q = heads(brgemm.matmul(x, params["wq"], backend=backend), cfg.dk)
    k = heads(brgemm.matmul(x, params["wk"], backend=backend), cfg.dk)
    k = k * (cfg.dk ** -0.5)
    v = heads(brgemm.matmul(x, params["wv"], backend=backend), cfg.dv)
    logi = (brgemm.matmul(x, params["wi"], params["bi"],
                          out_dtype=jnp.float32, backend=backend)
            ).transpose(0, 2, 1)                       # (B,H,T)
    logf = jax.nn.log_sigmoid(
        brgemm.matmul(x, params["wf"], params["bf"], out_dtype=jnp.float32,
                      backend=backend)).transpose(0, 2, 1)

    if t == 1 and state is not None:
        hv, state = mlstm_step(
            q[:, :, 0].astype(jnp.float32), k[:, :, 0].astype(jnp.float32),
            v[:, :, 0].astype(jnp.float32), logi[:, :, 0], logf[:, :, 0],
            state)
        hv = hv[:, :, None]
    else:
        hv, state = mlstm_chunkwise(q, k, v, logi, logf, chunk=cfg.chunk,
                                    state=state, unroll=cfg.unroll)

    hv = norms.rmsnorm(params["head_norm"], hv.astype(x.dtype))
    o = jax.nn.sigmoid(brgemm.matmul(x, params["wo"], backend=backend))
    o = o.reshape(b, t, h, cfg.dv).transpose(0, 2, 1, 3)
    y = (hv * o).transpose(0, 2, 1, 3).reshape(b, t, h * cfg.dv)
    y = brgemm.matmul(y, params["w_out"], backend=backend)
    return y, state


# ==========================================================================
# sLSTM
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class SLSTMCfg:
    d_model: int
    n_heads: int

    @property
    def dh(self):
        return self.d_model // self.n_heads


def slstm_init(key, cfg: SLSTMCfg, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.dh
    w = (jax.random.normal(ks[0], (d, 4 * d), jnp.float32)
         * (1.0 / d) ** 0.5).astype(dtype)
    r = (jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32)
         * (1.0 / dh) ** 0.5).astype(dtype)
    b = jnp.zeros((4 * d,), jnp.float32)
    # forget-gate bias positive
    b = b.at[2 * d:3 * d].set(3.0)
    return {"w": w, "r": r, "b": b.astype(dtype)}


def slstm_apply(params, x, cfg: SLSTMCfg, *, state=None,
                backend: str | None = None):
    """x: (B, T, D) -> (y, state). Gate order: z, i, f, o."""
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.dh
    x_part = brgemm.matmul(x, params["w"], out_dtype=jnp.float32,
                           backend=backend)          # (B,T,4D)
    bias = params["b"].astype(jnp.float32)
    r_w = params["r"].astype(jnp.float32)

    if state is None:
        state = {
            "h": jnp.zeros((b, d), jnp.float32),
            "c": jnp.zeros((b, d), jnp.float32),
            "n": jnp.ones((b, d), jnp.float32),
            "m": jnp.full((b, d), _LOG_EPS, jnp.float32),
        }

    def step(carry, xp):
        h_prev, c, n, m = carry
        hh = h_prev.reshape(b, h_, dh)
        rec = jnp.einsum("bhd,hde->bhe", hh, r_w).reshape(b, 4 * d)
        pre = xp + rec + bias
        z_t = jnp.tanh(pre[:, :d])
        li = pre[:, d:2 * d]
        lf = jax.nn.log_sigmoid(pre[:, 2 * d:3 * d])
        o_t = jax.nn.sigmoid(pre[:, 3 * d:])
        m_new = jnp.maximum(lf + m, li)
        i_p = jnp.exp(li - m_new)
        f_p = jnp.exp(lf + m - m_new)
        c_new = f_p * c + i_p * z_t
        n_new = f_p * n + i_p
        h_new = o_t * c_new / jnp.maximum(n_new, jnp.exp(-m_new))
        return (h_new, c_new, n_new, m_new), h_new

    h_ = h
    carry = (state["h"], state["c"], state["n"], state["m"])
    carry, hs = jax.lax.scan(step, carry, x_part.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    new_state = dict(zip(("h", "c", "n", "m"), carry))
    return y, new_state
