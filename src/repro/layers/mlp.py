"""Transformer MLP blocks via batch-reduce GEMM (dense + gated variants).

The activation is fused into the first GEMM's epilogue (paper Sec. 3.3.2 —
"apply g() while the output block is still hot").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import brgemm


def init(key, d: int, d_ff: int, *, gated: bool = True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    s_in = (1.0 / d) ** 0.5
    s_out = (1.0 / d_ff) ** 0.5
    params = {
        "w_up": (jax.random.normal(ks[0], (d, d_ff), jnp.float32) * s_in
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[1], (d_ff, d), jnp.float32) * s_out
                   ).astype(dtype),
    }
    if gated:
        params["w_gate"] = (jax.random.normal(ks[2], (d, d_ff), jnp.float32)
                            * s_in).astype(dtype)
    return params


def apply(params, x, *, activation: str = "silu",
          backend: str | None = None):
    if "w_gate" in params:
        # SwiGLU/GeGLU: act(x W_gate) * (x W_up), activation fused in-kernel
        g = brgemm.matmul(x, params["w_gate"], activation=activation,
                          backend=backend)
        u = brgemm.matmul(x, params["w_up"], backend=backend)
        h = g * u
    else:
        h = brgemm.matmul(x, params["w_up"], activation=activation,
                          backend=backend)
    return brgemm.matmul(h, params["w_down"], backend=backend)
