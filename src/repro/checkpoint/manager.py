"""Fault-tolerant checkpointing: atomic, async, keep-k, mesh-elastic.

Layout:  <dir>/step_<n>/shard_<host>.npz + MANIFEST.json, committed by
atomic rename of a temp directory (a crash mid-write never corrupts the
latest checkpoint).  ``save_async`` snapshots to host memory synchronously
(so training can donate buffers) and writes on a background thread.

Restore is *mesh-elastic*: arrays are saved unsharded (gathered per leaf)
and restored under any new mesh/sharding — the elastic-rescale path for
node-failure recovery (tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:08d}"

    def save(self, step: int, tree) -> None:
        flat, _ = _flatten(tree)
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "shard_0.npz", **flat)
        (tmp / "MANIFEST.json").write_text(json.dumps({
            "step": step, "n_arrays": len(flat),
            "keys": sorted(flat)}))
        final = self._step_dir(step)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)          # atomic commit
        self._gc()

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host RAM now; write in the background."""
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # sync device->host copy
        self._thread = threading.Thread(
            target=self.save, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*"))
        return steps[-1] if steps else None

    def restore(self, like_tree, step: int | None = None, *,
                shardings=None):
        """Restore into the structure of ``like_tree``; optionally place
        each leaf with the given shardings (elastic re-mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        data = np.load(self._step_dir(step) / "shard_0.npz")
        flat_like, treedef = _flatten(like_tree)
        leaves = []
        for key in flat_like:
            arr = data[key]
            leaves.append(arr)
        restored = jax.tree_util.tree_unflatten(
            treedef, [data[k] for k in flat_like])
        if shardings is not None:
            restored = jax.tree.map(
                lambda x, s: jax.device_put(x, s), restored, shardings)
        else:
            restored = jax.tree.map(
                lambda x, l: jax.numpy.asarray(x, l.dtype), restored,
                like_tree)
        return restored, step

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
