"""Quantized execution config + weight/activation quantization helpers.

The successor work to the source paper ("Harnessing Deep Learning and HPC
Kernels via High-Level Loop and Tensor Abstractions") shows the batch-reduce
GEMM building block carries low-precision datatypes unchanged: quantization
is *tuning-surface config*, not a new code path.  This module is that
config:

  * :class:`QuantConfig` — weight/activation storage dtype, scale
    granularity, and calibration mode.  It rides on the execution context
    (``repro.use(quant=...)``), joins the block-tuning cache key via
    :meth:`QuantConfig.tag`, and is validated in ``core.dispatch``.
  * :func:`quantize` / :func:`dequantize` — absmax scaling into int8 or
    fp8 storage, with reduction axes chosen by the caller (per-channel
    weight scales reduce the contraction dim; per-row activation scales
    reduce the feature dim).
  * :class:`QuantizedTensor` — a pre-quantized weight (storage + fp32
    scales) registered as a pytree node, so calibrated params flow through
    ``jit``/``lax.scan`` like plain arrays: a scan over stacked per-layer
    weights slices ``q`` and ``scale`` leaf-wise in lockstep.
  * :func:`calibrate_params` — offline weight calibration over a param
    pytree (``repro.quant.calibrate_params`` is the public alias).

The GEMM entry points (``repro.core.brgemm``) consume all of this through
dispatch — no call-site changes; see ``repro.kernels.brgemm.quant``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Max representable magnitude per storage dtype; the absmax scale is
# amax / QMAX so the largest entry lands exactly on the dtype's edge.
QMAX = {
    "int8": 127.0,
    "float8_e4m3fn": 448.0,
    "float8_e5m2": 57344.0,
}
STORAGE_DTYPES = tuple(sorted(QMAX))
GRANULARITIES = ("per_channel", "per_tensor")
A_GRANULARITIES = ("per_row", "per_tensor")
CALIBRATIONS = ("absmax",)

# Scales smaller than this clamp (an all-zero channel) quantize to zeros
# instead of dividing by zero.
_SCALE_FLOOR = 1e-30


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantized-execution config for the GEMM family.

    ``w_dtype`` / ``a_dtype`` name the weight / activation storage dtypes
    (int8 or an fp8 flavor).  ``granularity`` scopes the weight scales:
    ``per_channel`` keeps one fp32 scale per output channel (absmax over
    the contraction dim), ``per_tensor`` one scale for the whole operand.
    ``a_granularity`` scopes the dynamic activation scales likewise
    (``per_row`` = one scale per GEMM row).  ``calibration`` names the
    scale rule (``absmax`` — scale = absmax / qmax).
    """
    w_dtype: str = "int8"
    a_dtype: str = "int8"
    granularity: str = "per_channel"
    a_granularity: str = "per_row"
    calibration: str = "absmax"

    def __post_init__(self):
        for field, value, allowed in (
                ("w_dtype", self.w_dtype, STORAGE_DTYPES),
                ("a_dtype", self.a_dtype, STORAGE_DTYPES),
                ("granularity", self.granularity, GRANULARITIES),
                ("a_granularity", self.a_granularity, A_GRANULARITIES),
                ("calibration", self.calibration, CALIBRATIONS)):
            if value not in allowed:
                raise ValueError(
                    f"QuantConfig.{field}={value!r}; expected one of "
                    f"{', '.join(allowed)}")

    def tag(self) -> str:
        """Stable string form: the tuning-cache key / JSON field."""
        return (f"{self.w_dtype}:{self.a_dtype}:{self.granularity}:"
                f"{self.a_granularity}:{self.calibration}")

    @property
    def w_jnp(self):
        return jnp.dtype(self.w_dtype)

    @property
    def a_jnp(self):
        return jnp.dtype(self.a_dtype)

    @property
    def integer(self) -> bool:
        """Whether the accumulator is integer (int8 storage) vs fp32."""
        return self.w_dtype == "int8" and self.a_dtype == "int8"


_SHORTHANDS = {
    "int8": QuantConfig(),
    "fp8": QuantConfig(w_dtype="float8_e4m3fn", a_dtype="float8_e4m3fn"),
}


def as_quant_config(spec) -> QuantConfig:
    """Normalize a quant spec: QuantConfig | dict | shorthand/tag string.

    Strings accept the shorthands ``"int8"`` / ``"fp8"``, a bare storage
    dtype name, or a full :meth:`QuantConfig.tag` (round-trips).
    """
    if isinstance(spec, QuantConfig):
        return spec
    if isinstance(spec, dict):
        return QuantConfig(**spec)
    if isinstance(spec, str):
        if spec in _SHORTHANDS:
            return _SHORTHANDS[spec]
        if spec in QMAX:
            return QuantConfig(w_dtype=spec, a_dtype=spec)
        parts = spec.split(":")
        if len(parts) == 5:
            return QuantConfig(*parts)
        raise ValueError(
            f"unknown quant spec {spec!r}; expected 'int8', 'fp8', a "
            f"storage dtype ({', '.join(STORAGE_DTYPES)}), or a "
            f"QuantConfig tag")
    raise TypeError(
        f"quant must be a QuantConfig, dict, or string; got {type(spec)}")


# --------------------------------------------------------------------------
# quantize / dequantize
# --------------------------------------------------------------------------

def quantize(x, dtype: str = "int8", *, axis=None):
    """Absmax-quantize ``x``; returns ``(q, scale)`` with fp32 scales.

    ``axis`` gives the reduction axes of the absmax (the dims a single
    scale covers); ``None`` means one scale for the whole tensor.  The
    scale tensor drops the reduced axes, so ``q * expand(scale)``
    reconstructs: for a weight ``(..., k, n)`` with ``axis=-2`` the scale
    is ``(..., n)`` (per output channel).
    """
    if dtype not in QMAX:
        raise ValueError(f"unknown quant storage dtype {dtype!r}")
    x32 = jnp.asarray(x).astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, _SCALE_FLOOR) / jnp.float32(QMAX[dtype])
    q = x32 / scale
    if dtype == "int8":
        q = jnp.clip(jnp.round(q), -127.0, 127.0).astype(jnp.int8)
    else:
        q = q.astype(jnp.dtype(dtype))
    if axis is None:
        return q, scale.reshape(())
    return q, jnp.squeeze(scale, axis=axis)


def dequantize(q, scale):
    """Inverse of :func:`quantize`: expand the dropped axes and rescale.

    ``scale.ndim == q.ndim - 1`` is per-channel over the last axis
    (reduced axis was -2); ``q.ndim - 2`` is per-tensor over the trailing
    matrix dims; equal ranks multiply elementwise.
    """
    q32 = jnp.asarray(q).astype(jnp.float32)
    scale = jnp.asarray(scale).astype(jnp.float32)
    if scale.ndim == q32.ndim - 1:
        return q32 * scale[..., None, :]
    if scale.ndim == q32.ndim - 2:
        return q32 * scale[..., None, None]
    return q32 * scale


# --------------------------------------------------------------------------
# pre-quantized weights
# --------------------------------------------------------------------------

class QuantizedTensor:
    """A calibrated weight: quantized storage ``q`` + fp32 ``scale``.

    Registered as a pytree node (children: ``q``, ``scale``) so a
    calibrated param tree passes through ``jit`` and ``lax.scan``
    unchanged — scanning stacked per-layer weights slices both children
    in lockstep, yielding a per-layer ``QuantizedTensor``.  Exposes
    ``shape``/``ndim``/``dtype`` of the storage so GEMM wrappers can read
    the output dim without special-casing.
    """
    __slots__ = ("q", "scale")

    def __init__(self, q, scale):
        self.q = q
        self.scale = scale

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim

    @property
    def dtype(self):
        return self.q.dtype

    def dequantize(self):
        return dequantize(self.q, self.scale)

    def __repr__(self):
        return (f"QuantizedTensor(shape={tuple(self.q.shape)}, "
                f"dtype={self.q.dtype}, scale_shape={tuple(self.scale.shape)})")


jax.tree_util.register_pytree_node(
    QuantizedTensor,
    lambda t: ((t.q, t.scale), None),
    lambda aux, children: QuantizedTensor(*children),
)


def quantize_weight(w, quant) -> QuantizedTensor:
    """Calibrate one GEMM weight ``(..., k, n)`` under ``quant``.

    Per-channel scales reduce the contraction dim only, so stacked
    per-layer weights ``(L, k, n)`` get per-layer ``(L, n)`` scales —
    exactly what a ``lax.scan`` slice needs.
    """
    qcfg = as_quant_config(quant)
    if getattr(w, "ndim", 0) < 2:
        raise ValueError(f"GEMM weight must be >= 2-D; got shape "
                         f"{getattr(w, 'shape', None)}")
    axis = (-2,) if qcfg.granularity == "per_channel" else (-2, -1)
    q, scale = quantize(w, qcfg.w_dtype, axis=axis)
    return QuantizedTensor(q, scale)


# Param names never auto-quantized even though they start with "w": MLA's
# wkv_b is reshaped/einsum-ed outside the GEMM entry points.
CALIBRATE_DENYLIST = ("wkv_b",)


def _leaf_name(path) -> str | None:
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            return str(p.key)
    return None


def default_calibrate_predicate(path, leaf) -> bool:
    """Quantize ``w*``-named 2-D+ leaves (GEMM weights by convention);
    embedding tables, norm scales, and biases keep full precision."""
    name = _leaf_name(path)
    return (name is not None and name.startswith("w")
            and name not in CALIBRATE_DENYLIST
            and getattr(leaf, "ndim", 0) >= 2)


def calibrate_params(params, quant="int8", *, predicate=None):
    """Quantize the GEMM weights of a param pytree offline.

    Returns the same tree with selected leaves replaced by
    :class:`QuantizedTensor` (storage + per-channel scales).  The GEMM
    entry points detect quantized weights and run the quantized building
    block even without an active ``use(quant=...)`` context — so a
    calibrated tree is inference-ready as-is, and serving engines skip
    the per-step dynamic weight absmax.

    ``predicate(path, leaf) -> bool`` overrides leaf selection (default:
    :func:`default_calibrate_predicate`).  Calibration is inference-only:
    the quantized path does not define gradients.
    """
    qcfg = as_quant_config(quant)
    pred = predicate if predicate is not None else default_calibrate_predicate

    def visit(path, leaf):
        if isinstance(leaf, QuantizedTensor):
            return leaf
        if pred(path, leaf):
            return quantize_weight(leaf, qcfg)
        return leaf

    # is_leaf keeps already-calibrated weights atomic — without it the map
    # would recurse into the QuantizedTensor pytree and re-quantize its
    # int8 storage.
    return jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
