"""Compatibility aliases for the Pallas TPU API across jax releases."""
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; take
# whichever this jax provides, and fail loudly at import time (not with a
# cryptic NoneType error inside pallas_call) if neither exists.
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover - future jax incompatibility
    raise ImportError(
        "this jax release exposes neither pallas.tpu.CompilerParams nor "
        "pallas.tpu.TPUCompilerParams; update repro.core.pallas_compat "
        "for the new name")
