"""Core: the batch-reduce GEMM public API, unified backend dispatch
(op registry + execution context), blocking heuristics, and epilogues."""
