"""Core: the batch-reduce GEMM public API, blocking heuristics, epilogues."""
