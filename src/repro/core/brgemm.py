"""Core public API: the batch-reduce GEMM as the single building block.

Every matmul in this framework routes through this module — layers never
call ``jnp.dot`` directly for their compute hot-spots.  See
``repro.kernels.brgemm`` for the Pallas kernel and the XLA-path reference,
and ``repro.core.dispatch`` for the backend registry, the ``repro.use``
execution context, and the resolution precedence.
"""
from repro.kernels.brgemm import (  # noqa: F401
    batched_matmul,
    batched_matmul_q,
    brgemm,
    brgemm_q,
    matmul,
    matmul_q,
    resolve_backend,      # deprecated shim
    set_default_backend,  # deprecated shim
)
from repro.core.blocking import Blocks, choose_blocks  # noqa: F401
