"""Core public API: the batch-reduce GEMM as the single building block.

Every matmul in this framework routes through this module — layers never
call ``jnp.dot`` directly for their compute hot-spots.  See
``repro.kernels.brgemm`` for the Pallas kernel, the XLA-path reference, and
the backend-dispatch rules.
"""
from repro.kernels.brgemm import (  # noqa: F401
    batched_matmul,
    brgemm,
    matmul,
    resolve_backend,
    set_default_backend,
)
from repro.core.blocking import Blocks, choose_blocks  # noqa: F401
