"""Epilogue fusion registry for the batch-reduce GEMM kernel.

The paper's key fusion claim (Sec. 3.1.2, 3.3.2): element-wise operators are
applied on the just-computed output block *while it is hot in cache*.  On TPU
the analogue is applying the epilogue on the fp32 VMEM accumulator inside the
Pallas kernel, before the single write-back to HBM.

Every epilogue is defined in fp32 and must be usable both inside a Pallas
kernel body and in the pure-jnp reference path so the two stay bit-comparable.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax


def _gelu_tanh(x):
    # tanh approximation (matches jax.nn.gelu(approximate=True))
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


ACTIVATIONS = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "gelu": _gelu_tanh,
    "silu": lambda x: x * jax.nn.sigmoid(x),
    "exp": jnp.exp,
    "square": lambda x: x * x,
}

# Activation gradients expressible from the *output* y = act(pre).  These let
# the custom VJP avoid storing (or recomputing) the pre-activation.
GRAD_FROM_OUTPUT = {
    "none": lambda y: jnp.ones_like(y),
    "relu": lambda y: (y > 0).astype(y.dtype),
    "sigmoid": lambda y: y * (1.0 - y),
    "tanh": lambda y: 1.0 - y * y,
    "exp": lambda y: y,
}


def _gelu_grad_pre(pre):
    c = jnp.sqrt(2.0 / jnp.pi).astype(pre.dtype)
    inner = c * (pre + 0.044715 * pre**3)
    t = jnp.tanh(inner)
    sech2 = 1.0 - t * t
    return 0.5 * (1.0 + t) + 0.5 * pre * sech2 * c * (1.0 + 3 * 0.044715 * pre * pre)


def _silu_grad_pre(pre):
    s = jax.nn.sigmoid(pre)
    return s * (1.0 + pre * (1.0 - s))


# Gradients that need the pre-activation (recompute-based VJP path).
GRAD_FROM_PREACT = {
    "gelu": _gelu_grad_pre,
    "silu": _silu_grad_pre,
    "square": lambda pre: 2.0 * pre,
}


def needs_preact(activation: str) -> bool:
    """True if the activation gradient cannot be derived from the output."""
    if activation in GRAD_FROM_OUTPUT:
        return False
    if activation in GRAD_FROM_PREACT:
        return True
    raise ValueError(f"unknown activation {activation!r}")


def apply(activation: str, x):
    try:
        return ACTIVATIONS[activation](x)
    except KeyError:
        raise ValueError(
            f"unknown activation {activation!r}; known: {sorted(ACTIVATIONS)}"
        ) from None
