"""Blocking factors for every kernel in the library, on TPU.

The paper picks (m_b, n_b) so the accumulator block lives in registers and
the A/B panels stream from cache (Sec. 2, Fig. 2b).  On TPU the constraints
become:

  * lane dimension (last axis) must be a multiple of 128,
  * sublane dimension (second-minor) a multiple of 8 (fp32) / 16 (bf16) /
    32 (int8) for efficient VREG tiling,
  * MXU is a 128x128 systolic array -> contraction and output dims want to
    be multiples of 128,
  * the working set (A panel + B panel, double-buffered, + fp32 accumulator)
    must fit the ~16 MiB/core VMEM.

Every op family has its own block tuple (GEMM ``Blocks``, conv
``ConvBlocks``, attention ``AttnBlocks``) but they all resolve through one
schema table: :func:`default_blocks` is the static heuristic,
:func:`candidate_blocks` enumerates the pruned VMEM-feasible search grid the
measured autotuner (``core.autotune``) walks, and
``blocks_to_dict``/``blocks_from_dict`` give every tuple a JSON round-trip
for the persisted tuning cache.  Each op maps its loop nest onto a
canonical (m, n, k) triple — see the schema table at the bottom.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

LANE = 128
VMEM_BYTES = 16 * 1024 * 1024
# Leave headroom for Mosaic spills / semaphores / the output buffer.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024


def sublane(dtype) -> int:
    itemsize = jnp.dtype(dtype).itemsize
    return {4: 8, 2: 16, 1: 32}.get(itemsize, 8)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class Blocks:
    bm: int
    bn: int
    bk: int

    def astuple(self):
        return (self.bm, self.bn, self.bk)


def choose_blocks(
    m: int,
    n: int,
    k: int,
    dtype=jnp.float32,
    *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    prefer_bm: int = 128,
    prefer_bn: int = 128,
    prefer_bk: int = 512,
) -> Blocks:
    """Pick (bm, bn, bk) for a (m x k) @ (k x n) batch-reduce GEMM.

    Small dims are rounded up to the hardware tile (the wrapper pads), large
    dims get the preferred MXU-friendly block, and bk is shrunk until the
    double-buffered working set fits the VMEM budget.
    """
    itemsize = jnp.dtype(dtype).itemsize
    sub = sublane(dtype)

    bm = min(round_up(m, sub), prefer_bm)
    bm = round_up(bm, sub)
    bn = min(round_up(n, LANE), prefer_bn)
    bk = min(round_up(k, LANE), prefer_bk)

    while gemm_working_set(bm, bn, bk, itemsize) > vmem_budget and bk > LANE:
        bk = max(LANE, bk // 2)
    while gemm_working_set(bm, bn, bk, itemsize) > vmem_budget and bm > sub:
        bm = max(sub, bm // 2)
    return Blocks(bm=bm, bn=bn, bk=bk)


def gemm_working_set(bm: int, bn: int, bk: int, itemsize: int) -> int:
    """VMEM bytes for one GEMM tile: A/B panels double-buffered + fp32
    accumulator scratch + double-buffered output block.  The single
    feasibility model shared by the heuristic and the candidate grid."""
    panels = (bm * bk + bk * bn) * itemsize * 2
    return panels + bm * bn * 4 + bm * bn * itemsize * 2


# --------------------------------------------------------------------------
# op-specific block tuples
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvBlocks:
    """Direct-convolution tile: bq output pixels x bc input channels
    (the reduce panel) x bk output channels."""
    bq: int
    bc: int
    bk: int

    def astuple(self):
        return (self.bq, self.bc, self.bk)


@dataclasses.dataclass(frozen=True)
class AttnBlocks:
    """Flash-attention tile: block_q query rows x block_k kv rows per
    online-softmax step."""
    block_q: int
    block_k: int

    def astuple(self):
        return (self.block_q, self.block_k)


@dataclasses.dataclass(frozen=True)
class AttnBwdBlocks:
    """Flash-attention *backward* tile: block_q query rows x block_k kv
    rows per batch-reduce step of the dQ / dK/dV kernels.

    A separate tuple from ``AttnBlocks`` because the backward working set
    is very different from the forward's (q + dy + lse + delta panels on
    the q side, k + v panels plus dk/dv accumulators on the kv side), so
    the autotuner must be free to pick backward tiles independently of the
    forward winner for the same (tq, tk, d)."""
    block_q: int
    block_k: int

    def astuple(self):
        return (self.block_q, self.block_k)


@dataclasses.dataclass(frozen=True)
class ConvGeometry:
    """The non-canonical conv2d geometry (stride and filter extent) that
    shapes the real kernel's working set: the input panel streamed per
    grid step spans the *strided* output row plus the filter overhang, not
    the 1x1/stride-1 proxy.  Threaded through ``resolve_blocks`` so the
    candidate pruning, the autotune proxy problem, and the tuning-cache
    key all see the geometry the kernel will actually run."""
    kind = "conv"  # JSON tag (class attribute, not a field)
    stride: int
    r: int
    s: int

    def asdict(self):
        return {"kind": self.kind, **dataclasses.asdict(self)}


@dataclasses.dataclass(frozen=True)
class PagedAttnGeometry:
    """Paged-KV attention geometry: the kv stream is a gather over
    ``pages`` fixed-size pages of ``page_size`` positions, so a kv block
    that straddles a page boundary touches two non-contiguous source
    pages.  Threading this through ``resolve_blocks`` clamps ``block_k``
    to the page size (when a page is at least one lane tile) so every
    online-softmax step reads within one page, and keys the tuning cache
    on the page shape — the same (tq, tk, d) tunes separately for a paged
    serving tier and a contiguous one."""
    kind = "paged_attn"  # JSON tag (class attribute, not a field)
    page_size: int
    pages: int

    def asdict(self):
        return {"kind": self.kind, **dataclasses.asdict(self)}


def geometry_from_dict(d: dict | None):
    """Inverse of a geometry tuple's ``asdict`` (None passes through)."""
    if d is None:
        return None
    d = dict(d)
    cls = _GEOM_KIND_TO_CLS.get(d.pop("kind", None))
    if cls is None:
        raise ValueError(f"unknown geometry kind in {d!r}")
    return cls(**{k: int(v) for k, v in d.items()})


def choose_conv_blocks(
    q: int, c: int, k: int, dtype=jnp.float32, *, geometry=None
) -> ConvBlocks:
    """Static heuristic for conv2d: (q, c, k) = (out pixels/row, C, K)."""
    del geometry  # the heuristic stays static; candidates/proxy use it
    bq = min(round_up(q, 8), 128)
    bc = min(round_up(c, LANE), LANE)
    bk = min(round_up(k, LANE), LANE)
    return ConvBlocks(bq=bq, bc=bc, bk=bk)


def _page_clamp(block_k: int, geometry) -> int:
    """Largest lane-aligned block_k that stays within one KV page (no-op
    for sub-lane pages, where boundary crossings are unavoidable)."""
    if geometry is None or geometry.page_size < LANE:
        return block_k
    return min(block_k, geometry.page_size // LANE * LANE)


def choose_attention_blocks(
    tq: int, tk: int, d: int, dtype=jnp.float32, *, geometry=None
) -> AttnBlocks:
    """Static heuristic for flash attention: (tq, tk, d) = (query len,
    kv len, head dim).  With a ``PagedAttnGeometry``, block_k is clamped
    so no kv block straddles a page boundary."""
    del d
    return AttnBlocks(block_q=min(round_up(tq, 8), 128),
                      block_k=_page_clamp(min(round_up(tk, LANE), LANE),
                                          geometry))


def choose_attention_bwd_blocks(
    tq: int, tk: int, d: int, dtype=jnp.float32
) -> AttnBwdBlocks:
    """Static heuristic for the flash-attention backward kernels."""
    del d
    return AttnBwdBlocks(block_q=min(round_up(tq, 8), 128),
                         block_k=min(round_up(tk, LANE), LANE))


# --------------------------------------------------------------------------
# candidate grids for the measured autotuner
# --------------------------------------------------------------------------
#
# Each enumerator returns a deterministic, pruned list: only tiles that are
# hardware-legal, not wastefully larger than the (padded) problem, and whose
# working set fits the VMEM budget.  The heuristic pick is always a member,
# so autotuning can never do worse than the heuristic on the measured
# problem.

def _steps(lo: int, hi: int) -> list[int]:
    out, v = [], lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def gemm_candidates(
    m: int, n: int, k: int, dtype=jnp.float32, *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> list[Blocks]:
    itemsize = jnp.dtype(dtype).itemsize
    sub = sublane(dtype)

    bms = [b for b in _steps(sub, 256) if b <= round_up(m, sub) or b == sub]
    bns = [b for b in _steps(LANE, 256)
           if b <= round_up(n, LANE) or b == LANE]
    bks = [b for b in _steps(LANE, 1024)
           if b <= round_up(k, LANE) or b == LANE]
    cands = [
        Blocks(bm, bn, bk)
        for bm in bms for bn in bns for bk in bks
        if gemm_working_set(bm, bn, bk, itemsize) <= vmem_budget
    ]
    heur = choose_blocks(m, n, k, dtype, vmem_budget=vmem_budget)
    if heur not in cands:
        cands.append(heur)
    return sorted(cands, key=lambda b: b.astuple())


def conv_candidates(
    q: int, c: int, k: int, dtype=jnp.float32, *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    geometry: ConvGeometry | None = None,
) -> list[ConvBlocks]:
    itemsize = jnp.dtype(dtype).itemsize
    stride = geometry.stride if geometry is not None else 1
    s_ = geometry.s if geometry is not None else 1

    def working_set(bq, bc, bk):
        # The kernel streams one full padded input row per grid step:
        # (qp-1)*stride + (s-1) + stride columns (kernel.py's need_w), so
        # the panel scales with the *strided problem row*, not just bq.
        qp = round_up(q, bq)
        wpad = (qp - 1) * stride + (s_ - 1) + stride
        panels = (wpad * bc + bc * bk) * itemsize * 2
        return panels + bq * bk * 4 + bq * bk * itemsize * 2

    bqs = [b for b in _steps(8, 256) if b <= round_up(q, 8) or b == 8]
    bcs = [b for b in _steps(LANE, 256)
           if b <= round_up(c, LANE) or b == LANE]
    bks = [b for b in _steps(LANE, 256)
           if b <= round_up(k, LANE) or b == LANE]
    cands = [
        ConvBlocks(bq, bc, bk)
        for bq in bqs for bc in bcs for bk in bks
        if working_set(bq, bc, bk) <= vmem_budget
    ]
    heur = choose_conv_blocks(q, c, k, dtype, geometry=geometry)
    if heur not in cands:
        cands.append(heur)
    return sorted(cands, key=lambda b: b.astuple())


def attention_candidates(
    tq: int, tk: int, d: int, dtype=jnp.float32, *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    geometry: PagedAttnGeometry | None = None,
) -> list[AttnBlocks]:
    itemsize = jnp.dtype(dtype).itemsize
    dp = round_up(d, LANE)

    def working_set(bq, bk):
        panels = (bq * dp + 2 * bk * dp) * itemsize * 2  # q + k + v
        acc = bq * dp * 4 + 2 * bq * LANE * 4            # acc + (m, l)
        return panels + acc + bq * bk * 4                # + scores block

    def in_page(bk):
        # paged KV: only kv blocks that evenly tile a page (boundary
        # crossings would gather from two non-contiguous pages)
        if geometry is None or geometry.page_size < LANE:
            return True
        return bk <= geometry.page_size and geometry.page_size % bk == 0

    bqs = [b for b in _steps(8, 256) if b <= round_up(tq, 8) or b == 8]
    bks = [b for b in _steps(LANE, 512)
           if (b <= round_up(tk, LANE) or b == LANE) and in_page(b)]
    cands = [
        AttnBlocks(bq, bk)
        for bq in bqs for bk in bks
        if working_set(bq, bk) <= vmem_budget
    ]
    heur = choose_attention_blocks(tq, tk, d, dtype, geometry=geometry)
    if heur not in cands:
        cands.append(heur)
    return sorted(cands, key=lambda b: b.astuple())


def attention_bwd_candidates(
    tq: int, tk: int, d: int, dtype=jnp.float32, *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> list[AttnBwdBlocks]:
    itemsize = jnp.dtype(dtype).itemsize
    dp = round_up(d, LANE)

    def working_set(bq, bk):
        # q + dy + y panels on the q side (y feeds the fused delta in the
        # dQ kernel), k + v on the kv side, all double buffered; lse +
        # delta stats rows; dq or dk+dv accumulators (the dk/dv kernel is
        # the larger resident set) plus the dQ kernel's delta accumulator;
        # scores + ds blocks.
        panels = (3 * bq * dp + 2 * bk * dp) * itemsize * 2
        stats = 2 * bq * LANE * 4 * 2
        accs = 2 * bk * dp * 4 + bq * dp * 4 + bq * LANE * 4
        return panels + stats + accs + 2 * bq * bk * 4

    bqs = [b for b in _steps(8, 256) if b <= round_up(tq, 8) or b == 8]
    bks = [b for b in _steps(LANE, 512)
           if b <= round_up(tk, LANE) or b == LANE]
    cands = [
        AttnBwdBlocks(bq, bk)
        for bq in bqs for bk in bks
        if working_set(bq, bk) <= vmem_budget
    ]
    heur = choose_attention_bwd_blocks(tq, tk, d, dtype)
    if heur not in cands:
        cands.append(heur)
    return sorted(cands, key=lambda b: b.astuple())


# --------------------------------------------------------------------------
# per-op schema: one resolution surface for every block tuple
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockSchema:
    """How an op maps onto the canonical (m, n, k) tuning triple."""
    kind: str                    # JSON tag
    cls: type
    dims: tuple[str, str, str]   # what (m, n, k) mean for this op
    heuristic: Callable          # (m, n, k, dtype) -> block tuple
    candidates: Callable         # (m, n, k, dtype) -> [block tuple]
    geometry_cls: type | None = None  # non-canonical-dims tuple, if any


_GEMM_SCHEMA = BlockSchema(
    kind="gemm", cls=Blocks, dims=("m", "n", "k"),
    heuristic=choose_blocks, candidates=gemm_candidates)

BLOCK_SCHEMAS: dict[str, BlockSchema] = {
    "matmul": _GEMM_SCHEMA,
    "brgemm": _GEMM_SCHEMA,
    "batched_matmul": _GEMM_SCHEMA,
    "conv2d": BlockSchema(
        kind="conv", cls=ConvBlocks, dims=("q", "c", "k"),
        heuristic=choose_conv_blocks, candidates=conv_candidates,
        geometry_cls=ConvGeometry),
    "flash_attention": BlockSchema(
        kind="attn", cls=AttnBlocks, dims=("tq", "tk", "d"),
        heuristic=choose_attention_blocks, candidates=attention_candidates,
        geometry_cls=PagedAttnGeometry),
    "flash_attention_bwd": BlockSchema(
        kind="attn_bwd", cls=AttnBwdBlocks, dims=("tq", "tk", "d"),
        heuristic=choose_attention_bwd_blocks,
        candidates=attention_bwd_candidates),
}


def schema_for(op: str) -> BlockSchema:
    schema = BLOCK_SCHEMAS.get(op)
    if schema is None:
        raise ValueError(
            f"no block schema for op {op!r}; known: "
            f"{', '.join(sorted(BLOCK_SCHEMAS))}")
    return schema


def default_blocks(op: str, m: int, n: int, k: int, dtype=jnp.float32, *,
                   geometry=None):
    """The static heuristic pick for ``op`` in its own block tuple type."""
    schema = schema_for(op)
    if geometry is not None and schema.geometry_cls is not None:
        return schema.heuristic(m, n, k, dtype, geometry=geometry)
    return schema.heuristic(m, n, k, dtype)


def candidate_blocks(op: str, m: int, n: int, k: int, dtype=jnp.float32, *,
                     geometry=None):
    """Deterministically ordered VMEM-feasible candidate tiles for ``op``."""
    schema = schema_for(op)
    if geometry is not None and schema.geometry_cls is not None:
        return schema.candidates(m, n, k, dtype, geometry=geometry)
    return schema.candidates(m, n, k, dtype)


_KIND_TO_CLS = {s.kind: s.cls for s in BLOCK_SCHEMAS.values()}
_GEOM_KIND_TO_CLS = {s.geometry_cls.kind: s.geometry_cls
                     for s in BLOCK_SCHEMAS.values()
                     if s.geometry_cls is not None}


def blocks_to_dict(blocks) -> dict:
    """JSON-serializable form of any op's block tuple."""
    for schema in BLOCK_SCHEMAS.values():
        if isinstance(blocks, schema.cls):
            return {"kind": schema.kind, **dataclasses.asdict(blocks)}
    raise TypeError(f"not a block tuple: {blocks!r}")


def blocks_from_dict(d: dict):
    """Inverse of :func:`blocks_to_dict`."""
    d = dict(d)
    cls = _KIND_TO_CLS.get(d.pop("kind", None))
    if cls is None:
        raise ValueError(f"unknown block kind in {d!r}")
    return cls(**{k: int(v) for k, v in d.items()})
