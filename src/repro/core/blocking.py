"""Blocking-factor heuristics for the batch-reduce GEMM kernel on TPU.

The paper picks (m_b, n_b) so the accumulator block lives in registers and
the A/B panels stream from cache (Sec. 2, Fig. 2b).  On TPU the constraints
become:

  * lane dimension (last axis) must be a multiple of 128,
  * sublane dimension (second-minor) a multiple of 8 (fp32) / 16 (bf16) /
    32 (int8) for efficient VREG tiling,
  * MXU is a 128x128 systolic array -> contraction and output dims want to
    be multiples of 128,
  * the working set (A panel + B panel, double-buffered, + fp32 accumulator)
    must fit the ~16 MiB/core VMEM.
"""
from __future__ import annotations

import dataclasses
import jax.numpy as jnp

LANE = 128
VMEM_BYTES = 16 * 1024 * 1024
# Leave headroom for Mosaic spills / semaphores / the output buffer.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024


def sublane(dtype) -> int:
    itemsize = jnp.dtype(dtype).itemsize
    return {4: 8, 2: 16, 1: 32}.get(itemsize, 8)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class Blocks:
    bm: int
    bn: int
    bk: int

    def astuple(self):
        return (self.bm, self.bn, self.bk)


def choose_blocks(
    m: int,
    n: int,
    k: int,
    dtype=jnp.float32,
    *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    prefer_bm: int = 128,
    prefer_bn: int = 128,
    prefer_bk: int = 512,
) -> Blocks:
    """Pick (bm, bn, bk) for a (m x k) @ (k x n) batch-reduce GEMM.

    Small dims are rounded up to the hardware tile (the wrapper pads), large
    dims get the preferred MXU-friendly block, and bk is shrunk until the
    double-buffered working set fits the VMEM budget.
    """
    itemsize = jnp.dtype(dtype).itemsize
    sub = sublane(dtype)

    bm = min(round_up(m, sub), prefer_bm)
    bm = round_up(bm, sub)
    bn = min(round_up(n, LANE), prefer_bn)
    bk = min(round_up(k, LANE), prefer_bk)

    def working_set(bm, bn, bk):
        panels = (bm * bk + bk * bn) * itemsize * 2  # double buffered
        acc = bm * bn * 4  # fp32 accumulator in VMEM scratch
        out = bm * bn * itemsize * 2
        return panels + acc + out

    while working_set(bm, bn, bk) > vmem_budget and bk > LANE:
        bk = max(LANE, bk // 2)
    while working_set(bm, bn, bk) > vmem_budget and bm > sub:
        bm = max(sub, bm // 2)
    return Blocks(bm=bm, bn=bn, bk=bk)
