"""Unified building-block dispatch: op registry + execution context.

The paper's thesis is that every DL primitive reduces to one building block
(the batch-reduce GEMM); the library around it degenerates to tuning of
loops around this sole kernel.  This module is the API expression of that
consolidation: every primitive op (``matmul``, ``brgemm``,
``batched_matmul``, ``conv2d``, ``flash_attention``) registers named
backend implementations here, and every knob that used to be hand-threaded
(backend selection, interpret mode, block geometry, accumulation dtype)
resolves through one ``ExecutionContext``.

Backend resolution precedence (first set wins):

  1. explicit ``backend=`` call argument        (never falls back)
  2. innermost active ``use(backend=...)`` context
     (the deprecated ``set_default_backend`` global acts as the outermost
     context entry, preserving its legacy override-beats-env behavior)
  3. the ``REPRO_BACKEND`` env var (legacy alias: ``REPRO_BRGEMM_BACKEND``)
  4. hardware default: ``pallas`` on TPU, ``xla`` elsewhere

A backend chosen by tiers 2-4 that is unavailable on the current platform
(per its capability predicate) falls back deterministically to the highest
priority available backend for that op.  An explicitly requested backend
(tier 1) never falls back: it raises instead, so tests and benchmarks fail
loudly rather than silently measuring the wrong path.

Block selection routes through a memoized, shape-keyed tuning cache keyed
``(op, backend, m, n, k, dtype, policy)`` so a future autotuner drops in
via :func:`register_block_policy` without touching any call site.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import os
import threading
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.blocking import Blocks, choose_blocks

ENV_VAR = "REPRO_BACKEND"
LEGACY_ENV_VAR = "REPRO_BRGEMM_BACKEND"


# --------------------------------------------------------------------------
# op registry
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendImpl:
    op: str
    name: str
    fn: Callable
    available: Callable[[], bool]
    priority: int  # fallback order: higher first


_REGISTRY: dict[str, dict[str, BackendImpl]] = {}
_KERNELS_IMPORTED = False
_REGISTER_LOCK = threading.RLock()  # reentrant: the import re-enters dispatch


def _ensure_registered() -> None:
    """Import the kernel packages so their ops modules self-register.

    Marked done only after a *successful* import, so a failed first import
    (broken dep, interrupt) is retried rather than leaving the registry
    permanently empty; the lock keeps concurrent first-resolvers from
    observing a partially-populated registry.
    """
    global _KERNELS_IMPORTED
    if _KERNELS_IMPORTED:
        return
    with _REGISTER_LOCK:
        if _KERNELS_IMPORTED:
            return
        import repro.kernels  # noqa: F401
        _KERNELS_IMPORTED = True


def pallas_available() -> bool:
    """The Pallas TPU kernels compile on TPU and interpret on CPU."""
    return jax.default_backend() in ("tpu", "cpu")


def register(op: str, backend: str, fn: Callable | None = None, *,
             available: Callable[[], bool] | None = None,
             priority: int = 0):
    """Register ``fn`` as the ``backend`` implementation of ``op``.

    Usable directly or as a decorator.  ``available`` is a zero-arg
    capability predicate evaluated at resolution time (platform checks);
    ``priority`` orders the deterministic fallback (higher first).
    """
    def deco(f):
        _REGISTRY.setdefault(op, {})[backend] = BackendImpl(
            op=op, name=backend, fn=f,
            available=available or (lambda: True), priority=priority)
        return f
    return deco if fn is None else deco(fn)


def registered_ops() -> tuple[str, ...]:
    _ensure_registered()
    return tuple(sorted(_REGISTRY))


def backends_for(op: str) -> tuple[str, ...]:
    """All registered backend names for ``op`` (available or not)."""
    return tuple(sorted(_impls(op)))


def available_backends(op: str) -> tuple[str, ...]:
    """Backend names for ``op`` whose capability predicate holds now."""
    return tuple(sorted(n for n, b in _impls(op).items() if b.available()))


def _impls(op: str) -> dict[str, BackendImpl]:
    _ensure_registered()
    impls = _REGISTRY.get(op)
    if not impls:
        known = ", ".join(sorted(_REGISTRY)) or "<none>"
        raise ValueError(f"unknown op {op!r}; registered ops: {known}")
    return impls


def _known_backend_names() -> set[str]:
    _ensure_registered()
    return {n for impls in _REGISTRY.values() for n in impls}


def _check_backend_name(name: str) -> None:
    known = _known_backend_names()
    if name not in known:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(sorted(known))}")


# --------------------------------------------------------------------------
# execution context
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """One frame of execution configuration; ``None`` fields are unset and
    inherit from the enclosing frame (or the env/hardware default)."""
    backend: str | None = None
    blocks_policy: str | Callable | None = None
    accum_dtype: Any = None
    interpret: bool | None = None


_STACK: contextvars.ContextVar[tuple[ExecutionContext, ...]] = \
    contextvars.ContextVar("repro_dispatch_stack", default=())

# Backing store for the deprecated ``set_default_backend`` shim.  Treated
# as the outermost context frame: any ``use`` context overrides it, and it
# overrides the env var — exactly the old brgemm-only global's precedence.
_DEPRECATED_GLOBAL_BACKEND: str | None = None


@contextlib.contextmanager
def use(*, backend: str | None = None,
        blocks_policy: str | Callable | None = None,
        accum_dtype=None, interpret: bool | None = None):
    """Scope execution configuration: ``with repro.use(backend="xla"): ...``

    Only the fields passed are set; everything else inherits from the
    enclosing context.  Nesting composes (innermost set field wins) and the
    previous state is restored on exit, including on exceptions.

    Note: a jit-compiled function captures whatever the context resolves to
    at *trace* time; entering a different context later does not retrace
    already-compiled code.
    """
    if backend is not None:
        _check_backend_name(backend)
    if (blocks_policy is not None and not callable(blocks_policy)
            and blocks_policy not in BLOCK_POLICIES):
        raise ValueError(
            f"unknown blocks_policy {blocks_policy!r}; registered policies: "
            f"{', '.join(sorted(BLOCK_POLICIES))} (or pass a callable)")
    ctx = ExecutionContext(backend=backend, blocks_policy=blocks_policy,
                           accum_dtype=accum_dtype, interpret=interpret)
    token = _STACK.set(_STACK.get() + (ctx,))
    try:
        yield ctx
    finally:
        _STACK.reset(token)


def current_context() -> ExecutionContext:
    """The merged view of the active context stack (innermost wins)."""
    backend = _DEPRECATED_GLOBAL_BACKEND
    blocks_policy = accum_dtype = interpret = None
    for ctx in _STACK.get():
        backend = ctx.backend if ctx.backend is not None else backend
        blocks_policy = (ctx.blocks_policy if ctx.blocks_policy is not None
                         else blocks_policy)
        accum_dtype = (ctx.accum_dtype if ctx.accum_dtype is not None
                       else accum_dtype)
        interpret = ctx.interpret if ctx.interpret is not None else interpret
    return ExecutionContext(backend=backend, blocks_policy=blocks_policy,
                            accum_dtype=accum_dtype, interpret=interpret)


# --------------------------------------------------------------------------
# resolution
# --------------------------------------------------------------------------

def _hardware_default() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _env_backend() -> str | None:
    return os.environ.get(ENV_VAR) or os.environ.get(LEGACY_ENV_VAR) or None


def resolve(op: str, backend: str | None = None) -> str:
    """Resolve the backend name for ``op`` under the precedence order."""
    impls = _impls(op)
    explicit = backend is not None
    name = (backend or current_context().backend or _env_backend()
            or _hardware_default())
    if name not in impls:
        raise ValueError(
            f"unknown backend {name!r} for op {op!r}; registered backends: "
            f"{', '.join(sorted(impls))}")
    if impls[name].available():
        return name
    if explicit:
        raise RuntimeError(
            f"backend {name!r} for op {op!r} is not available on platform "
            f"{jax.default_backend()!r} (explicitly requested, so not "
            f"falling back; available: {', '.join(available_backends(op))})")
    for cand in sorted(impls.values(), key=lambda b: (-b.priority, b.name)):
        if cand.available():
            return cand.name
    raise RuntimeError(
        f"no available backend for op {op!r} on platform "
        f"{jax.default_backend()!r}; registered: "
        f"{', '.join(sorted(impls))}")


def get_impl(op: str, backend: str | None = None) -> Callable:
    """Resolve and return the implementation callable for ``op``."""
    return _impls(op)[resolve(op, backend)].fn


def call(op: str, *args, backend: str | None = None, **kwargs):
    """One-shot dispatch: resolve ``op`` and invoke its implementation."""
    return get_impl(op, backend)(*args, **kwargs)


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Pallas interpret mode: call arg > context > (not on TPU)."""
    if interpret is not None:
        return bool(interpret)
    ctx = current_context().interpret
    if ctx is not None:
        return bool(ctx)
    return jax.default_backend() != "tpu"


def resolve_accum_dtype(accum_dtype=None):
    """Accumulation dtype for the GEMM family: call arg > context > fp32."""
    if accum_dtype is not None:
        return jnp.dtype(accum_dtype)
    ctx = current_context().accum_dtype
    return jnp.dtype(ctx) if ctx is not None else jnp.dtype(jnp.float32)


# --------------------------------------------------------------------------
# shape-keyed block tuning cache
# --------------------------------------------------------------------------

BLOCK_POLICIES: dict[str, Callable] = {}
_TUNING_CACHE: dict[tuple, Blocks] = {}
_TUNING_LOCK = threading.Lock()


def register_block_policy(name: str, fn: Callable) -> None:
    """Register a block-selection policy.

    ``fn(op, m, n, k, dtype, backend) -> Blocks``.  Results are memoized in
    the tuning cache, so an expensive search-based autotuner pays its cost
    once per (op, shape, dtype, backend).
    """
    BLOCK_POLICIES[name] = fn


register_block_policy(
    "heuristic", lambda op, m, n, k, dtype, backend: choose_blocks(
        m, n, k, dtype))


def resolve_blocks(op: str, m: int, n: int, k: int, dtype, *, backend: str,
                   blocks: Blocks | None = None) -> Blocks:
    """Block geometry for a GEMM-shaped op: call arg > context policy.

    Policy results are memoized keyed (op, backend, shapes, dtype, policy);
    an explicit ``blocks`` argument bypasses the cache entirely.
    """
    if blocks is not None:
        return blocks
    policy = current_context().blocks_policy or "heuristic"
    if callable(policy):
        # keyed on the callable itself so ad-hoc autotuners are memoized
        # too (a fresh lambda per call site gets a fresh entry)
        policy_fn, policy_key = policy, policy
    else:
        policy_fn, policy_key = BLOCK_POLICIES[policy], policy
    key = (op, backend, int(m), int(n), int(k), jnp.dtype(dtype).name,
           policy_key)
    hit = _TUNING_CACHE.get(key)
    if hit is None:
        hit = policy_fn(op, m, n, k, dtype, backend)
        with _TUNING_LOCK:
            _TUNING_CACHE[key] = hit
    return hit


def tuning_cache_info() -> dict[tuple, Blocks]:
    return dict(_TUNING_CACHE)


def clear_tuning_cache() -> None:
    _TUNING_CACHE.clear()


# --------------------------------------------------------------------------
# deprecated shims (pre-dispatch API)
# --------------------------------------------------------------------------

def set_default_backend(name: str | None) -> None:
    """Deprecated: use ``with repro.use(backend=...)`` instead."""
    warnings.warn(
        "set_default_backend is deprecated; use "
        "`with repro.use(backend=...)` instead",
        DeprecationWarning, stacklevel=2)
    if name is not None:
        _check_backend_name(name)
    global _DEPRECATED_GLOBAL_BACKEND
    _DEPRECATED_GLOBAL_BACKEND = name


def resolve_backend(backend: str | None = None, op: str = "brgemm") -> str:
    """Deprecated: use ``repro.core.dispatch.resolve(op, backend)``."""
    warnings.warn(
        "resolve_backend is deprecated; use "
        "repro.core.dispatch.resolve(op, backend) instead",
        DeprecationWarning, stacklevel=2)
    return resolve(op, backend)
