"""Unified building-block dispatch: op registry + execution context.

The paper's thesis is that every DL primitive reduces to one building block
(the batch-reduce GEMM); the library around it degenerates to tuning of
loops around this sole kernel.  This module is the API expression of that
consolidation: every primitive op (``matmul``, ``brgemm``,
``batched_matmul``, ``conv2d``, ``flash_attention``) registers named
backend implementations here, and every knob that used to be hand-threaded
(backend selection, interpret mode, block geometry, accumulation dtype)
resolves through one ``ExecutionContext``.

Backend resolution precedence (first set wins):

  1. explicit ``backend=`` call argument        (never falls back)
  2. innermost active ``use(backend=...)`` context
     (the deprecated ``set_default_backend`` global acts as the outermost
     context entry, preserving its legacy override-beats-env behavior)
  3. the ``REPRO_BACKEND`` env var (legacy alias: ``REPRO_BRGEMM_BACKEND``)
  4. hardware default: ``pallas`` on TPU, ``xla`` elsewhere

Between tiers 1 and 2 sits the per-op pin: an ``axis_specs`` entry may be
a dict ``{"axes": ..., "backend": ...}``, and its ``backend`` wins over the
context-wide backend for that op only — e.g. pin ``backend="xla"`` for an
all-gather-heavy row-parallel op while pallas serves the rest.

A backend chosen by tiers 2-4 (including a per-op pin) that is unavailable
on the current platform (per its capability predicate) falls back
deterministically to the highest priority available backend for that op.
An explicitly requested backend (tier 1) never falls back: it raises
instead, so tests and benchmarks fail loudly rather than silently
measuring the wrong path.

Quantized execution enters the same way: ``use(quant=...)`` puts a
``repro.core.quantize.QuantConfig`` on the context; the GEMM entry points
read it via :func:`resolve_quant` and route to the quantized building
block (``repro.kernels.brgemm.quant``), and :func:`resolve_blocks` keys
the tuning cache with the quant tag (int8 tiles have different VMEM
footprints, so quantized problems tune separately).

Block selection routes through a memoized, shape-keyed tuning cache keyed
``(op, backend, m, n, k, dtype, policy)``.  Every op resolves its geometry
here — the GEMM family's ``Blocks``, conv2d's ``ConvBlocks``, and
flash-attention's ``AttnBlocks`` all flow through :func:`resolve_blocks`
under a pluggable policy (``heuristic`` by default; the measured
``autotune`` policy registers from :mod:`repro.core.autotune`).  The cache
persists to JSON (:func:`save_cache` / :func:`load_cache`, or automatically
via the ``REPRO_TUNING_CACHE`` env var) so tuning cost is paid once per
machine.

Resolution is *mesh-aware*: with ``use(mesh=...)`` active, the canonical
(m, n, k) an op reports is the **global** problem, but every device of a
sharded execution runs a local shard of it — so :func:`resolve_blocks`
first maps the triple to the per-device local problem
(:func:`repro.sharding.local.local_problem`, using the same divisibility
fallback as the sharding rules; override per op with
``use(axis_specs={op: (m_axes, n_axes, k_axes)})``), then tunes, caches,
and persists under ``(local problem, mesh signature)``.  Policies —
including the measured autotuner — therefore always see and measure the
local shape, and a tuned cache transfers across mesh sizes exactly when
the local shapes match.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import inspect
import json
import os
import threading
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp

import repro.obs as _obs
from repro.obs import telemetry as _telemetry
from repro.core.blocking import (
    BLOCK_SCHEMAS,
    Blocks,
    blocks_from_dict,
    blocks_to_dict,
    default_blocks,
    geometry_from_dict,
)

ENV_VAR = "REPRO_BACKEND"
LEGACY_ENV_VAR = "REPRO_BRGEMM_BACKEND"
TUNING_CACHE_ENV = "REPRO_TUNING_CACHE"


# --------------------------------------------------------------------------
# op registry
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendImpl:
    op: str
    name: str
    fn: Callable
    available: Callable[[], bool]
    priority: int  # fallback order: higher first


_REGISTRY: dict[str, dict[str, BackendImpl]] = {}
_KERNELS_IMPORTED = False
_REGISTER_LOCK = threading.RLock()  # reentrant: the import re-enters dispatch


def _ensure_registered() -> None:
    """Import the kernel packages so their ops modules self-register.

    Marked done only after a *successful* import, so a failed first import
    (broken dep, interrupt) is retried rather than leaving the registry
    permanently empty; the lock keeps concurrent first-resolvers from
    observing a partially-populated registry.
    """
    global _KERNELS_IMPORTED
    if _KERNELS_IMPORTED:
        return
    with _REGISTER_LOCK:
        if _KERNELS_IMPORTED:
            return
        import repro.kernels  # noqa: F401
        _KERNELS_IMPORTED = True


def pallas_available() -> bool:
    """The Pallas TPU kernels compile on TPU and interpret on CPU."""
    return jax.default_backend() in ("tpu", "cpu")


def register(op: str, backend: str, fn: Callable | None = None, *,
             available: Callable[[], bool] | None = None,
             priority: int = 0):
    """Register ``fn`` as the ``backend`` implementation of ``op``.

    Usable directly or as a decorator.  ``available`` is a zero-arg
    capability predicate evaluated at resolution time (platform checks);
    ``priority`` orders the deterministic fallback (higher first).
    """
    def deco(f):
        _REGISTRY.setdefault(op, {})[backend] = BackendImpl(
            op=op, name=backend, fn=f,
            available=available or (lambda: True), priority=priority)
        return f
    return deco if fn is None else deco(fn)


def registered_ops() -> tuple[str, ...]:
    _ensure_registered()
    return tuple(sorted(_REGISTRY))


def backends_for(op: str) -> tuple[str, ...]:
    """All registered backend names for ``op`` (available or not)."""
    return tuple(sorted(_impls(op)))


def available_backends(op: str) -> tuple[str, ...]:
    """Backend names for ``op`` whose capability predicate holds now."""
    return tuple(sorted(n for n, b in _impls(op).items() if b.available()))


def _impls(op: str) -> dict[str, BackendImpl]:
    _ensure_registered()
    impls = _REGISTRY.get(op)
    if not impls:
        known = ", ".join(sorted(_REGISTRY)) or "<none>"
        raise ValueError(f"unknown op {op!r}; registered ops: {known}")
    return impls


def _known_backend_names() -> set[str]:
    _ensure_registered()
    return {n for impls in _REGISTRY.values() for n in impls}


def _check_backend_name(name: str) -> None:
    known = _known_backend_names()
    if name not in known:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(sorted(known))}")


# --------------------------------------------------------------------------
# execution context
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """One frame of execution configuration; ``None`` fields are unset and
    inherit from the enclosing frame (or the env/hardware default).

    ``mesh`` is any object exposing ``axis_names`` and ``shape`` (a real
    ``jax.sharding.Mesh`` or an ``AbstractMesh``); ``axis_specs`` maps op
    names to canonical-triple axis assignments (see
    ``repro.sharding.local.local_problem``) or to dicts
    ``{"axes": triple, "backend": name}`` adding a per-op backend pin;
    ``quant`` is a ``repro.core.quantize.QuantConfig`` (or None for full
    precision); ``tracer`` is a ``repro.obs.Tracer`` scoped to this
    context (and the asyncio tasks it spawns)."""
    backend: str | None = None
    blocks_policy: str | Callable | None = None
    accum_dtype: Any = None
    interpret: bool | None = None
    mesh: Any = None
    axis_specs: Any = None
    quant: Any = None
    tracer: Any = None


_STACK: contextvars.ContextVar[tuple[ExecutionContext, ...]] = \
    contextvars.ContextVar("repro_dispatch_stack", default=())

# Backing store for the deprecated ``set_default_backend`` shim.  Treated
# as the outermost context frame: any ``use`` context overrides it, and it
# overrides the env var — exactly the old brgemm-only global's precedence.
_DEPRECATED_GLOBAL_BACKEND: str | None = None


def _axis_spec_axes(spec):
    """The (m, n, k) axis triple of an axis_specs entry, or None.

    An entry is either the bare triple or a dict ``{"axes": triple,
    "backend": name}``; a dict without ``axes`` pins only the backend and
    leaves the default axis assignment in force."""
    if isinstance(spec, dict):
        return spec.get("axes")
    return spec


def _axis_spec_backend(spec) -> str | None:
    """The per-op backend pin of an axis_specs entry, or None."""
    if isinstance(spec, dict):
        return spec.get("backend")
    return None


def _check_axis_spec(op: str, spec) -> None:
    """An axis spec is one entry per canonical dim: exactly 3 entries,
    each ``None`` / axis name / tuple of axis names — or a dict with
    ``axes`` (the same triple) and/or ``backend`` (a per-op backend pin).
    A bare string would silently iterate per *character* (every letter an
    unknown axis -> everything replicates), so reject it loudly here."""
    if isinstance(spec, dict):
        unknown = set(spec) - {"axes", "backend"}
        if unknown:
            raise ValueError(
                f"axis_specs[{op!r}]: unknown key(s) {sorted(unknown)}; "
                f"a dict entry takes 'axes' and/or 'backend'")
        backend = spec.get("backend")
        if backend is not None:
            _check_backend_name(backend)
            if backend not in _impls(op):
                raise ValueError(
                    f"axis_specs[{op!r}]: backend {backend!r} is not "
                    f"registered for this op (has: "
                    f"{', '.join(sorted(_impls(op)))})")
        spec = spec.get("axes")
        if spec is None:
            return
    bad = None
    if isinstance(spec, str) or not hasattr(spec, "__iter__"):
        bad = f"{spec!r} is not a sequence of 3 entries"
    else:
        entries = tuple(spec)
        if len(entries) != 3:
            bad = f"expected 3 entries (m, n, k), got {len(entries)}"
        else:
            for e in entries:
                if e is None or isinstance(e, str):
                    continue
                if isinstance(e, (tuple, list)) and all(
                        isinstance(a, str) for a in e):
                    continue
                bad = (f"entry {e!r} is not None, an axis name, or a "
                       f"tuple of axis names")
                break
    if bad:
        raise ValueError(f"axis_specs[{op!r}]: {bad}")


@contextlib.contextmanager
def use(*, backend: str | None = None,
        blocks_policy: str | Callable | None = None,
        accum_dtype=None, interpret: bool | None = None,
        mesh=None, axis_specs=None, quant=None, tracer=None):
    """Scope execution configuration: ``with repro.use(backend="xla"): ...``

    Only the fields passed are set; everything else inherits from the
    enclosing context.  Nesting composes (innermost set field wins) and the
    previous state is restored on exit, including on exceptions.

    ``mesh`` makes block resolution *per-shard*: every op's canonical
    (m, n, k) is mapped to the per-device local problem before tuning
    (``repro.sharding.local``), and cache entries carry the mesh
    signature.  ``axis_specs`` (``{op: (m_axes, n_axes, k_axes)}`` or
    ``{op: {"axes": ..., "backend": ...}}`` to also pin a per-op backend)
    overrides how the triple shards — innermost set mapping wins
    wholesale, it is not merged key-by-key.  ``quant`` switches the GEMM
    family to quantized execution (a ``QuantConfig``, dict, or shorthand
    like ``"int8"``/``"fp8"``; see ``repro.core.quantize``).  ``tracer``
    (a ``repro.obs.Tracer``) scopes trace recording to this context —
    dispatch resolutions, autotune measurements, and any ``obs.span``
    entered inside it record there.

    Note: a jit-compiled function captures whatever the context resolves to
    at *trace* time; entering a different context later does not retrace
    already-compiled code.
    """
    if backend is not None:
        _check_backend_name(backend)
    if blocks_policy is not None and not callable(blocks_policy):
        _policy_fn(blocks_policy)  # validates; lazily registers "autotune"
    if axis_specs is not None:
        unknown = set(axis_specs) - set(BLOCK_SCHEMAS)
        if unknown:
            raise ValueError(
                f"axis_specs for unknown op(s) {sorted(unknown)}; known: "
                f"{', '.join(sorted(BLOCK_SCHEMAS))}")
        for op_name, spec in axis_specs.items():
            _check_axis_spec(op_name, spec)
    if quant is not None:
        # Normalized (and therefore validated) at entry, so every reader
        # downstream sees a QuantConfig, never a raw spec.
        from repro.core.quantize import as_quant_config
        quant = as_quant_config(quant)
    ctx = ExecutionContext(backend=backend, blocks_policy=blocks_policy,
                           accum_dtype=accum_dtype, interpret=interpret,
                           mesh=mesh, axis_specs=axis_specs, quant=quant,
                           tracer=tracer)
    token = _STACK.set(_STACK.get() + (ctx,))
    obs_token = _obs._activate(tracer) if tracer is not None else None
    try:
        yield ctx
    finally:
        if obs_token is not None:
            _obs._deactivate(obs_token)
        _STACK.reset(token)


def current_context() -> ExecutionContext:
    """The merged view of the active context stack (innermost wins)."""
    backend = _DEPRECATED_GLOBAL_BACKEND
    blocks_policy = accum_dtype = interpret = mesh = axis_specs = None
    quant = tracer = None
    for ctx in _STACK.get():
        backend = ctx.backend if ctx.backend is not None else backend
        blocks_policy = (ctx.blocks_policy if ctx.blocks_policy is not None
                         else blocks_policy)
        accum_dtype = (ctx.accum_dtype if ctx.accum_dtype is not None
                       else accum_dtype)
        interpret = ctx.interpret if ctx.interpret is not None else interpret
        mesh = ctx.mesh if ctx.mesh is not None else mesh
        axis_specs = (ctx.axis_specs if ctx.axis_specs is not None
                      else axis_specs)
        quant = ctx.quant if ctx.quant is not None else quant
        tracer = ctx.tracer if ctx.tracer is not None else tracer
    return ExecutionContext(backend=backend, blocks_policy=blocks_policy,
                            accum_dtype=accum_dtype, interpret=interpret,
                            mesh=mesh, axis_specs=axis_specs, quant=quant,
                            tracer=tracer)


# --------------------------------------------------------------------------
# resolution
# --------------------------------------------------------------------------

def _hardware_default() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _env_backend() -> str | None:
    return os.environ.get(ENV_VAR) or os.environ.get(LEGACY_ENV_VAR) or None


def _record_dispatch(op: str, backend: str,
                     fallback_from: str | None = None) -> None:
    """Telemetry + tracing for one resolution: the always-on counters
    behind ``repro_op_dispatch_total`` / ``repro_backend_fallbacks_total``,
    plus an instant event when a tracer is active."""
    _telemetry.TELEMETRY.record_dispatch(op, backend,
                                         fallback_from=fallback_from)
    tr = _obs.current_tracer()
    if tr is not None:
        if fallback_from is not None:
            tr.event("dispatch", op=op, backend=backend,
                     fallback_from=fallback_from)
        else:
            tr.event("dispatch", op=op, backend=backend)


def resolve(op: str, backend: str | None = None) -> str:
    """Resolve the backend name for ``op`` under the precedence order:
    explicit call arg > per-op ``axis_specs`` backend pin > context
    backend > env var > hardware default.  Only the explicit tier refuses
    to fall back on unavailability."""
    impls = _impls(op)
    explicit = backend is not None
    ctx = current_context()
    pinned = None
    if not explicit and ctx.axis_specs is not None:
        pinned = _axis_spec_backend(ctx.axis_specs.get(op))
    name = (backend or pinned or ctx.backend or _env_backend()
            or _hardware_default())
    if name not in impls:
        raise ValueError(
            f"unknown backend {name!r} for op {op!r}; registered backends: "
            f"{', '.join(sorted(impls))}")
    if impls[name].available():
        _record_dispatch(op, name)
        return name
    if explicit:
        raise RuntimeError(
            f"backend {name!r} for op {op!r} is not available on platform "
            f"{jax.default_backend()!r} (explicitly requested, so not "
            f"falling back; available: {', '.join(available_backends(op))})")
    for cand in sorted(impls.values(), key=lambda b: (-b.priority, b.name)):
        if cand.available():
            _record_dispatch(op, cand.name, fallback_from=name)
            return cand.name
    raise RuntimeError(
        f"no available backend for op {op!r} on platform "
        f"{jax.default_backend()!r}; registered: "
        f"{', '.join(sorted(impls))}")


def get_impl(op: str, backend: str | None = None) -> Callable:
    """Resolve and return the implementation callable for ``op``."""
    return _impls(op)[resolve(op, backend)].fn


def call(op: str, *args, backend: str | None = None, **kwargs):
    """One-shot dispatch: resolve ``op`` and invoke its implementation."""
    return get_impl(op, backend)(*args, **kwargs)


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Pallas interpret mode: call arg > context > (not on TPU)."""
    if interpret is not None:
        return bool(interpret)
    ctx = current_context().interpret
    if ctx is not None:
        return bool(ctx)
    return jax.default_backend() != "tpu"


def resolve_accum_dtype(accum_dtype=None):
    """Accumulation dtype for the GEMM family: call arg > context > fp32.

    Orthogonal to ``quant``: with both set, quantized GEMMs use the
    dtype-implied accumulator (int32 for int8, fp32 for fp8) and
    ``accum_dtype`` governs the remaining full-precision ops."""
    if accum_dtype is not None:
        return jnp.dtype(accum_dtype)
    ctx = current_context().accum_dtype
    return jnp.dtype(ctx) if ctx is not None else jnp.dtype(jnp.float32)


def resolve_quant(quant=None):
    """The active ``QuantConfig``: call arg > context > None (full
    precision).  Accepts any spec ``repro.core.quantize.as_quant_config``
    does."""
    if quant is not None:
        from repro.core.quantize import as_quant_config
        return as_quant_config(quant)
    return current_context().quant


# --------------------------------------------------------------------------
# shape-keyed block tuning cache
# --------------------------------------------------------------------------

BLOCK_POLICIES: dict[str, Callable] = {}
_TUNING_CACHE: dict[tuple, Any] = {}
_TUNING_LOCK = threading.Lock()
_ENV_CACHE_LOADED = False
_CACHE_LOAD_ERRORS = 0    # corrupt/unreadable cache files seen this process


def register_block_policy(name: str, fn: Callable) -> None:
    """Register a block-selection policy.

    ``fn(op, m, n, k, dtype, backend) -> block tuple`` (the op's own type:
    ``Blocks`` / ``ConvBlocks`` / ``AttnBlocks``).  Results are memoized in
    the tuning cache, so an expensive search-based autotuner pays its cost
    once per (op, shape, dtype, backend).
    """
    BLOCK_POLICIES[name] = fn


register_block_policy(
    "heuristic",
    lambda op, m, n, k, dtype, backend, geometry=None, quant=None:
        default_blocks(op, m, n, k, dtype, geometry=geometry))


def _accepts_kwarg(fn: Callable, name: str) -> bool:
    """Whether a policy callable takes the optional ``name=`` kwarg.

    Pre-geometry (and pre-quant) policies keep their 6-arg signature
    working: they are simply called without the newer kwargs (and tune
    the geometry-agnostic, full-precision proxy)."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # pragma: no cover - builtins etc.
        return False
    return name in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


def _policy_fn(name: str) -> Callable:
    fn = BLOCK_POLICIES.get(name)
    if fn is not None:
        return fn
    if name == "autotune":
        # Registered lazily so importing dispatch never pays for the
        # autotuner module (which imports every kernel package).
        import repro.core.autotune  # noqa: F401
        return BLOCK_POLICIES[name]
    raise ValueError(
        f"unknown blocks_policy {name!r}; registered policies: "
        f"{', '.join(sorted(BLOCK_POLICIES))}")


def resolve_blocks(op: str, m: int, n: int, k: int, dtype, *, backend: str,
                   blocks=None, geometry=None, quant=None):
    """Block geometry for ``op``: call arg > context policy > heuristic.

    ``(m, n, k)`` is the op's canonical tuning triple (GEMM ``m/n/k``, conv
    ``q/c/k``, attention fwd/bwd ``tq/tk/d`` — see
    ``blocking.BLOCK_SCHEMAS``).  ``geometry`` carries op-specific
    non-canonical dims (conv2d's ``ConvGeometry(stride, r, s)``) so the
    policy can prune and measure the true working set; it joins the cache
    key, so the same (m, n, k) with different geometry tunes separately.

    Under an active ``use(mesh=...)`` the triple is first mapped to the
    per-device **local** problem (``repro.sharding.local.local_problem``,
    honoring ``use(axis_specs=...)`` overrides), so the policy — and the
    measured autotuner's proxy — sees the shard each device actually runs,
    and the cache key gains the mesh signature.

    ``quant`` (a ``QuantConfig`` or tag string) marks a quantized problem:
    its tag joins the cache key — the same (m, n, k) tunes separately per
    quant config, since storage dtypes change the candidate grid's VMEM
    feasibility — and quant-aware policies receive it as a ``quant=``
    kwarg so the measured proxy runs the quantized kernel.  Callers on
    the quant path pass the *storage* dtype (int8/fp8) as ``dtype``, so
    candidate enumeration adapts its sublane/itemsize maths for free.

    Policy results are memoized keyed (op, backend, local shapes, dtype,
    policy, geometry, mesh signature, quant tag); an explicit ``blocks``
    argument bypasses the cache entirely.  When ``REPRO_TUNING_CACHE``
    names a file, the cache is loaded from it on first use and written
    through on every new entry.
    """
    if blocks is not None:
        return blocks
    _maybe_load_env_cache()
    ctx = current_context()
    policy = ctx.blocks_policy or "heuristic"
    if callable(policy):
        # keyed on the callable itself so ad-hoc autotuners are memoized
        # too (a fresh lambda per call site gets a fresh entry)
        policy_fn, policy_key = policy, policy
    else:
        policy_fn, policy_key = _policy_fn(policy), policy
    mesh_sig = None
    if ctx.mesh is not None:
        # Lazy import: sharding.local is tiny but dispatch must stay
        # importable before the sharding package (kernel registration).
        from repro.sharding import local as _local
        m, n, k = _local.local_problem(op, m, n, k, ctx.mesh,
                                       axis_specs=ctx.axis_specs)
        mesh_sig = _local.mesh_signature(ctx.mesh)
    quant_tag = quant if (quant is None or isinstance(quant, str)) \
        else quant.tag()
    key = (op, backend, int(m), int(n), int(k), jnp.dtype(dtype).name,
           policy_key, geometry, mesh_sig, quant_tag)
    hit = _TUNING_CACHE.get(key)
    if hit is not None:
        source = "cache-hit"
    else:
        kwargs = {}
        if geometry is not None and _accepts_kwarg(policy_fn, "geometry"):
            kwargs["geometry"] = geometry
        if quant is not None and _accepts_kwarg(policy_fn, "quant"):
            kwargs["quant"] = quant
        auto_before = dict(_telemetry.TELEMETRY.autotune)
        hit = policy_fn(op, m, n, k, dtype, backend, **kwargs)
        source = _blocks_source(policy_key, auto_before)
        with _TUNING_LOCK:
            _TUNING_CACHE[key] = hit
        env_path = os.environ.get(TUNING_CACHE_ENV)
        if env_path and isinstance(policy_key, str):
            try:
                save_cache(env_path)
            except OSError as exc:
                # write-through is best-effort: an unwritable cache path
                # must not fail the resolve that produced the blocks
                warnings.warn(f"could not write tuning cache to "
                              f"{env_path!r}: {exc}")
    _telemetry.TELEMETRY.record_blocks(source)
    tr = _obs.current_tracer()
    if tr is not None:
        _trace_blocks(tr, op, backend, m, n, k, dtype, geometry, mesh_sig,
                      quant_tag, source, hit)
    return hit


def _blocks_source(policy_key, auto_before: dict) -> str:
    """Where a fresh blocks pick came from: the policy name, refined for
    ``autotune`` by whether a measured search (or a neighbor seed)
    actually ran — the autotuner returns the plain heuristic untouched
    off the pallas path."""
    if not isinstance(policy_key, str):
        return "custom"
    if policy_key == "autotune":
        after = _telemetry.TELEMETRY.autotune
        if after["seeded"] > auto_before["seeded"]:
            return "autotune-seeded"
        if after["searches"] > auto_before["searches"]:
            return "autotune-measured"
        return "heuristic"
    return policy_key


def _trace_blocks(tr, op, backend, m, n, k, dtype, geometry, mesh_sig,
                  quant_tag, source, blocks) -> None:
    """One ``resolve_blocks`` instant event carrying the full dispatch
    decision (op, backend, shape, blocks source, quant, mesh) plus the
    FLOP/byte cost of the problem, and a blocks-source annotation on the
    enclosing span (if any)."""
    from repro.obs import flops as _flops
    ev = {"op": op, "backend": backend, "m": int(m), "n": int(n),
          "k": int(k), "dtype": jnp.dtype(dtype).name, "source": source,
          "blocks": str(blocks)}
    if quant_tag is not None:
        ev["quant"] = quant_tag
    if mesh_sig is not None:
        ev["mesh"] = str(mesh_sig)
    try:
        cost = _flops.op_cost(op, m, n, k, dtype, geometry=geometry,
                              quant=quant_tag)
    except ValueError:
        cost = None
    if cost is not None:
        ev["flops"] = cost.flops
        ev["bytes"] = cost.bytes
        ev["intensity"] = round(cost.intensity, 3)
    tr.event("resolve_blocks", **ev)
    tr.annotate(**{f"blocks_source.{op}": source})


def tuning_cache_info() -> dict[tuple, Any]:
    return dict(_TUNING_CACHE)


def cache_load_errors() -> int:
    """How many corrupt/unreadable tuning-cache loads this process has
    swallowed (or raised, when strict).  Surfaced by the autotune CLI so
    a silently-ignored bad cache file is still visible to operators."""
    return _CACHE_LOAD_ERRORS


def clear_tuning_cache() -> None:
    global _ENV_CACHE_LOADED, _CACHE_LOAD_ERRORS
    _TUNING_CACHE.clear()
    _ENV_CACHE_LOADED = False
    _CACHE_LOAD_ERRORS = 0


def _maybe_load_env_cache() -> None:
    global _ENV_CACHE_LOADED
    if _ENV_CACHE_LOADED:
        return
    _ENV_CACHE_LOADED = True  # one attempt per process (or per cache clear)
    path = os.environ.get(TUNING_CACHE_ENV)
    if path and os.path.exists(path):
        # non-strict: a corrupt/truncated/unknown-schema cache file must
        # degrade to heuristic blocks, never fail the first resolve
        load_cache(path, strict=False)


def _entry_key(e: dict) -> tuple:
    geom = e.get("geometry")
    mesh = e.get("mesh")
    return (e["op"], e["backend"], int(e["m"]), int(e["n"]), int(e["k"]),
            e["dtype"], e["policy"], e.get("platform"),
            tuple(sorted(geom.items())) if geom else None,
            tuple(mesh) if mesh else None, e.get("quant"))


def save_cache(path: str | None = None) -> int:
    """Persist the tuning cache as JSON; returns the number of entries.

    Entries keyed by an ad-hoc callable policy are skipped (a function
    identity does not survive the process); named-policy entries round-trip.
    Each entry is stamped with the measuring platform
    (``jax.default_backend()``) so CPU interpret-mode timings never dictate
    TPU tiles.  Entries already in the file but not in memory (e.g. written
    by a concurrent process sharing the file, or measured on another
    platform) are preserved, not clobbered.
    """
    path = path or os.environ.get(TUNING_CACHE_ENV)
    if not path:
        raise ValueError(
            f"no path given and {TUNING_CACHE_ENV} is not set")
    platform = jax.default_backend()
    with _TUNING_LOCK:
        entries = [
            {"op": op, "backend": backend, "m": m, "n": n, "k": k,
             "dtype": dtype, "policy": policy, "platform": platform,
             "geometry": geometry.asdict() if geometry is not None else None,
             "mesh": list(mesh_sig) if mesh_sig is not None else None,
             "quant": quant_tag,
             "blocks": blocks_to_dict(blk)}
            for (op, backend, m, n, k, dtype, policy, geometry, mesh_sig,
                 quant_tag), blk in _TUNING_CACHE.items()
            if isinstance(policy, str)
        ]
    if os.path.exists(path):
        try:
            with open(path) as f:
                prior = json.load(f).get("entries", [])
        except (OSError, ValueError, AttributeError):
            prior = []   # unreadable/corrupt file: overwrite, don't merge
        if not isinstance(prior, list):
            prior = []   # unknown schema (entries not a list)
        seen = {_entry_key(e) for e in entries}
        for e in prior:
            try:
                if _entry_key(e) not in seen:
                    entries.append(e)
            except (KeyError, TypeError, AttributeError):
                continue   # junk prior entry: drop it from the rewrite
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump({"version": 1, "entries": entries}, f, indent=1)
    os.replace(tmp, path)  # atomic: concurrent readers see old or new
    return len(entries)


def load_cache(path: str | None = None, *, strict: bool = True) -> int:
    """Merge a JSON tuning cache into the in-memory one; returns the number
    of entries actually inserted.  In-memory entries win on key collision
    (they are at least as fresh as the file), and entries measured on a
    different platform are ignored (their timings don't transfer).

    A corrupt, truncated, or unknown-schema file raises when ``strict``
    (the explicit-call default) and otherwise warns and returns 0 — the
    resolver falls back to heuristic blocks.  The automatic
    ``REPRO_TUNING_CACHE`` load is non-strict: a bad cache file must
    degrade performance, not availability.  Either way the failure is
    counted in :func:`cache_load_errors`.
    """
    path = path or os.environ.get(TUNING_CACHE_ENV)
    if not path:
        raise ValueError(
            f"no path given and {TUNING_CACHE_ENV} is not set")
    global _CACHE_LOAD_ERRORS
    try:
        with open(path) as f:
            data = json.load(f)
        entries = data.get("entries", ())
        if not isinstance(entries, (list, tuple)):
            raise ValueError(
                f"unknown tuning-cache schema: 'entries' is "
                f"{type(entries).__name__}, expected a list")
    except (OSError, ValueError, AttributeError) as exc:
        # OSError: unreadable; ValueError: truncated / not JSON / bad
        # schema; AttributeError: top level is not an object
        with _TUNING_LOCK:
            _CACHE_LOAD_ERRORS += 1
        if strict:
            raise
        warnings.warn(
            f"ignoring corrupt tuning cache {path!r} "
            f"({type(exc).__name__}: {exc}); falling back to heuristic "
            f"blocks")
        return 0
    platform = jax.default_backend()
    count = 0
    with _TUNING_LOCK:
        for e in entries:
            try:
                if e.get("platform", platform) != platform:
                    continue
                mesh = e.get("mesh")
                # .get: files written before the quant field (or by older
                # repo versions) load as full-precision entries.
                key = (e["op"], e["backend"], int(e["m"]), int(e["n"]),
                       int(e["k"]), e["dtype"], e["policy"],
                       geometry_from_dict(e.get("geometry")),
                       tuple(str(a) for a in mesh) if mesh else None,
                       e.get("quant"))
                blk = blocks_from_dict(e["blocks"])
            except (KeyError, TypeError, ValueError, AttributeError):
                # Entry written by another repo version (unknown block or
                # geometry kind, or junk that is not an object): skip it
                # rather than fail the whole load; save_cache preserves
                # recognizable prior entries in the file untouched.
                continue
            if key not in _TUNING_CACHE:
                _TUNING_CACHE[key] = blk
                count += 1
    return count


# --------------------------------------------------------------------------
# deprecated shims (pre-dispatch API)
# --------------------------------------------------------------------------

def set_default_backend(name: str | None) -> None:
    """Deprecated: use ``with repro.use(backend=...)`` instead."""
    warnings.warn(
        "set_default_backend is deprecated; use "
        "`with repro.use(backend=...)` instead",
        DeprecationWarning, stacklevel=2)
    if name is not None:
        _check_backend_name(name)
    global _DEPRECATED_GLOBAL_BACKEND
    _DEPRECATED_GLOBAL_BACKEND = name


def resolve_backend(backend: str | None = None, op: str = "brgemm") -> str:
    """Deprecated: use ``repro.core.dispatch.resolve(op, backend)``."""
    warnings.warn(
        "resolve_backend is deprecated; use "
        "repro.core.dispatch.resolve(op, backend) instead",
        DeprecationWarning, stacklevel=2)
    return resolve(op, backend)
