"""Measured block-policy: search the loop tilings around the one kernel.

The paper reduces DL library development to "mere (potentially automatic)
tuning of loops around this sole optimized kernel"; PolyDL/PolyScientist
(arXiv 2006.02230, 2002.02145) show that a measured search over those
tilings is where the remaining performance lives.  This module is that
search: ``repro.use(blocks_policy="autotune")`` makes every op resolve its
block tuple by

  1. enumerating the pruned, VMEM-feasible candidate grid from
     ``core.blocking.candidate_blocks`` (deterministic order; the static
     heuristic pick is always measured first, so autotuning never loses to
     it on the measured problem),
  2. timing each candidate with a compile-and-run harness on a synthetic
     proxy problem of the op's canonical (m, n, k) shape — interpret-safe
     on CPU, compiled via Mosaic on TPU,
  3. memoizing the winner in the dispatch tuning cache, which persists to
     JSON via ``REPRO_TUNING_CACHE`` so the search cost is paid once per
     machine.

``python -m repro.core.autotune --op matmul --shape 32 32 32`` runs a
one-shot search and reports how many candidates were actually measured —
zero on a warm persisted cache (this is what CI asserts).
"""
from __future__ import annotations

import argparse
import functools
import math
import os
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import blocking, dispatch
from repro.obs.telemetry import TELEMETRY

ENV_MAX_CANDIDATES = "REPRO_AUTOTUNE_CANDIDATES"
ENV_REPEATS = "REPRO_AUTOTUNE_REPEATS"
DEFAULT_MAX_CANDIDATES = 8
DEFAULT_REPEATS = 3


def _stat(name: str) -> property:
    return property(
        lambda self: TELEMETRY.autotune[name],
        lambda self, value: TELEMETRY.set_autotune(name, value))


class SearchStats:
    """Process-wide counters; lets tests and CI assert cache behavior.

    A property proxy over the unified dispatch telemetry
    (``repro.obs.telemetry.TELEMETRY.autotune``): the CLI's cache-hit
    report, these attributes, and the Prometheus
    ``repro_autotune_*_total`` families all read the same store, so
    they can never drift apart.
    """
    searches = _stat("searches")
    measured = _stat("measured")
    failed = _stat("failed")
    seeded = _stat("seeded")   # grids seeded from a tuned neighbor

    def snapshot(self) -> dict:
        return dict(TELEMETRY.autotune)


STATS = SearchStats()


# --------------------------------------------------------------------------
# proxy problems: one runner per op, same blocked-GEMM inner loop
# --------------------------------------------------------------------------

def proxy_runner(op: str, m: int, n: int, k: int, dtype, blocks,
                 interpret: bool, geometry=None,
                 quant=None) -> Callable[[], object]:
    """A zero-arg callable executing ``op`` once with ``blocks``.

    Conv and attention are measured on a proxy with the same canonical
    (m, n, k).  With a ``ConvGeometry`` the conv proxy is a true
    (R, S, stride) convolution producing q output pixels per row — the
    exact panel walk the real kernel takes — falling back to the 1x1 /
    stride-1 proxy otherwise.  ``flash_attention_bwd`` runs the forward
    once outside the timed callable (residuals are inputs, not work) and
    measures only the fused backward kernels.

    With a ``quant`` config the GEMM proxies run the *quantized* kernels
    on unit-scale quantized operands — the candidate being timed is the
    tile the quantized op will actually execute (int8 panels stream half
    the bytes of bf16, so the winner can differ).
    """
    if quant is not None and op in ("matmul", "brgemm", "batched_matmul"):
        from repro.core.quantize import as_quant_config
        from repro.kernels.brgemm import quant_kernel as QK
        qcfg = as_quant_config(quant)
        wdt, adt = qcfg.w_jnp, qcfg.a_jnp
        ones = functools.partial(jnp.ones, dtype=jnp.float32)
        if op == "matmul":
            xq = jnp.ones((m, k), adt)
            wq = jnp.ones((k, n), wdt)
            return lambda: QK.matmul_q_pallas(
                xq, wq, ones((m,)), ones((n,)), blocks=blocks,
                interpret=interpret)
        aq = jnp.ones((2, m, k), adt)
        bq = jnp.ones((2, k, n), wdt)
        if op == "brgemm":
            return lambda: QK.brgemm_q_pallas(
                aq, bq, ones((m,)), ones((n,)), blocks=blocks,
                interpret=interpret)
        return lambda: QK.batched_matmul_q_pallas(
            aq, bq, ones((2, m)), ones((2, n)), blocks=blocks,
            interpret=interpret)
    if op in ("matmul", "brgemm", "batched_matmul"):
        from repro.kernels.brgemm import kernel as K
        if op == "matmul":
            x = jnp.ones((m, k), dtype)
            w = jnp.ones((k, n), dtype)
            return lambda: K.matmul_pallas(
                x, w, blocks=blocks, interpret=interpret)
        a = jnp.ones((2, m, k), dtype)
        b = jnp.ones((2, k, n), dtype)
        if op == "brgemm":
            return lambda: K.brgemm_stacked_pallas(
                a, b, blocks=blocks, interpret=interpret)
        return lambda: K.batched_matmul_pallas(
            a, b, blocks=blocks, interpret=interpret)
    if op == "conv2d":
        from repro.kernels.conv2d.kernel import conv2d_pallas
        q, c, kk = m, n, k
        stride, r_, s_ = ((geometry.stride, geometry.r, geometry.s)
                         if geometry is not None else (1, 1, 1))
        x = jnp.ones((1, r_, (q - 1) * stride + s_, c), dtype)
        w = jnp.ones((r_, s_, c, kk), dtype)
        return lambda: conv2d_pallas(x, w, stride=stride, blocks=blocks,
                                     interpret=interpret)
    if op == "flash_attention":
        from repro.kernels.flash_attention.kernel import (
            flash_attention_pallas,
        )
        tq, tk, d = m, n, k
        qq = jnp.ones((1, 1, tq, d), dtype)
        kv = jnp.ones((1, 1, tk, d), dtype)
        return lambda: flash_attention_pallas(
            qq, kv, kv, causal=False, blocks=blocks, interpret=interpret)
    if op == "flash_attention_bwd":
        from repro.kernels.flash_attention.bwd import (
            flash_attention_bwd_pallas,
        )
        from repro.kernels.flash_attention.kernel import (
            flash_attention_pallas,
        )
        tq, tk, d = m, n, k
        qq = jnp.ones((1, 1, tq, d), dtype)
        kv = jnp.ones((1, 1, tk, d), dtype)
        y, lse = flash_attention_pallas(
            qq, kv, kv, causal=False,
            blocks=blocking.default_blocks("flash_attention", tq, tk, d,
                                           dtype),
            interpret=interpret, return_residuals=True)
        dy = jnp.ones_like(y)
        return lambda: flash_attention_bwd_pallas(
            qq, kv, kv, y, lse, dy, causal=False, blocks=blocks,
            interpret=interpret)
    raise ValueError(f"no autotune runner for op {op!r}")


def measure_candidate(op: str, m: int, n: int, k: int, dtype, backend: str,
                      blocks, repeats: int | None = None,
                      geometry=None, quant=None) -> float:
    """Best-of-``repeats`` wall time (seconds) for one candidate tile.

    The first call compiles (or builds the interpreter); only subsequent
    runs are timed, so compile jitter never decides the winner.
    """
    del backend  # the runner is the pallas kernel; xla never measures
    repeats = repeats if repeats is not None else int(
        os.environ.get(ENV_REPEATS, DEFAULT_REPEATS))
    fn = proxy_runner(op, m, n, k, dtype, blocks,
                      dispatch.resolve_interpret(), geometry=geometry,
                      quant=quant)
    jax.block_until_ready(fn())  # warmup / compile
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def nearest_tuned_neighbor(op: str, m: int, n: int, k: int, dtype,
                           backend: str):
    """The winning tile of the closest already-autotuned problem.

    Cross-shape transfer: before paying a full sweep for a new (m, n, k),
    look at what the measured search already chose for the *nearest* tuned
    shape of the same (op, backend, dtype) — under sharding the same
    global problem re-tunes per local shard shape, and neighbors' winners
    are strong priors.  Distance is the L1 log2 gap over the canonical
    triple; only entries tuned by the named ``autotune`` policy count
    (heuristic entries carry no measurement).  A same-triple entry under a
    different cache key (other mesh signature / geometry) is a distance-0
    neighbor — the best seed there is.  Returns ``None`` when no neighbor
    exists.
    """
    dname = jnp.dtype(dtype).name
    best, best_d = None, float("inf")
    for key, blk in dispatch.tuning_cache_info().items():
        kop, kbackend, km, kn, kk, kdtype, kpolicy = key[:7]
        if (kop, kbackend, kdtype) != (op, backend, dname):
            continue
        if kpolicy != "autotune":
            continue
        d = sum(abs(math.log2(max(a, 1)) - math.log2(max(b, 1)))
                for a, b in ((m, km), (n, kn), (k, kk)))
        if d < best_d:
            best, best_d = blk, d
    return best


def _prune(candidates: Sequence, heuristic, max_candidates: int) -> list:
    """Deterministic subset: the heuristic pick first, then an evenly
    spaced sample of the remaining grid."""
    rest = [c for c in candidates if c != heuristic]
    keep = max(0, max_candidates - 1)
    if len(rest) > keep:
        if keep == 0:
            rest = []
        else:
            step = len(rest) / keep
            rest = [rest[int(i * step)] for i in range(keep)]
    return [heuristic] + rest


def autotune_blocks(op: str, m: int, n: int, k: int, dtype, backend: str, *,
                    geometry=None, quant=None,
                    max_candidates: int | None = None,
                    repeats: int | None = None,
                    timer: Callable | None = None):
    """Measured search over the candidate grid; returns the fastest tile.

    ``timer(op, m, n, k, dtype, backend, blocks) -> seconds`` is injectable
    for tests; the default is :func:`measure_candidate` on the
    geometry-true proxy.  Candidate order is deterministic, ties keep the
    earlier candidate, and a candidate whose measurement raises is skipped
    (counted in ``STATS.failed``) — if every candidate fails, the
    heuristic pick is returned.

    The grid is *seeded* from the nearest already-tuned neighbor (same
    op/backend/dtype, closest shape): when that winner is feasible for
    this problem it is measured first, ahead of the heuristic, so tie
    breaks favor it and a truncated sweep still covers the best prior.
    Note ``resolve_blocks`` hands this function the per-device *local*
    problem under a mesh context, so sharded re-tunes seed from their
    unsharded (or differently-sharded) neighbors automatically.
    """
    heuristic = blocking.default_blocks(op, m, n, k, dtype,
                                        geometry=geometry)
    if backend != "pallas":
        # Tiling is backend-internal off the pallas path; nothing to measure.
        return heuristic
    max_candidates = max_candidates if max_candidates is not None else int(
        os.environ.get(ENV_MAX_CANDIDATES, DEFAULT_MAX_CANDIDATES))
    if timer is None:
        timer = functools.partial(measure_candidate, repeats=repeats,
                                  geometry=geometry, quant=quant)
    grid = blocking.candidate_blocks(op, m, n, k, dtype, geometry=geometry)
    candidates = _prune(grid, heuristic, max_candidates)
    seed = nearest_tuned_neighbor(op, m, n, k, dtype, backend)
    if seed is not None and seed in grid:  # feasible for *this* working set
        # prepend, then re-trim: the seed displaces the tail candidate so
        # the configured measurement budget is never exceeded
        candidates = ([seed] + [c for c in candidates if c != seed])
        candidates = candidates[:max(1, max_candidates)]
        STATS.seeded += 1
    STATS.searches += 1
    tr = obs.current_tracer()
    cost = obs.op_cost(op, m, n, k, dtype, geometry=geometry,
                       quant=quant) if tr is not None else None
    search_span = tr.span(
        "autotune.search", op=op, m=int(m), n=int(n), k=int(k),
        dtype=jnp.dtype(dtype).name, candidates=len(candidates),
        seeded=seed is not None and seed in grid,
    ) if tr is not None else obs.NULL_SPAN
    best, best_t = heuristic, float("inf")
    with search_span:
        for cand in candidates:
            try:
                if tr is not None:
                    with tr.span("autotune.measure", op=op,
                                 blocks=str(cand)) as sp:
                        t = timer(op, m, n, k, dtype, backend, cand)
                        sp.set(seconds=t, flops=cost.flops,
                               gflops_per_s=round(cost.flops / t / 1e9, 3)
                               if t > 0 else None)
                else:
                    t = timer(op, m, n, k, dtype, backend, cand)
                STATS.measured += 1
            except Exception:
                STATS.failed += 1
                continue
            if t < best_t:
                best, best_t = cand, t
        search_span.set(best=str(best), best_seconds=best_t
                        if best_t < float("inf") else None)
    return best


dispatch.register_block_policy("autotune", autotune_blocks)


# --------------------------------------------------------------------------
# CLI smoke: one-shot search, reports cache warmth (used by CI)
# --------------------------------------------------------------------------

def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        description="one-shot autotune search; measured=0 means the "
                    "persisted tuning cache satisfied the query")
    ap.add_argument("--op", default="matmul",
                    choices=sorted(blocking.BLOCK_SCHEMAS))
    ap.add_argument("--shape", nargs=3, type=int, default=(32, 32, 32),
                    metavar=("M", "N", "K"),
                    help="the op's canonical tuning triple")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--quant", default=None,
                    help="quant spec ('int8', 'fp8', or a QuantConfig "
                         "tag); tunes the quantized kernel variant")
    ap.add_argument("--candidates", type=int, default=None,
                    help="cap the measured candidate count")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)

    m, n, k = args.shape
    qcfg = None
    dtype = jnp.dtype(args.dtype)
    if args.quant is not None:
        from repro.core.quantize import as_quant_config
        qcfg = as_quant_config(args.quant)
        # the quantized op tunes on (and keys its cache by) storage dtype
        dtype = qcfg.w_jnp
    # Env (not an ad-hoc callable) so the search stays under the *named*
    # "autotune" policy — only named-policy entries persist to JSON.
    if args.candidates is not None:
        os.environ[ENV_MAX_CANDIDATES] = str(args.candidates)
    if args.repeats is not None:
        os.environ[ENV_REPEATS] = str(args.repeats)
    before = STATS.snapshot()
    with dispatch.use(blocks_policy="autotune"):
        blocks = dispatch.resolve_blocks(
            args.op, m, n, k, dtype, backend="pallas", quant=qcfg)
    measured = STATS.measured - before["measured"]
    failed = STATS.failed - before["failed"]
    # Hit/miss by whether a search ran at all — measured==0 alone would
    # also be true for a cold search whose every candidate failed.
    hit = STATS.searches == before["searches"]
    qfield = f" quant={qcfg.tag()}" if qcfg is not None else ""
    print(f"autotune op={args.op} shape={m}x{n}x{k} dtype={dtype.name}"
          f"{qfield} selected={blocks} failed={failed} measured={measured} "
          f"cache={'hit' if hit else 'miss'} "
          f"cache_errors={dispatch.cache_load_errors()}")


if __name__ == "__main__":
    main()
