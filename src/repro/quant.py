"""Public quantization API: ``repro.quant``.

One import surface for quantized execution:

    import repro
    from repro import quant

    qparams = quant.calibrate_params(params, "int8")   # offline weights
    with repro.use(quant="int8"):                      # dynamic activations
        logits = model.apply(qparams, batch)           # zero call-site changes

See ``repro.core.quantize`` for the config/calibration machinery and
``repro.kernels.brgemm.quant`` for the quantized building-block kernels.
"""
from repro.core.quantize import (  # noqa: F401
    QuantConfig,
    QuantizedTensor,
    as_quant_config,
    calibrate_params,
    default_calibrate_predicate,
    dequantize,
    quantize,
    quantize_weight,
)
