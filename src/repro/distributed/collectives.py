"""Distributed-optimization helpers: gradient compression with error
feedback, and collective utilities.

Under pjit, gradients are already reduce-scattered by XLA; compressing the
fp32 gradient tree to int8 (per-tensor absmax scaling) before the optimizer
models the wire-format compression used at 1000+-node scale.  Error feedback
(residual carried in the caller's state) keeps convergence — exposed here as
pure functions so the train step can thread the residual.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_grads(grads, *, kind: str = "int8"):
    """Returns (compressed_tree, scales_tree)."""
    if kind == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), None
    if kind == "int8":
        def enc(g):
            g32 = g.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
            q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            return q, scale
        flat, treedef = jax.tree.flatten(grads)
        enc_out = [enc(g) for g in flat]
        q = jax.tree.unflatten(treedef, [e[0] for e in enc_out])
        s = jax.tree.unflatten(treedef, [e[1] for e in enc_out])
        return q, s
    raise ValueError(kind)


def decompress_grads(grads, scales, *, kind: str = "int8"):
    if kind == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if kind == "int8":
        return jax.tree.map(
            lambda q, s: q.astype(jnp.float32) * s, grads, scales)
    raise ValueError(kind)


def compress_with_error_feedback(grads, residual, *, kind: str = "int8"):
    """Error-feedback compression: q = C(g + r); r' = (g + r) - q."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                grads)
    biased = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    q, s = compress_grads(biased, kind=kind)
    deq = decompress_grads(q, s, kind=kind)
    new_residual = jax.tree.map(jnp.subtract, biased, deq)
    return deq, new_residual
