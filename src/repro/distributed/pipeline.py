"""GPipe-style pipeline parallelism via shard_map + collective_permute.

The layer stack is split into S stages along a "stage" mesh axis; each
microbatch flows stage -> stage via ``jax.lax.ppermute``.  The schedule is
the classic GPipe loop of (S + M - 1) ticks for M microbatches: stage s
computes microbatch m at tick s + m, so the collective_permute overlaps the
next tick's compute (XLA schedules the permute async).

This substrate is exercised at small scale in tests (CPU, 4 stages); the
production meshes here use DP x TP because all 10 assigned archs fit that
way on 512 chips — PP becomes necessary beyond ~1T dense params (DESIGN.md
Sec. 4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_params, x, layer_fn, *, mesh, n_microbatches: int,
                   axis: str = "stage"):
    """Run ``layer_fn(params, x)`` as a pipeline over mesh axis ``axis``.

    stage_params: pytree whose leaves have a leading stage dim (sharded on
    ``axis``); x: (M, mb, ...) microbatched global input (replicated).
    Returns y with the same shape as x.
    """
    n_stages = mesh.shape[axis]
    m = n_microbatches
    assert x.shape[0] == m

    def stage_body(params, x_local):
        # params: this stage's slice (leading dim 1); x_local: full (M, ...)
        params = jax.tree.map(lambda p: p[0], params)
        stage = jax.lax.axis_index(axis)
        n_ticks = n_stages + m - 1

        buf = jnp.zeros_like(x_local[0])
        outputs = jnp.zeros_like(x_local)

        def tick(carry, t):
            buf, outputs = carry
            mb_idx = t - stage
            # stage 0 ingests microbatch t from the global input
            inp = jnp.where(
                stage == 0,
                x_local[jnp.clip(t, 0, m - 1)],
                buf)
            active = (mb_idx >= 0) & (mb_idx < m)
            y = layer_fn(params, inp)
            y = jnp.where(active, y, buf)
            # last stage writes its finished microbatch
            outputs = jax.lax.cond(
                active & (stage == n_stages - 1),
                lambda o: o.at[jnp.clip(mb_idx, 0, m - 1)].set(y),
                lambda o: o,
                outputs)
            # everyone forwards to the next stage
            buf_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf_next, outputs), None

        (buf, outputs), _ = jax.lax.scan(
            tick, (buf, outputs), jnp.arange(n_ticks))
        # all-reduce across stages so every stage returns the full output
        # (only the last stage holds real data; others hold zeros)
        outputs = jnp.where(stage == n_stages - 1, outputs,
                            jnp.zeros_like(outputs))
        return jax.lax.psum(outputs, axis)

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    f = shard_map(
        stage_body, mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
        check_rep=False)
    return f(stage_params, x)
