"""repro: batch-reduce GEMM as the single DL building block, on TPU/JAX."""
__version__ = "1.0.0"
