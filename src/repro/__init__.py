"""repro: batch-reduce GEMM as the single DL building block, on TPU/JAX.

Execution configuration (backend, block policy, accumulation dtype,
interpret mode, quantization) scopes through the context API:

    import repro
    with repro.use(backend="xla"):
        ...  # every primitive in here routes to the XLA reference path
    with repro.use(quant="int8"):
        ...  # GEMMs run the int8 building block, dequant fused in-epilogue
    with repro.use(tracer=obs.Tracer()):
        ...  # spans + dispatch telemetry recorded for everything in here
"""
from repro import obs  # noqa: F401
from repro.core.blocking import (  # noqa: F401
    AttnBlocks,
    AttnBwdBlocks,
    Blocks,
    ConvBlocks,
    ConvGeometry,
)
from repro.core.dispatch import (  # noqa: F401
    ExecutionContext,
    available_backends,
    backends_for,
    current_context,
    load_cache,
    registered_ops,
    resolve,
    save_cache,
    use,
)
from repro.core.quantize import (  # noqa: F401
    QuantConfig,
    QuantizedTensor,
    calibrate_params,
    quantize_weight,
)

from repro.obs import Tracer  # noqa: F401

__version__ = "1.7.0"
