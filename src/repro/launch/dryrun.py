"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the shape's entry point (train_step / prefill /
decode_step) against abstract inputs (ShapeDtypeStruct — no allocation) with
production shardings, compiles it, and records:

  * memory analysis (bytes per device; proves it fits),
  * cost analysis (FLOPs / bytes for the roofline),
  * collective bytes parsed from the partitioned HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), per collective kind,
  * the per-shard block choices mesh-aware dispatch resolves for the
    cell's hot GEMM/attention problems, next to the global-shape picks
    (``resolved_blocks``: tiles tuned for the 16-row shard a device runs,
    not the 8192-row global problem).

The host-device-count XLA flag is set from :func:`main` (or the
``REPRO_DRYRUN_DEVICES`` env var), **never at import time**, so importing
this module for tests does not clobber ``XLA_FLAGS`` for the whole
process.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all            # every cell, subprocesses
  python -m repro.launch.dryrun --all --multi-pod
  python -m repro.launch.dryrun --blocks-smoke --devices 8   # CI smoke
"""
import argparse
import json
import os
import pathlib
import re
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, applicable
from repro.core import dispatch
from repro.core.blocking import blocks_to_dict
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import api
from repro.sharding import local as shlocal
from repro.sharding import rules
from repro.sharding.annotate import use_rules
from repro.train import optimizer as opt
from repro.train import train_step as ts

ART = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"

DEVICES_ENV = "REPRO_DRYRUN_DEVICES"
DEFAULT_HOST_DEVICES = 512


def force_host_device_count(n: int | None = None) -> None:
    """Arrange for ``n`` fake host devices (default 512, or
    ``REPRO_DRYRUN_DEVICES``).  Must run before jax initializes its
    backends; a pre-existing device-count flag in ``XLA_FLAGS`` wins."""
    n = n or int(os.environ.get(DEVICES_ENV, DEFAULT_HOST_DEVICES))
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the partitioned HLO."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        kind = m.group(1)
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(
            m.group(1))[0]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
        out["_count_" + kind] = out.get("_count_" + kind, 0) + 1
    out["total"] = sum(v for k, v in out.items()
                       if not k.startswith("_count_") and k != "total")
    return out


def cell_problems(cfg, shape):
    """The cell's hot canonical tuning problems, with the axis assignment
    the sharding rules induce on each triple.

    One row per projection family: column-parallel GEMMs (qkv / mlp-up)
    shard rows on the DP axes and the out dim on the model axis;
    row-parallel GEMMs (attn-out / mlp-down) shard the *contraction* dim
    on the model axis instead; attention's triple stays head-sharded
    (local == global).  Returns ``(name, op, (m, n, k), axis_spec)``.
    """
    dp = ("pod", "data")  # shlocal.shard_count skips axes absent from mesh
    model = "model" if cfg.tp else None
    decode = shape.kind == "decode"
    rows = shape.global_batch * (1 if decode else shape.seq_len)
    d, dh = cfg.d_model, cfg.dh
    n_q = cfg.n_heads * dh
    probs = [
        ("attn_qkv", "matmul", (rows, n_q, d), (dp, model, None)),
        ("attn_out", "matmul", (rows, d, n_q), (dp, None, model)),
    ]
    if cfg.d_ff:
        probs += [
            ("mlp_up", "matmul", (rows, cfg.d_ff, d), (dp, model, None)),
            ("mlp_down", "matmul", (rows, d, cfg.d_ff), (dp, None, model)),
        ]
    if cfg.moe_d_ff:
        probs.append(("moe_up", "brgemm",
                      (rows, cfg.moe_d_ff, d), (dp, model, None)))
    tq = 1 if decode else shape.seq_len
    probs.append(("attention", "flash_attention",
                  (tq, shape.seq_len, dh), (None, None, None)))
    return probs


def block_choices(cfg, shape, mesh, dtype=None):
    """Per-shard vs global-shape block resolution for one cell.

    For each hot problem this resolves the tile twice — once against the
    global shape (meshless context) and once through mesh-aware dispatch
    (``use(mesh=..., axis_specs=...)``, which localizes the triple before
    tuning) — and records both with the local problem, so the dry-run
    artifact shows exactly where global-shape tuning would have picked
    tiles for a problem no device runs.  Heuristic policy: cheap enough to
    run per cell; a persisted ``REPRO_TUNING_CACHE`` upgrade is the
    measured follow-up.
    """
    dtype = jnp.dtype(dtype or cfg.dtype)
    out = []
    for name, op, (m, n, k), spec in cell_problems(cfg, shape):
        blk_global = dispatch.resolve_blocks(op, m, n, k, dtype,
                                             backend="pallas")
        with dispatch.use(mesh=mesh, axis_specs={op: spec}):
            local = shlocal.local_problem(op, m, n, k, mesh,
                                          axis_specs={op: spec})
            blk_local = dispatch.resolve_blocks(op, m, n, k, dtype,
                                                backend="pallas")
        out.append({
            "name": name, "op": op, "dtype": dtype.name,
            "global": [m, n, k], "local": list(local),
            "blocks_global": blocks_to_dict(blk_global),
            "blocks_local": blocks_to_dict(blk_local),
            "differs": blk_local != blk_global,
        })
    return out


def _bytes_per_device(tree_specs, shardings, mesh) -> float:
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tree_specs),
                        jax.tree.leaves(shardings, is_leaf=lambda x:
                                        isinstance(x, NamedSharding))):
        n = 1
        for d in leaf.shape:
            n *= d
        shards = 1
        for ax in sh.spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                shards *= mesh.shape[a]
        total += n * jnp.dtype(leaf.dtype).itemsize / shards
    return total


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
               moment_dtype: str | None = None, extra_tag: str = "",
               cfg_overrides: dict | None = None):
    import dataclasses as _dc
    cfg = configs.get(arch_name)
    if cfg_overrides:
        # Cost-accounting mode (launch/costs.py): small unrolled stacks so
        # XLA cost analysis counts every layer (while-loop bodies are
        # otherwise counted once); full-model costs are extrapolated from
        # two layer counts.  The compile-proof sweep uses rolled scans.
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    # bf16 moments for the largest models keep optimizer HBM in budget
    if moment_dtype is None:
        total, _ = cfg.param_counts()
        moment_dtype = "bfloat16" if total > 1e11 else "float32"
    ocfg = opt.AdamWCfg(moment_dtype=moment_dtype)

    t0 = time.time()
    with mesh, use_rules(rules.activation_rules(mesh), mesh):
        if shape.kind == "train":
            state = ts.abstract_state(cfg, ocfg)
            batch = api.input_specs(cfg, shape)
            state_sh = rules.param_shardings(state, mesh, fsdp=cfg.fsdp, tp=cfg.tp)
            batch_sh = rules.batch_shardings(batch, mesh)
            step = ts.make_train_step(cfg, ocfg)
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh)).lower(state, batch)
            arg_bytes = _bytes_per_device(state, state_sh, mesh)
        elif shape.kind == "prefill":
            params = api.params_specs(None, cfg)
            batch = api.input_specs(cfg, shape)
            cache = api.cache_specs(cfg, shape)
            p_sh = rules.param_shardings(params, mesh, fsdp=cfg.fsdp, tp=cfg.tp)
            b_sh = rules.batch_shardings(batch, mesh)
            c_sh = rules.cache_shardings(cache, mesh)

            def prefill_fn(params, batch, cache):
                return api.prefill(params, batch, cfg, cache)

            lowered = jax.jit(
                prefill_fn, in_shardings=(p_sh, b_sh, c_sh)).lower(
                    params, batch, cache)
            arg_bytes = (_bytes_per_device(params, p_sh, mesh)
                         + _bytes_per_device(cache, c_sh, mesh))
        else:  # decode
            params = api.params_specs(None, cfg)
            batch = api.input_specs(cfg, shape)
            cache = api.cache_specs(cfg, shape)
            p_sh = rules.param_shardings(params, mesh, fsdp=cfg.fsdp, tp=cfg.tp)
            b_sh = rules.batch_shardings(batch, mesh)
            c_sh = rules.cache_shardings(cache, mesh)
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
            pos_sh = NamedSharding(mesh, P())

            def decode_fn(params, tokens, cache, pos):
                return api.decode_step(params, tokens, cfg, cache, pos)

            lowered = jax.jit(
                decode_fn,
                in_shardings=(p_sh, b_sh["tokens"], c_sh, pos_sh)).lower(
                    params, batch["tokens"], cache, pos_spec)
            arg_bytes = (_bytes_per_device(params, p_sh, mesh)
                         + _bytes_per_device(cache, c_sh, mesh))

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not support it
        mem_info = {"error": str(e)}

    coll = collective_bytes(compiled.as_text())
    total_p, active_p = cfg.param_counts()
    rec = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mesh_axes": {str(a): int(mesh.shape[a]) for a in mesh.axis_names},
        "status": "ok", "tag": extra_tag,
        "n_devices": mesh.size,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops"),
        "bytes_accessed_per_device": cost.get("bytes accessed"),
        "collective_bytes_per_device": coll,
        "state_bytes_per_device": arg_bytes,
        "params_total": total_p, "params_active": active_p,
        "moment_dtype": moment_dtype,
        # outside the `with mesh` block on purpose: the meshless baseline
        # resolution must not see a dispatch mesh context
        "resolved_blocks": block_choices(cfg, shape, mesh),
    }
    return rec


def _cell_path(arch, shape, multi_pod, tag=""):
    mesh = "multi" if multi_pod else "single"
    suffix = f"_{tag}" if tag else ""
    return ART / f"dryrun_{arch}_{shape}_{mesh}{suffix}.json"


def run_all(multi_pod: bool, force: bool = False):
    ART.mkdir(parents=True, exist_ok=True)
    results = []
    for arch in configs.ARCH_NAMES:
        for shape in SHAPES:
            out = _cell_path(arch, shape, multi_pod)
            if out.exists() and not force:
                results.append(json.loads(out.read_text()))
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if multi_pod:
                cmd.append("--multi-pod")
            print(f"[dryrun] {arch} x {shape} "
                  f"({'multi' if multi_pod else 'single'})", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if multi_pod else "single",
                       "status": "error",
                       "stderr": r.stderr[-4000:]}
                out.write_text(json.dumps(rec, indent=1))
                print(f"  ERROR: {r.stderr[-500:]}", flush=True)
                results.append(rec)
            else:
                results.append(json.loads(out.read_text()))
                print("  ok", flush=True)
    return results


def blocks_smoke(arch: str, shape_name: str) -> int:
    """CI smoke: one (arch x shape x host-mesh) cell through mesh-aware
    dispatch.  Prints the ``resolved_blocks`` record and fails unless at
    least one per-shard choice differs from the global-shape choice."""
    mesh = make_host_mesh()
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh_axes": {str(a): int(mesh.shape[a]) for a in mesh.axis_names},
        "n_devices": mesh.size,
        "resolved_blocks": block_choices(cfg, shape, mesh),
    }
    print(json.dumps(rec, indent=1))
    n_diff = sum(r["differs"] for r in rec["resolved_blocks"])
    print(f"[dryrun-smoke] problems={len(rec['resolved_blocks'])} "
          f"per_shard_differs={n_diff}")
    return 0 if n_diff else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--devices", type=int, default=None,
                    help="forced host device count (default "
                         f"{DEFAULT_HOST_DEVICES}, or {DEVICES_ENV})")
    ap.add_argument("--blocks-smoke", action="store_true",
                    help="resolve one cell's blocks per-shard on a host "
                         "mesh and assert they differ from the global "
                         "choice (CI)")
    args = ap.parse_args()
    force_host_device_count(args.devices)

    if args.blocks_smoke:
        sys.exit(blocks_smoke(args.arch or "smollm-135m",
                              args.shape or "decode_32k"))

    if args.all:
        res = run_all(args.multi_pod, args.force)
        n_ok = sum(r["status"] == "ok" for r in res)
        n_skip = sum(r["status"] == "skipped" for r in res)
        n_err = sum(r["status"] == "error" for r in res)
        print(f"[dryrun] ok={n_ok} skipped={n_skip} error={n_err}")
        sys.exit(1 if n_err else 0)

    assert args.arch and args.shape
    rec = lower_cell(args.arch, args.shape, multi_pod=args.multi_pod)
    ART.mkdir(parents=True, exist_ok=True)
    out = _cell_path(args.arch, args.shape, args.multi_pod)
    out.write_text(json.dumps(rec, indent=1))
    print(json.dumps(
        {k: v for k, v in rec.items() if k != "stderr"}, indent=1))


if __name__ == "__main__":
    main()
