"""Roofline analysis from the dry-run artifacts (TPU v5e targets).

Per (arch x shape), single-pod mesh:
  compute    = HLO_FLOPs / (chips * 197 TFLOP/s)
  memory     = HLO_bytes / (chips * 819 GB/s)
  collective = collective_bytes / (chips * 50 GB/s/link)

The dry-run records *per-device* flops/bytes (XLA cost analysis runs on the
SPMD-partitioned per-device module), so terms divide by the per-chip peak
directly.  MODEL_FLOPS uses 6*N*D (dense) / 6*N_active*D (MoE) for train
and 2*N*D_tokens for inference shapes; the ratio MODEL/HLO exposes remat
and dispatch overheads.

Note on the memory term: XLA's "bytes accessed" counts every HLO buffer
read/write (no fusion credit), so it is an upper bound — on TPU, Mosaic/XLA
fusion keeps most intermediates in VMEM.  It is still the right
*optimization signal*: changes that reduce it (remat policy, fusion,
layout) reduce real HBM traffic.

Usage:
  python -m repro.launch.roofline                  # full table (markdown)
  python -m repro.launch.roofline --json out.json
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro import configs
from repro.configs.shapes import SHAPES
from repro.models import api

ART = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link


def _attn_layer_count(cfg) -> int:
    if cfg.block == "rglru_hybrid":
        return (cfg.n_layers // len(cfg.pattern)) * cfg.pattern.count("attn")
    if cfg.block == "xlstm":
        return 0
    if cfg.block == "encdec":
        return cfg.n_enc_layers + 2 * cfg.n_layers  # self + cross
    return cfg.n_layers


def model_flops(arch_name: str, shape_name: str) -> float:
    """6*N*D (param term) + 12*L*B*T^2*H*dh/2 (causal attention term) for
    train; 1/3 of the multiplier for forward-only shapes."""
    cfg = configs.get(arch_name)
    shape = SHAPES[shape_name]
    total, active = cfg.param_counts()
    n = active  # 6*N_active*D for MoE == 6*N*D for dense (active == total)
    tl = api.token_len(cfg, shape)
    la = _attn_layer_count(cfg)
    dh = cfg.qk_nope_dim + cfg.qk_rope_dim if cfg.mla else cfg.dh
    h = cfg.n_heads

    def attn_flops(t_q, t_kv, fwd_only):
        if la == 0:
            return 0.0
        window = min(cfg.window or t_kv, t_kv)
        eff_kv = min(window, t_kv)
        mult = 4.0 if fwd_only else 12.0   # 2 matmuls fwd (+4 bwd) * 2 flops
        causal = 0.5 if t_q == t_kv else 1.0
        return mult * la * h * dh * t_q * eff_kv * causal

    if shape.kind == "train":
        tokens = shape.global_batch * tl
        return (6.0 * n * tokens
                + shape.global_batch * attn_flops(tl, tl, False))
    if shape.kind == "prefill":
        tokens = shape.global_batch * tl
        return (2.0 * n * tokens
                + shape.global_batch * attn_flops(tl, tl, True))
    tokens = shape.global_batch * 1
    return (2.0 * n * tokens
            + shape.global_batch * attn_flops(1, shape.seq_len, True))


def load_cell(arch: str, shape: str, mesh: str = "single", tag: str = ""):
    suffix = f"_{tag}" if tag else ""
    # prefer the trip-count-exact cost artifact; fall back to the rolled
    # compile-proof record
    for prefix in ("cost", "dryrun"):
        f = ART / f"{prefix}_{arch}_{shape}_{mesh}{suffix}.json"
        if f.exists():
            return json.loads(f.read_text())
    return None


def analyze(rec: dict) -> dict | None:
    if rec is None or rec.get("status") != "ok":
        return None
    n = rec["n_devices"]
    flops_dev = rec["flops_per_device"] or 0.0
    bytes_dev = rec["bytes_accessed_per_device"] or 0.0
    coll_dev = rec["collective_bytes_per_device"].get("total", 0.0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = flops_dev * n
    out = {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        # fraction of the compute roofline actually achieved if the machine
        # ran at the dominant term's speed (the score axis)
        "roofline_fraction": (mf / n / PEAK_FLOPS) / max(terms.values())
        if max(terms.values()) > 0 else 0.0,
    }
    return out


_SUGGEST = {
    "compute": ("raise MXU utilization: larger per-chip batch/microbatch, "
                "fuse small ops into the brgemm epilogues"),
    "memory": ("cut HBM traffic: relax remat recompute, fuse elementwise "
               "chains, cast activations/caches to bf16/int8"),
    "collective": ("re-shard: move the dominant all-gather/all-to-all to a "
                   "different axis, overlap with compute, or compress"),
}


def table(mesh: str = "single"):
    rows = []
    for arch in configs.ARCH_NAMES:
        for shape in SHAPES:
            rec = load_cell(arch, shape, mesh)
            if rec is None:
                continue
            if rec["status"] == "skipped":
                rows.append({"arch": arch, "shape": shape,
                             "status": "skipped",
                             "reason": rec["reason"][:60]})
                continue
            a = analyze(rec)
            rows.append({"arch": arch, "shape": shape, "status": "ok",
                         **a, "suggest": _SUGGEST[a["dominant"]]})
    return rows


def to_markdown(rows) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " MODEL_FLOPS | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped |"
                f" — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} |"
            f" {r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} |"
            f" {r['dominant']} | {r['model_flops']:.2e} |"
            f" {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = table(args.mesh)
    print(to_markdown(rows))
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
