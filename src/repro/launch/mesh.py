"""Production meshes (functions, not module constants — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (2, 2) x (data, model))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel / FSDP axes present in the mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)
