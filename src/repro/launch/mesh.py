"""Production meshes (functions, not module constants — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (2, 2) x (data, model))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(n_devices: int | None = None):
    """A smoke-scale (data, model) mesh over the host's visible devices.

    Used by the dry-run blocks smoke and tests running under
    ``--xla_force_host_platform_device_count=N``: the model axis takes the
    largest power-of-two factor up to 16 that still leaves a data axis
    (e.g. 8 devices -> (2, 4)), mirroring the production mesh's shape
    hierarchy at host scale.
    """
    n = n_devices or jax.device_count()
    model = 1
    while model * 2 <= min(n // 2, 16) and n % (model * 2) == 0:
        model *= 2
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel / FSDP axes present in the mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)
