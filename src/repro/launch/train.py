"""Training launcher: mesh + shardings + data + checkpoints + restart loop.

Usage (CPU-scale example; production meshes come from mesh.py):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 50 --batch 8 --seq 64 --mesh 1x1
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.shapes import ShapeCfg
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_mesh
from repro.sharding import rules
from repro.sharding.annotate import use_rules
from repro.train import optimizer as opt
from repro.train import train_step as ts


def run(cfg, shape, *, mesh, steps: int, ckpt_dir=None, save_every=50,
        microbatches: int = 1, log_every: int = 10, seed: int = 0):
    ocfg = opt.AdamWCfg()
    step_fn = ts.make_train_step(cfg, ocfg, microbatches=microbatches)

    with mesh, use_rules(rules.activation_rules(mesh), mesh):
        state = ts.init_state(jax.random.PRNGKey(seed), cfg, ocfg)
        state_sh = rules.param_shardings(state, mesh)
        state = jax.tree.map(jax.device_put, state,
                             state_sh)
        jit_step = jax.jit(step_fn, in_shardings=(state_sh, None),
                           out_shardings=(state_sh, None),
                           donate_argnums=(0,))

        ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        start = 0
        if ckpt and ckpt.latest_step() is not None:
            state, start = ckpt.restore(state, shardings=state_sh)
            start += 1
            print(f"[train] resumed from step {start - 1}")

        pipe = TokenPipeline(cfg, shape, seed=seed, start_step=start)
        losses = []
        t0 = time.time()
        for step in range(start, steps):
            batch = next(pipe)
            state, metrics = jit_step(state, batch)
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                dt = time.time() - t0
                tok_s = (step - start + 1) * shape.global_batch \
                    * batch["tokens"].shape[1] / max(dt, 1e-9)
                print(f"[train] step {step} loss {losses[-1]:.4f} "
                      f"tokens/s {tok_s:,.0f}")
            if ckpt and step and step % save_every == 0:
                ckpt.save_async(step, state)
        if ckpt:
            ckpt.wait()
        pipe.close()
        return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_NAMES,
                    default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="1x1",
                    help="DATAxMODEL, e.g. 2x2 (needs that many devices)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    d, m = (int(v) for v in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    shape = ShapeCfg("cli", "train", args.seq, args.batch)
    _, losses = run(cfg, shape, mesh=mesh, steps=args.steps,
                    ckpt_dir=args.ckpt_dir,
                    microbatches=args.microbatches)
    print(f"[train] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
