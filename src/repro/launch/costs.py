import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# isort: split
"""Exact HLO cost accounting via layer-count extrapolation.

XLA's cost analysis counts a ``lax.scan`` (while-loop) body ONCE regardless
of trip count, so the rolled-scan dry-run under-reports FLOPs/bytes/
collective bytes by ~n_layers.  Full unrolling of 60-90 layer models at 512
devices is compile-prohibitive.  Instead: lower each cell at two (or three)
SMALL layer counts with the stacks UNROLLED — per-layer HLO is identical
across layers, so costs are exactly affine in the layer/group count — and
extrapolate to the real depth:

    cost(L) = base + per_layer * L
    per_layer = (cost(L2) - cost(L1)) / (L2 - L1)

Per block type the sample points respect the arch's grouping constraints
(xlstm groups of `slstm_every`, rglru (rec,rec,attn) groups + tail, enc/dec
stacks separately).  Writes ``cost_<arch>_<shape>_<mesh>.json`` artifacts
consumed by launch/roofline.py.

Usage:
  python -m repro.launch.costs --arch grok-1-314b --shape train_4k
  python -m repro.launch.costs --all
"""
import argparse
import json
import subprocess
import sys

from repro import configs
from repro.configs.shapes import SHAPES, applicable
from repro.launch.dryrun import ART, lower_cell

_FIELDS = ("flops_per_device", "bytes_accessed_per_device")


def _extract(rec):
    out = {f: rec[f] or 0.0 for f in _FIELDS}
    coll = rec["collective_bytes_per_device"]
    for k, v in coll.items():
        if not k.startswith("_count_"):
            out[f"coll_{k}"] = v
    return out


def _combine(base, slope_pairs):
    """base: costs dict; slope_pairs: list of (per_unit_costs, extra_units)."""
    out = dict(base)
    for per, n in slope_pairs:
        for k in set(out) | set(per):
            out[k] = out.get(k, 0.0) + per.get(k, 0.0) * n
    return out


def _diff(a, b, denom=1.0):
    return {k: (a.get(k, 0.0) - b.get(k, 0.0)) / denom
            for k in set(a) | set(b)}


def cost_cell(arch: str, shape: str, *, multi_pod: bool = False,
              extra_overrides: dict | None = None, tag: str = ""):
    cfg = configs.get(arch)
    ok, why = applicable(cfg, SHAPES[shape])
    if not ok:
        return {"arch": arch, "shape": shape, "status": "skipped",
                "reason": why}
    eo = extra_overrides or {}
    if cfg.block == "xlstm" and SHAPES[shape].seq_len > 8192:
        # unrolling 32k/256 = 128 chunk steps per layer is compile-
        # prohibitive; use a 2048 chunk (16 steps).  CAVEAT (EXPERIMENTS.md
        # §Method): overstates the intra-chunk quadratic term ~8x vs the
        # production chunk=256 — a conservative upper bound.
        eo.setdefault("mlstm_chunk", 2048)

    def lower(**ov):
        rec = lower_cell(arch, shape, multi_pod=multi_pod,
                         cfg_overrides={"scan_unroll": True, **eo, **ov})
        assert rec["status"] == "ok", rec
        return _extract(rec), rec

    if cfg.block in ("dense", "moe"):
        c2, _ = lower(n_layers=2)
        c4, rec = lower(n_layers=4)
        per = _diff(c4, c2, 2)
        full = _combine(c2, [(per, cfg.n_layers - 2)])
    elif cfg.block == "mla_moe":
        nd = cfg.n_dense_layers
        c1, _ = lower(n_layers=nd + 1)
        c2, rec = lower(n_layers=nd + 2)
        per = _diff(c2, c1, 1)
        full = _combine(c1, [(per, cfg.n_layers - nd - 1)])
    elif cfg.block == "xlstm":
        se = cfg.slstm_every
        c1, _ = lower(n_layers=se)          # 1 group
        c2, rec = lower(n_layers=2 * se)    # 2 groups
        per = _diff(c2, c1, 1)
        full = _combine(c1, [(per, cfg.n_layers // se - 1)])
    elif cfg.block == "rglru_hybrid":
        np_ = len(cfg.pattern)
        g_real = cfg.n_layers // np_
        tail = cfg.n_layers - g_real * np_
        c1, _ = lower(n_layers=np_)         # 1 group, no tail
        c2, rec = lower(n_layers=2 * np_)   # 2 groups
        per_group = _diff(c2, c1, 1)
        parts = [(per_group, g_real - 1)]
        if tail:
            c_tail, _ = lower(n_layers=np_ + tail)  # 1 group + tail
            parts.append((_diff(c_tail, c1, 1), 1.0))
        full = _combine(c1, parts)
    elif cfg.block == "encdec":
        c22, _ = lower(n_layers=2, n_enc_layers=2)
        c42, _ = lower(n_layers=2, n_enc_layers=4)
        c24, rec = lower(n_layers=4, n_enc_layers=2)
        per_enc = _diff(c42, c22, 2)
        per_dec = _diff(c24, c22, 2)
        full = _combine(c22, [(per_enc, cfg.n_enc_layers - 2),
                              (per_dec, cfg.n_layers - 2)])
    else:
        raise ValueError(cfg.block)

    total_p, active_p = cfg.param_counts()
    out = {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "tag": tag,
        "n_devices": rec["n_devices"],
        "method": "unrolled-2pt-extrapolation",
        "flops_per_device": full["flops_per_device"],
        "bytes_accessed_per_device": full["bytes_accessed_per_device"],
        "collective_bytes_per_device": {
            **{k[5:]: v for k, v in full.items() if k.startswith("coll_")},
            "total": full.get("coll_total", 0.0),
        },
        "state_bytes_per_device": rec["state_bytes_per_device"],
        "params_total": total_p, "params_active": active_p,
    }
    return out


def _out_path(arch, shape, multi_pod, tag=""):
    mesh = "multi" if multi_pod else "single"
    suffix = f"_{tag}" if tag else ""
    return ART / f"cost_{arch}_{shape}_{mesh}{suffix}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix for A/B runs")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    help="ArchCfg override, e.g. --set attention_impl=chunked")
    args = ap.parse_args()
    ART.mkdir(parents=True, exist_ok=True)

    overrides = {}
    for kv in args.overrides:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        elif v.isdigit():
            v = int(v)
        overrides[k] = v

    if args.all:
        n_err = 0
        for arch in configs.ARCH_NAMES:
            for shape in SHAPES:
                out = _out_path(arch, shape, args.multi_pod)
                if out.exists() and not args.force:
                    continue
                cmd = [sys.executable, "-m", "repro.launch.costs",
                       "--arch", arch, "--shape", shape]
                if args.multi_pod:
                    cmd.append("--multi-pod")
                print(f"[costs] {arch} x {shape}", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True)
                if r.returncode != 0:
                    n_err += 1
                    print(f"  ERROR: {r.stderr[-400:]}", flush=True)
                else:
                    print("  ok", flush=True)
        sys.exit(1 if n_err else 0)

    rec = cost_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                    extra_overrides=overrides, tag=args.tag)
    _out_path(args.arch, args.shape, args.multi_pod, args.tag).write_text(
        json.dumps(rec, indent=1))
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
