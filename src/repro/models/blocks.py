"""Single-layer transformer blocks for every assigned architecture family.

Each block exposes ``*_init(key, cfg)``, ``*_apply(params, x, cfg, ...)`` and
``*_cache(cfg, batch, max_len)``; stacking/scanning lives in
``models/transformer.py``.  The aux dict (MoE losses) keeps a fixed structure
so heterogeneous stacks scan cleanly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchCfg
from repro.layers import attention, mlp, moe, norms, recurrent

ZERO_AUX = {"load_balance_loss": 0.0, "router_z_loss": 0.0,
            "dropped_fraction": 0.0}


def _dtype(cfg: ArchCfg):
    return jnp.dtype(cfg.dtype)


def attn_cfg(cfg: ArchCfg, *, window=None) -> attention.AttnCfg:
    return attention.AttnCfg(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        window=window if window is not None else cfg.window,
        mla=cfg.mla, q_lora_rank=cfg.q_lora_rank,
        kv_lora_rank=cfg.kv_lora_rank, qk_nope_dim=cfg.qk_nope_dim,
        qk_rope_dim=cfg.qk_rope_dim, v_head_dim=cfg.v_head_dim,
        xla_impl=cfg.attention_impl, unroll=cfg.scan_unroll)


def moe_cfg(cfg: ArchCfg) -> moe.MoECfg:
    return moe.MoECfg(
        d_model=cfg.d_model, d_ff=cfg.moe_d_ff, n_experts=cfg.n_experts,
        top_k=cfg.top_k, n_shared=cfg.n_shared_experts,
        capacity_factor=cfg.moe_capacity_factor)


# --------------------------------------------------------------------------
# dense / moe decoder block: x += attn(ln(x)); x += ffn(ln(x))
# --------------------------------------------------------------------------

def decoder_block_init(key, cfg: ArchCfg, *, use_moe: bool):
    ks = jax.random.split(key, 2)
    dt = _dtype(cfg)
    p = {
        "ln1": norms.rmsnorm_init(cfg.d_model, dt),
        "attn": attention.init(ks[0], attn_cfg(cfg), dt),
        "ln2": norms.rmsnorm_init(cfg.d_model, dt),
    }
    if use_moe:
        p["moe"] = moe.init(ks[1], moe_cfg(cfg), dt)
    else:
        p["mlp"] = mlp.init(ks[1], cfg.d_model, cfg.d_ff,
                            gated=cfg.gated_mlp, dtype=dt)
    return p


def decoder_block_apply(params, x, cfg: ArchCfg, *, mode="train",
                        cache=None, pos=0, backend=None):
    acfg = attn_cfg(cfg)
    h = norms.rmsnorm(params["ln1"], x)
    if mode == "train":
        x = x + attention.apply(params["attn"], h, acfg, mode="train",
                                backend=backend)
        new_cache = cache
    elif cfg.window and not cfg.mla:
        if mode == "prefill_chunk":
            raise ValueError(
                "chunked prefill is not supported for sliding-window archs "
                "(ring cache holds only the trailing window)")
        # sliding-window archs serve from a ring buffer of size `window`
        if mode == "decode":
            y, new_cache = _ring_decode(params["attn"], h, acfg, cache, pos,
                                        backend)
        else:  # prefill
            y = attention.apply(params["attn"], h, acfg, mode="train",
                                backend=backend)
            new_cache = _ring_from_prefill(params["attn"], h, acfg, cache,
                                           backend)
        x = x + y
    else:
        y, new_cache = attention.apply(
            params["attn"], h, acfg, mode=mode, cache=cache, pos=pos,
            backend=backend)
        x = x + y
    h = norms.rmsnorm(params["ln2"], x)
    if "moe" in params:
        y, aux = moe.apply(params["moe"], h, moe_cfg(cfg), backend=backend)
    else:
        y = mlp.apply(params["mlp"], h, activation=cfg.mlp_activation,
                      backend=backend)
        aux = ZERO_AUX
    return x + y, new_cache, aux


def decoder_block_cache(cfg: ArchCfg, batch: int, max_len: int):
    acfg = attn_cfg(cfg)
    length = min(max_len, cfg.window) if cfg.window else max_len
    return attention.init_cache(acfg, batch, length, _dtype(cfg))


# --------------------------------------------------------------------------
# xLSTM block: x += mixer(ln(x));  mixer in {mLSTM, sLSTM}
# --------------------------------------------------------------------------

def mlstm_cfg(cfg: ArchCfg) -> recurrent.MLSTMCfg:
    dh = cfg.d_model // cfg.n_heads
    return recurrent.MLSTMCfg(d_model=cfg.d_model, n_heads=cfg.n_heads,
                              dk=dh, dv=dh, chunk=cfg.mlstm_chunk,
                              unroll=cfg.scan_unroll)


def slstm_cfg(cfg: ArchCfg) -> recurrent.SLSTMCfg:
    return recurrent.SLSTMCfg(d_model=cfg.d_model, n_heads=cfg.n_heads)


def mlstm_block_init(key, cfg: ArchCfg):
    dt = _dtype(cfg)
    return {"ln": norms.rmsnorm_init(cfg.d_model, dt),
            "mlstm": recurrent.mlstm_init(key, mlstm_cfg(cfg), dt)}


def mlstm_block_apply(params, x, cfg, *, state=None, backend=None):
    h = norms.rmsnorm(params["ln"], x)
    y, state = recurrent.mlstm_apply(params["mlstm"], h, mlstm_cfg(cfg),
                                     state=state, backend=backend)
    return x + y, state


def mlstm_block_state(cfg: ArchCfg, batch: int):
    m = mlstm_cfg(cfg)
    return (jnp.zeros((batch, m.n_heads, m.dk, m.dv), jnp.float32),
            jnp.zeros((batch, m.n_heads, m.dk), jnp.float32),
            jnp.full((batch, m.n_heads), -1e30, jnp.float32))


def slstm_block_init(key, cfg: ArchCfg):
    dt = _dtype(cfg)
    return {"ln": norms.rmsnorm_init(cfg.d_model, dt),
            "slstm": recurrent.slstm_init(key, slstm_cfg(cfg), dt)}


def slstm_block_apply(params, x, cfg, *, state=None, backend=None):
    h = norms.rmsnorm(params["ln"], x)
    y, state = recurrent.slstm_apply(params["slstm"], h, slstm_cfg(cfg),
                                     state=state, backend=backend)
    return x + y, state


def slstm_block_state(cfg: ArchCfg, batch: int):
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), jnp.float32),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.ones((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}


# --------------------------------------------------------------------------
# RG-LRU hybrid blocks (RecurrentGemma): rec/rec/attn pattern, each with MLP
# --------------------------------------------------------------------------

def rglru_cfg(cfg: ArchCfg) -> recurrent.RGLRUCfg:
    return recurrent.RGLRUCfg(d_model=cfg.d_model, d_rnn=cfg.d_rnn)


def rec_block_init(key, cfg: ArchCfg):
    ks = jax.random.split(key, 2)
    dt = _dtype(cfg)
    return {
        "ln1": norms.rmsnorm_init(cfg.d_model, dt),
        "rglru": recurrent.rglru_init(ks[0], rglru_cfg(cfg), dt),
        "ln2": norms.rmsnorm_init(cfg.d_model, dt),
        "mlp": mlp.init(ks[1], cfg.d_model, cfg.d_ff,
                        gated=cfg.gated_mlp, dtype=dt),
    }


def rec_block_apply(params, x, cfg, *, state=None, backend=None):
    h = norms.rmsnorm(params["ln1"], x)
    y, state = recurrent.rglru_apply(params["rglru"], h, rglru_cfg(cfg),
                                     state=state, backend=backend)
    x = x + y
    x = x + mlp.apply(params["mlp"], norms.rmsnorm(params["ln2"], x),
                      activation=cfg.mlp_activation, backend=backend)
    return x, state


def rec_block_state(cfg: ArchCfg, batch: int):
    r = rglru_cfg(cfg)
    return {"h": jnp.zeros((batch, r.d_rnn), jnp.float32),
            "conv": jnp.zeros((batch, r.conv_width - 1, r.d_rnn),
                              _dtype(cfg))}


def local_attn_block_init(key, cfg: ArchCfg):
    ks = jax.random.split(key, 2)
    dt = _dtype(cfg)
    return {
        "ln1": norms.rmsnorm_init(cfg.d_model, dt),
        "attn": attention.init(ks[0], attn_cfg(cfg), dt),
        "ln2": norms.rmsnorm_init(cfg.d_model, dt),
        "mlp": mlp.init(ks[1], cfg.d_model, cfg.d_ff,
                        gated=cfg.gated_mlp, dtype=dt),
    }


def local_attn_block_apply(params, x, cfg, *, mode="train", cache=None,
                           pos=0, backend=None):
    acfg = attn_cfg(cfg)
    h = norms.rmsnorm(params["ln1"], x)
    if mode == "train":
        x = x + attention.apply(params["attn"], h, acfg, mode="train",
                                backend=backend)
        new_cache = cache
    elif mode == "decode":
        # ring-buffer cache of size window
        y, new_cache = _ring_decode(params["attn"], h, acfg, cache, pos,
                                    backend)
        x = x + y
    else:  # prefill
        y = attention.apply(params["attn"], h, acfg, mode="train",
                            backend=backend)
        new_cache = _ring_from_prefill(params["attn"], h, acfg, cache,
                                       backend)
        x = x + y
    x = x + mlp.apply(params["mlp"], norms.rmsnorm(params["ln2"], x),
                      activation=cfg.mlp_activation, backend=backend)
    return x, new_cache


def _ring_decode(attn_params, h, acfg, cache, pos, backend):
    from repro.kernels.flash_attention.ref import mha_ref
    from repro.core import brgemm
    w = cache["k"].shape[2]
    positions = jnp.full((h.shape[1],), pos)
    q, k, v = attention._gqa_qkv(attn_params, h, acfg, positions, backend)
    slot = pos % w
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))
    kv_len = jnp.minimum(pos + 1, w)
    o = mha_ref(q, cache["k"], cache["v"], causal=False, kv_len=kv_len)
    y = brgemm.matmul(attention._merge_heads(o), attn_params["wo"],
                      backend=backend)
    return y, cache


def _ring_from_prefill(attn_params, h, acfg, cache, backend):
    """Build the decode ring buffer from the last `window` prefill keys."""
    w = cache["k"].shape[2]
    t = h.shape[1]
    positions = jnp.arange(t)
    _, k, v = attention._gqa_qkv(attn_params, h, acfg, positions, backend)
    if t >= w:
        k_last, v_last = k[:, :, -w:], v[:, :, -w:]
        shift = (t - w) % w
        k_last = jnp.roll(k_last, shift, axis=2)
        v_last = jnp.roll(v_last, shift, axis=2)
        return {"k": k_last.astype(cache["k"].dtype),
                "v": v_last.astype(cache["v"].dtype)}
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    return cache


def local_attn_block_cache(cfg: ArchCfg, batch: int, max_len: int):
    acfg = attn_cfg(cfg)
    length = min(max_len, cfg.window or max_len)
    return attention.init_cache(acfg, batch, length, _dtype(cfg))
