"""ResNet-50 (paper Sec. 4.2.2 workload) on the direct-conv primitive.

Bottleneck blocks exactly as in Table 2; a ``width`` factor scales channel
counts for CPU-sized smoke tests.  All convolutions route through the
batch-reduce conv (kernels/conv2d).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.layers import conv as conv_layer
from repro.layers import linear


@dataclasses.dataclass(frozen=True)
class ResNetCfg:
    n_classes: int = 1000
    width: int = 64               # 64 = full ResNet-50
    stage_blocks: tuple = (3, 4, 6, 3)


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn(params, x, eps=1e-5):
    # inference-style norm over (N, H, W) — keeps the example dependency-free
    mean = x.mean(axis=(0, 1, 2), keepdims=True)
    var = x.var(axis=(0, 1, 2), keepdims=True)
    xhat = (x - mean) * jax.lax.rsqrt(var + eps)
    return xhat * params["scale"] + params["bias"]


def _bottleneck_init(key, cin, cmid, cout, stride):
    ks = jax.random.split(key, 4)
    p = {
        "conv1": conv_layer.init(ks[0], cin, cmid, 1, 1, use_bias=False),
        "bn1": _bn_init(cmid),
        "conv2": conv_layer.init(ks[1], cmid, cmid, 3, 3, use_bias=False),
        "bn2": _bn_init(cmid),
        "conv3": conv_layer.init(ks[2], cmid, cout, 1, 1, use_bias=False),
        "bn3": _bn_init(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = conv_layer.init(ks[3], cin, cout, 1, 1, use_bias=False)
        p["bn_proj"] = _bn_init(cout)
    return p


def _bottleneck(p, x, stride, backend):
    h = jax.nn.relu(_bn(p["bn1"], conv_layer.apply(
        p["conv1"], x, backend=backend)))
    h = jax.nn.relu(_bn(p["bn2"], conv_layer.apply(
        p["conv2"], h, stride=stride, padding=1, backend=backend)))
    h = _bn(p["bn3"], conv_layer.apply(p["conv3"], h, backend=backend))
    if "proj" in p:
        x = _bn(p["bn_proj"], conv_layer.apply(
            p["proj"], x, stride=stride, backend=backend))
    return jax.nn.relu(x + h)


def init_params(key, cfg: ResNetCfg):
    w = cfg.width
    ks = jax.random.split(key, 2 + sum(cfg.stage_blocks))
    p = {"stem": conv_layer.init(ks[0], 3, w, 7, 7, use_bias=False),
         "bn_stem": _bn_init(w), "stages": []}
    cin = w
    ki = 1
    for si, n_blocks in enumerate(cfg.stage_blocks):
        cmid = w * (2 ** si)
        cout = cmid * 4
        stage = []
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1  # static, not a param
            stage.append(_bottleneck_init(ks[ki], cin, cmid, cout, stride))
            cin = cout
            ki += 1
        p["stages"].append(stage)
    p["head"] = linear.init(ks[ki], cin, cfg.n_classes)
    return p


def forward(params, x, cfg: ResNetCfg, *, backend=None):
    """x: (N, H, W, 3) -> logits (N, n_classes)."""
    h = conv_layer.apply(params["stem"], x, stride=2, padding=3,
                         backend=backend)
    h = jax.nn.relu(_bn(params["bn_stem"], h))
    # 3x3 max pool stride 2
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for si, stage in enumerate(params["stages"]):
        for bi, block in enumerate(stage):
            stride = 2 if (bi == 0 and si > 0) else 1
            h = _bottleneck(block, h, stride, backend)
    h = h.mean(axis=(1, 2))
    return linear.apply(params["head"], h, backend=backend)
