"""Unified decoder-only LM covering the assigned architecture families.

Layer stacks are ``lax.scan``s over stacked parameters (compact HLO, bounded
compile time at 512 devices) with optional per-layer remat.  Heterogeneous
stacks scan over *groups* with a fixed per-step structure:

  * dense / moe:      scan over L identical decoder blocks
  * mla_moe:          3 leading dense blocks (scan) + scan over MoE blocks
  * xlstm:            scan over G groups of (slstm_every-1 mLSTM + 1 sLSTM)
  * rglru_hybrid:     scan over G groups of (rec, rec, attn) + trailing recs

Serve modes (prefill/decode) scan over (params, caches) pairs and emit the
updated caches as scan outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchCfg
from repro.layers import embeddings, norms
from repro.core import brgemm
from repro.models import blocks
from repro.sharding.annotate import constrain

MTP_WEIGHT = 0.3
LB_WEIGHT = 0.01
Z_WEIGHT = 1e-4


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _stack_init(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _stack_tree(tree, n: int):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)


# ==========================================================================
# init
# ==========================================================================

def init_params(key, cfg: ArchCfg):
    ks = jax.random.split(key, 8)
    dt = _dt(cfg)
    p = {
        "embed": embeddings.init(ks[0], cfg.vocab, cfg.d_model, dtype=dt),
        "final_ln": norms.rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["head"] = {
            "w": (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab),
                                    jnp.float32)
                  * cfg.d_model ** -0.5).astype(dt)}

    if cfg.block in ("dense", "moe"):
        use_moe = cfg.block == "moe"
        p["blocks"] = _stack_init(
            ks[2], cfg.n_layers,
            lambda k: blocks.decoder_block_init(k, cfg, use_moe=use_moe))
    elif cfg.block == "mla_moe":
        nd = cfg.n_dense_layers
        p["dense_blocks"] = _stack_init(
            ks[2], nd,
            lambda k: blocks.decoder_block_init(k, cfg, use_moe=False))
        p["moe_blocks"] = _stack_init(
            ks[3], cfg.n_layers - nd,
            lambda k: blocks.decoder_block_init(k, cfg, use_moe=True))
        if cfg.mtp:
            p["mtp_block"] = blocks.decoder_block_init(
                ks[4], cfg, use_moe=False)
    elif cfg.block == "xlstm":
        se = cfg.slstm_every or cfg.n_layers + 1
        if cfg.n_layers % se == 0:
            g, per = cfg.n_layers // se, se - 1
            p["mlstm_groups"] = _stack_init(
                ks[2], g,
                lambda k: _stack_init(
                    k, per, lambda k2: blocks.mlstm_block_init(k2, cfg)))
            p["slstm_groups"] = _stack_init(
                ks[3], g, lambda k: blocks.slstm_block_init(k, cfg))
        else:
            p["mlstm_groups"] = _stack_init(
                ks[2], 1,
                lambda k: _stack_init(
                    k, cfg.n_layers,
                    lambda k2: blocks.mlstm_block_init(k2, cfg)))
    elif cfg.block == "rglru_hybrid":
        n_pat = len(cfg.pattern)
        g = cfg.n_layers // n_pat
        tail = cfg.n_layers - g * n_pat
        n_rec = cfg.pattern.count("rec")
        p["groups"] = {
            "rec": _stack_init(
                ks[2], g,
                lambda k: _stack_init(
                    k, n_rec, lambda k2: blocks.rec_block_init(k2, cfg))),
            "attn": _stack_init(
                ks[3], g, lambda k: blocks.local_attn_block_init(k, cfg)),
        }
        if tail:
            p["tail_rec"] = _stack_init(
                ks[4], tail, lambda k: blocks.rec_block_init(k, cfg))
    else:
        raise ValueError(cfg.block)

    if cfg.n_patches:
        d = cfg.d_model
        p["vision_proj"] = {
            "w1": (jax.random.normal(ks[5], (d, d), jnp.float32)
                   * d ** -0.5).astype(dt),
            "b1": jnp.zeros((d,), dt),
            "w2": (jax.random.normal(ks[6], (d, d), jnp.float32)
                   * d ** -0.5).astype(dt),
            "b2": jnp.zeros((d,), dt),
        }
    return p


# ==========================================================================
# stack runners
# ==========================================================================

def _aux0():
    return {"load_balance_loss": jnp.float32(0),
            "router_z_loss": jnp.float32(0),
            "dropped_fraction": jnp.float32(0)}


def _acc(a, b):
    return jax.tree.map(jnp.add, a, b)


def _scan_train(stacked, x, apply_fn, remat, unroll=False):
    """apply_fn(p, x) -> (x, aux)."""

    def body(carry, p):
        x, aux = carry
        x, aux_i = apply_fn(p, x)
        return (x, _acc(aux, aux_i)), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, _aux0()), stacked, unroll=unroll)
    return x, aux


def _scan_serve(stacked, caches, x, apply_fn, unroll=False):
    """apply_fn(p, x, cache) -> (x, cache)."""

    def body(x, xs):
        p, c = xs
        x, c_new = apply_fn(p, x, c)
        return x, c_new

    return jax.lax.scan(body, x, (stacked, caches), unroll=unroll)


def _run_stacks(params, x, cfg: ArchCfg, *, mode, caches, pos, backend):
    """Returns (x, aux, new_caches)."""
    aux = _aux0()

    if cfg.block in ("dense", "moe"):
        if mode == "train":
            x, aux = _scan_train(
                params["blocks"], x,
                lambda p, h: blocks.decoder_block_apply(
                    p, h, cfg, mode="train", backend=backend)[::2],
                cfg.remat, cfg.scan_unroll)
            return x, aux, caches
        x, new_c = _scan_serve(
            params["blocks"], caches["blocks"], x,
            lambda p, h, c: blocks.decoder_block_apply(
                p, h, cfg, mode=mode, cache=c, pos=pos, backend=backend)[:2],
            cfg.scan_unroll)
        return x, aux, {"blocks": new_c}

    if cfg.block == "mla_moe":
        def dense_fn(p, h):
            h, _, a = blocks.decoder_block_apply(p, h, cfg, mode="train",
                                                 backend=backend)
            return h, a

        def moe_fn(p, h):
            h, _, a = blocks.decoder_block_apply(p, h, cfg, mode="train",
                                                 backend=backend)
            return h, a

        if mode == "train":
            x, a1 = _scan_train(params["dense_blocks"], x, dense_fn,
                                cfg.remat, cfg.scan_unroll)
            x, a2 = _scan_train(params["moe_blocks"], x, moe_fn, cfg.remat,
                                cfg.scan_unroll)
            return x, _acc(a1, a2), caches
        x, c1 = _scan_serve(
            params["dense_blocks"], caches["dense_blocks"], x,
            lambda p, h, c: blocks.decoder_block_apply(
                p, h, cfg, mode=mode, cache=c, pos=pos, backend=backend)[:2],
            cfg.scan_unroll)
        x, c2 = _scan_serve(
            params["moe_blocks"], caches["moe_blocks"], x,
            lambda p, h, c: blocks.decoder_block_apply(
                p, h, cfg, mode=mode, cache=c, pos=pos, backend=backend)[:2],
            cfg.scan_unroll)
        return x, aux, {"dense_blocks": c1, "moe_blocks": c2}

    if cfg.block == "xlstm":
        # states thread through both train (chunkwise) and serve modes
        has_slstm = "slstm_groups" in params
        mg = params["mlstm_groups"]
        sg = params.get("slstm_groups")
        mstates = caches["mlstm"]
        sstates = caches.get("slstm")

        def body(x, xs):
            if has_slstm:
                (mp, sp), (mst, sst) = xs
            else:
                (mp,), (mst,) = xs
                sp, sst = None, None

            def inner(x2, xs2):
                p, st = xs2
                x2, st = blocks.mlstm_block_apply(p, x2, cfg, state=st,
                                                  backend=backend)
                return x2, st

            if cfg.remat and mode == "train":
                inner = jax.checkpoint(inner)
            x, mst = jax.lax.scan(inner, x, (mp, mst),
                                  unroll=cfg.scan_unroll)
            if sp is not None:
                x, sst = blocks.slstm_block_apply(sp, x, cfg, state=sst,
                                                  backend=backend)
                return x, (mst, sst)
            return x, (mst,)

        if has_slstm:
            x, (mstates, sstates) = jax.lax.scan(
                body, x, ((mg, sg), (mstates, sstates)),
                unroll=cfg.scan_unroll)
            return x, aux, {"mlstm": mstates, "slstm": sstates}
        x, (mstates,) = jax.lax.scan(body, x, ((mg,), (mstates,)),
                                     unroll=cfg.scan_unroll)
        return x, aux, {"mlstm": mstates}

    if cfg.block == "rglru_hybrid":
        def group_body(x, xs):
            (rp, ap), (rst, acache) = xs

            def rec_inner(x2, xs2):
                p, st = xs2
                x2, st = blocks.rec_block_apply(p, x2, cfg, state=st,
                                                backend=backend)
                return x2, st

            if cfg.remat and mode == "train":
                rec_inner = jax.checkpoint(rec_inner)
            x, rst = jax.lax.scan(rec_inner, x, (rp, rst),
                                  unroll=cfg.scan_unroll)
            x, acache = blocks.local_attn_block_apply(
                ap, x, cfg, mode=mode, cache=acache, pos=pos,
                backend=backend)
            return x, (rst, acache)

        g = params["groups"]
        x, (rstates, acaches) = jax.lax.scan(
            group_body, x,
            ((g["rec"], g["attn"]),
             (caches["groups_rec"], caches["groups_attn"])),
            unroll=cfg.scan_unroll)
        new_caches = {"groups_rec": rstates, "groups_attn": acaches}
        if "tail_rec" in params:
            def rec_inner(x2, xs2):
                p, st = xs2
                x2, st = blocks.rec_block_apply(p, x2, cfg, state=st,
                                                backend=backend)
                return x2, st
            if cfg.remat and mode == "train":
                rec_inner = jax.checkpoint(rec_inner)
            x, tst = jax.lax.scan(rec_inner, x, (params["tail_rec"],
                                                 caches["tail_rec"]),
                                  unroll=cfg.scan_unroll)
            new_caches["tail_rec"] = tst
        return x, aux, new_caches

    raise ValueError(cfg.block)


# ==========================================================================
# caches / states
# ==========================================================================

def init_cache(cfg: ArchCfg, batch: int, max_len: int):
    if cfg.block in ("dense", "moe"):
        return {"blocks": _stack_tree(
            blocks.decoder_block_cache(cfg, batch, max_len), cfg.n_layers)}
    if cfg.block == "mla_moe":
        c = blocks.decoder_block_cache(cfg, batch, max_len)
        return {"dense_blocks": _stack_tree(c, cfg.n_dense_layers),
                "moe_blocks": _stack_tree(
                    c, cfg.n_layers - cfg.n_dense_layers)}
    if cfg.block == "xlstm":
        se = cfg.slstm_every or cfg.n_layers + 1
        if cfg.n_layers % se == 0:
            g, per = cfg.n_layers // se, se - 1
            return {
                "mlstm": _stack_tree(
                    _stack_tree(blocks.mlstm_block_state(cfg, batch), per),
                    g),
                "slstm": _stack_tree(blocks.slstm_block_state(cfg, batch),
                                     g),
            }
        return {"mlstm": _stack_tree(
            _stack_tree(blocks.mlstm_block_state(cfg, batch),
                        cfg.n_layers), 1)}
    if cfg.block == "rglru_hybrid":
        n_pat = len(cfg.pattern)
        g = cfg.n_layers // n_pat
        tail = cfg.n_layers - g * n_pat
        n_rec = cfg.pattern.count("rec")
        caches = {
            "groups_rec": _stack_tree(
                _stack_tree(blocks.rec_block_state(cfg, batch), n_rec), g),
            "groups_attn": _stack_tree(
                blocks.local_attn_block_cache(cfg, batch, max_len), g),
        }
        if tail:
            caches["tail_rec"] = _stack_tree(
                blocks.rec_block_state(cfg, batch), tail)
        return caches
    raise ValueError(cfg.block)


# `train` mode for recurrent archs still needs state threading; give zeros.
def _train_states(cfg: ArchCfg, batch: int):
    if cfg.block in ("xlstm", "rglru_hybrid"):
        return init_cache(cfg, batch, max_len=1)
    return None


# ==========================================================================
# forward / loss / serve
# ==========================================================================

def _embed_inputs(params, batch, cfg: ArchCfg):
    h = embeddings.encode(params["embed"], batch["tokens"]).astype(_dt(cfg))
    if cfg.n_patches:
        v = batch["patch_embeds"].astype(_dt(cfg))
        vp = params["vision_proj"]
        v = brgemm.matmul(v, vp["w1"], vp["b1"], activation="gelu")
        v = brgemm.matmul(v, vp["w2"], vp["b2"])
        h = jnp.concatenate([v, h], axis=1)
    return constrain(h, "activation")


def _head(params, h, cfg: ArchCfg):
    h = norms.rmsnorm(params["final_ln"], h)
    if cfg.tie_embeddings:
        logits = embeddings.decode(params["embed"], h)
    else:
        logits = brgemm.matmul(h, params["head"]["w"],
                               out_dtype=jnp.float32)
    return constrain(logits, "logits")


def forward(params, batch, cfg: ArchCfg, *, backend=None):
    """Train-mode forward. Returns (logits_fp32, aux)."""
    h = _embed_inputs(params, batch, cfg)
    caches = _train_states(cfg, h.shape[0])
    h, aux, _ = _run_stacks(params, h, cfg, mode="train", caches=caches,
                            pos=0, backend=backend)
    if cfg.n_patches:
        h = h[:, cfg.n_patches:]
    logits = _head(params, h, cfg)
    if cfg.mtp and "mtp_block" in params:
        h2, _, _ = blocks.decoder_block_apply(
            params["mtp_block"], h, cfg, mode="train", backend=backend)
        aux = dict(aux)
        aux["mtp_logits"] = _head(params, h2, cfg)
    return logits, aux


def _xent(logits, labels, mask):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)


def loss_fn(params, batch, cfg: ArchCfg, *, backend=None):
    logits, aux = forward(params, batch, cfg, backend=backend)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    loss = _xent(logits, labels, mask)
    metrics = {"ce_loss": loss}
    if "mtp_logits" in aux:
        # MTP: predict token t+2 (labels shifted one more step)
        mtp_loss = _xent(aux["mtp_logits"][:, :-1], labels[:, 1:],
                         mask[:, 1:])
        loss = loss + MTP_WEIGHT * mtp_loss
        metrics["mtp_loss"] = mtp_loss
    if cfg.block in ("moe", "mla_moe"):
        loss = (loss + LB_WEIGHT * aux["load_balance_loss"]
                + Z_WEIGHT * aux["router_z_loss"])
        metrics["load_balance_loss"] = aux["load_balance_loss"]
    metrics["loss"] = loss
    return loss, metrics


def prefill(params, batch, cfg: ArchCfg, cache, *, backend=None,
            logit_pos=None):
    """Returns (last-token logits, updated cache).

    ``logit_pos`` (traced int, index into the hidden sequence including any
    patch prefix) selects which position's logits to return instead of the
    last one — used by bucketed prefill, where prompts are right-padded and
    the true last token sits before the pad.
    """
    h = _embed_inputs(params, batch, cfg)
    h, _, cache = _run_stacks(params, h, cfg, mode="prefill", caches=cache,
                              pos=0, backend=backend)
    if logit_pos is None:
        h_last = h[:, -1:]
    else:
        h_last = jax.lax.dynamic_slice_in_dim(h, logit_pos, 1, axis=1)
    logits = _head(params, h_last, cfg)
    return logits[:, 0], cache


def prefill_chunk(params, batch, cfg: ArchCfg, cache, pos, *, length=None,
                  backend=None):
    """One chunk of a longer prompt: tokens at positions ``pos..pos+C-1``.

    The chunk attends causally to everything already written into
    ``cache`` (earlier chunks) plus itself, and appends its own KV at
    ``pos``.  ``length`` (traced int <= C) marks the valid prefix of a
    right-padded final chunk: logits are returned for chunk-local index
    ``length - 1``; pad positions still write KV, but they land beyond the
    prompt and every later mask (``kv_len = pos + 1``) excludes them
    exactly.  Chaining chunks therefore reproduces one-shot ``prefill``.
    Fixed chunk width => one compilation per chunk budget.
    """
    h = _embed_inputs(params, batch, cfg)
    h, _, cache = _run_stacks(params, h, cfg, mode="prefill_chunk",
                              caches=cache, pos=pos, backend=backend)
    idx = h.shape[1] - 1 if length is None else length - 1
    h_last = jax.lax.dynamic_slice_in_dim(h, idx, 1, axis=1)
    logits = _head(params, h_last, cfg)
    return logits[:, 0], cache


def decode_step(params, tokens, cfg: ArchCfg, cache, pos, *, backend=None):
    """tokens: (B, 1); pos: traced int. Returns (logits (B, V), cache)."""
    h = embeddings.encode(params["embed"], tokens).astype(_dt(cfg))
    h = constrain(h, "activation")
    h, _, cache = _run_stacks(params, h, cfg, mode="decode", caches=cache,
                              pos=pos, backend=backend)
    logits = _head(params, h, cfg)
    return logits[:, 0], cache
