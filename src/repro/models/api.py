"""Uniform model API + input specs for every (arch x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for the entry point that each shape kind
lowers: ``train_step`` for train shapes, ``prefill``/``decode_step`` for
inference shapes.  ``make_batch`` materializes small concrete batches for
smoke tests.

Modality stubs (per assignment): [vlm] patch embeddings and [audio] frame
embeddings enter as precomputed inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchCfg
from repro.configs.shapes import ShapeCfg
from repro.models import encdec, transformer


def is_encdec(cfg: ArchCfg) -> bool:
    return cfg.block == "encdec"


def get_module(cfg: ArchCfg):
    return encdec if is_encdec(cfg) else transformer


def init_params(key, cfg: ArchCfg):
    return get_module(cfg).init_params(key, cfg)


def loss_fn(params, batch, cfg: ArchCfg, **kw):
    return get_module(cfg).loss_fn(params, batch, cfg, **kw)


def forward(params, batch, cfg: ArchCfg, **kw):
    return get_module(cfg).forward(params, batch, cfg, **kw)


def prefill(params, batch, cfg: ArchCfg, cache, **kw):
    return get_module(cfg).prefill(params, batch, cfg, cache, **kw)


def decode_step(params, tokens, cfg: ArchCfg, cache, pos, **kw):
    return get_module(cfg).decode_step(params, tokens, cfg, cache, pos, **kw)


def prefill_chunk(params, batch, cfg: ArchCfg, cache, pos, *, length=None,
                  first_chunk: bool = True, **kw):
    """One chunk of a longer prompt against a batch-1 cache view.

    ``first_chunk`` is only meaningful for enc-dec (runs the encoder and
    caches cross-KV); decoder-only models ignore it.
    """
    if is_encdec(cfg):
        return encdec.prefill_chunk(params, batch, cfg, cache, pos,
                                    length=length, first_chunk=first_chunk,
                                    **kw)
    return transformer.prefill_chunk(params, batch, cfg, cache, pos,
                                     length=length, **kw)


# --------------------------------------------------------------------------
# slot-indexed decode (continuous batching)
# --------------------------------------------------------------------------

def init_cache(cfg: ArchCfg, batch: int, max_len: int, src_len: int = 0):
    """Serve cache for either module (``src_len`` only used by enc-dec)."""
    if is_encdec(cfg):
        return encdec.init_cache(cfg, batch, max_len, src_len)
    return transformer.init_cache(cfg, batch, max_len)


def cache_batch_axes(cfg: ArchCfg, max_len: int, src_len: int = 0):
    """Per-leaf batch-axis tree for the serve cache.

    The cache pytree mixes leaves whose batch dimension sits at different
    positions (layer-stacked KV leaves carry it at axis 1, grouped
    recurrent states at axis 2, ...).  Rather than hard-coding the layout
    per architecture family, diff the abstract shapes of a batch-1 and a
    batch-2 cache: the single axis whose extent changed is the batch axis.
    The result matches the cache tree structure, so it can be passed
    directly as a ``vmap`` in/out axes tree.
    """
    one = jax.eval_shape(lambda: init_cache(cfg, 1, max_len, src_len))
    two = jax.eval_shape(lambda: init_cache(cfg, 2, max_len, src_len))

    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        if len(diffs) != 1:
            raise ValueError(
                f"ambiguous batch axis for cache leaf {a.shape}: {diffs}")
        return diffs[0]

    return jax.tree.map(axis, one, two)


def decode_step_slots(params, tokens, cfg: ArchCfg, cache, positions, *,
                      batch_axes, **kw):
    """One decode step over a slot pool with per-slot positions.

    ``tokens``: (S, 1) int32 — last sampled token per slot; ``positions``:
    (S,) int32 — the absolute position each slot's token is written at;
    ``cache``: a slot pool (batch dimension = S); ``batch_axes``: the tree
    from :func:`cache_batch_axes`.  Returns (logits (S, V), new cache).

    Implemented as a vmap of the ordinary batch-1 ``decode_step`` over the
    slot dimension, so every architecture family's decode path (padded KV,
    ring buffers, compressed MLA caches, recurrent states) gets per-slot
    position/length semantics without per-family code: cache writes become
    scatters and the kv-length masks become per-slot masks under the
    batching rules.  Free slots decode garbage that is never read — their
    writes land at positions a later prefill/decode overwrites before any
    attention mask exposes them.
    """
    def one(tok, c, pos):
        c = jax.tree.map(lambda x, a: jnp.expand_dims(x, a), c, batch_axes)
        logits, c = decode_step(params, tok[None, :], cfg, c, pos, **kw)
        c = jax.tree.map(lambda x, a: jnp.squeeze(x, a), c, batch_axes)
        return logits[0], c

    return jax.vmap(one, in_axes=(0, batch_axes, 0),
                    out_axes=(0, batch_axes))(tokens, cache, positions)


# --------------------------------------------------------------------------
# paged decode (page-gather as batch-reduce over page lists)
# --------------------------------------------------------------------------

def supports_paging(cfg: ArchCfg) -> bool:
    """Whether the serve cache can be paged for this architecture.

    Paging needs every growing cache leaf to be a position-indexed KV
    tensor whose reads are masked by ``kv_len`` — true for full-attention
    decoders (dense/moe/mla_moe) and the enc-dec decoder.  Sliding-window
    ring buffers index ``pos % window`` (a page holds no stable position
    range) and recurrent states have no time axis at all, so those
    families stay on the slotted pool.
    """
    return (cfg.block in ("dense", "moe", "mla_moe", "encdec")
            and not cfg.window and not cfg.n_patches)


def cache_time_axes(cfg: ArchCfg, src_len: int = 0):
    """Per-leaf *time*-axis tree for the serve cache (-1 = not pageable).

    Discovered structurally, like :func:`cache_batch_axes`: diff the
    abstract shapes of two caches built at different ``max_len`` — the
    single axis whose extent changed with ``max_len`` is the time axis.
    Leaves whose shape does not depend on ``max_len`` (recurrent states,
    ring buffers, enc-dec cross-KV at fixed ``src_len``) get ``-1``: they
    stay slot-resident under paging.
    """
    a = jax.eval_shape(lambda: init_cache(cfg, 1, 16, src_len))
    b = jax.eval_shape(lambda: init_cache(cfg, 1, 32, src_len))

    def axis(x, y):
        diffs = [i for i, (m, n) in enumerate(zip(x.shape, y.shape))
                 if m != n]
        if not diffs:
            return -1
        if len(diffs) != 1:
            raise ValueError(
                f"ambiguous time axis for cache leaf {x.shape}: {diffs}")
        return diffs[0]

    return jax.tree.map(axis, a, b)


def pages_to_view(pages, a: int, t: int):
    """(P pages at axis ``a``, page_size at axis ``t``) -> contiguous
    batch-1 cache view with ``P * page_size`` at the time axis."""
    x = jnp.moveaxis(pages, a, t - 1)
    shape = x.shape[:t - 1] + (x.shape[t - 1] * x.shape[t],) + x.shape[t + 1:]
    return jnp.expand_dims(x.reshape(shape), a)


def view_to_pages(view, a: int, t: int, page_size: int):
    """Inverse of :func:`pages_to_view`."""
    x = jnp.squeeze(view, a)
    shape = (x.shape[:t - 1] + (x.shape[t - 1] // page_size, page_size)
             + x.shape[t:])
    return jnp.moveaxis(x.reshape(shape), t - 1, a)


def _dequant_pages(pages, scale, a: int, dtype):
    """int8 pages * per-page scale (broadcast from axis ``a``) -> dtype."""
    shape = [1] * pages.ndim
    shape[a] = pages.shape[a]
    return (pages.astype(jnp.float32) * scale.reshape(shape)).astype(dtype)


def _quant_pages(pages, a: int):
    """Per-page absmax int8: returns (q, (n_pages_axis,) fp32 scales)."""
    from repro.core.quantize import quantize
    axes = tuple(i for i in range(pages.ndim) if i != a)
    return quantize(pages, "int8", axis=axes)


def decode_step_paged(params, tokens, cfg: ArchCfg, data, page_tables,
                      positions, *, batch_axes, time_axes, page_size,
                      scales=None, view_dtypes=None, **kw):
    """One decode step over a paged pool: gather page lists, batch-reduce.

    ``data``: the pool pytree — pageable leaves hold ``n_pages`` pages at
    their batch axis and ``page_size`` at their time axis; slot-resident
    leaves (``time_axes`` == -1) hold ``n_slots`` entries at their batch
    axis.  ``page_tables``: (S, P) int32 page ids, padded with the
    sentinel ``n_pages`` past each slot's allocation.  ``positions``:
    (S,) absolute write position per slot.

    Per slot (vmapped): gather its page list (sentinels clip to page 0 —
    garbage that ``kv_len`` masking never exposes), reassemble a
    contiguous batch-1 view of length ``P * page_size``, run the ordinary
    ``decode_step``, and split the view back into pages.  Outside the
    vmap, each leaf's updated pages scatter into the pool in one
    ``mode="drop"`` write (sentinel ids fall out), so the whole step stays
    one jit-compiled call.

    ``scales``: with quantized pages, a tuple of (n_pages,) fp32 per-page
    scale arrays aligned with the pageable leaves in flatten order
    (``view_dtypes`` gives each leaf's compute dtype); dequant happens in
    the gather and fresh scales are computed in the scatter.  Returns
    (logits (S, V), new data, new scales).
    """
    data_leaves, treedef = jax.tree.flatten(data)
    a_leaves = treedef.flatten_up_to(batch_axes)
    t_leaves = treedef.flatten_up_to(time_axes)
    quant = scales is not None
    resident = tuple(x for x, t in zip(data_leaves, t_leaves) if t == -1)
    res_axes = tuple(a for a, t in zip(a_leaves, t_leaves) if t == -1)

    def one(tok, pt, res, pos):
        res_it = iter(res)
        scale_it = iter(scales or ())
        dtype_it = iter(view_dtypes or ())
        view_leaves = []
        for x, a, t in zip(data_leaves, a_leaves, t_leaves):
            if t == -1:
                view_leaves.append(jnp.expand_dims(next(res_it), a))
                continue
            ids = jnp.clip(pt, 0, x.shape[a] - 1)
            pages = jnp.take(x, ids, axis=a)
            if quant:
                pages = _dequant_pages(pages, jnp.take(next(scale_it), ids),
                                       a, next(dtype_it))
            view_leaves.append(pages_to_view(pages, a, t))
        view = jax.tree.unflatten(treedef, view_leaves)
        logits, new = decode_step(params, tok[None, :], cfg, view, pos, **kw)
        out_pages, out_res = [], []
        for x, a, t in zip(treedef.flatten_up_to(new), a_leaves, t_leaves):
            if t == -1:
                out_res.append(jnp.squeeze(x, a))
            else:
                out_pages.append(view_to_pages(x, a, t, page_size))
        return logits[0], tuple(out_pages), tuple(out_res)

    logits, pages_upd, res_upd = jax.vmap(
        one, in_axes=(0, 0, res_axes, 0),
        out_axes=(0, 0, res_axes))(tokens, page_tables, resident, positions)

    flat_ids = page_tables.reshape(-1)
    new_leaves = list(data_leaves)
    new_scales = list(scales) if quant else None
    pi = ri = 0
    for i, (x, a, t) in enumerate(zip(data_leaves, a_leaves, t_leaves)):
        if t == -1:
            new_leaves[i] = res_upd[ri]
            ri += 1
            continue
        u = jnp.moveaxis(pages_upd[pi], 0, a)       # slot axis next to pages
        u = u.reshape(u.shape[:a] + (-1,) + u.shape[a + 2:])
        if quant:
            u, sc = _quant_pages(u, a)
            new_scales[pi] = new_scales[pi].at[flat_ids].set(sc, mode="drop")
        idx = (slice(None),) * a + (flat_ids,)
        new_leaves[i] = x.at[idx].set(u.astype(x.dtype), mode="drop")
        pi += 1
    new_data = jax.tree.unflatten(treedef, new_leaves)
    if quant:
        return logits, new_data, tuple(new_scales)
    return logits, new_data, None


# --------------------------------------------------------------------------
# shape bookkeeping
# --------------------------------------------------------------------------

def encdec_src_len(cfg: ArchCfg, shape: ShapeCfg) -> int:
    if shape.kind == "train":
        return shape.seq_len // 2
    return min(4096, shape.seq_len // 8)


def token_len(cfg: ArchCfg, shape: ShapeCfg) -> int:
    """Decoder-token length for the given shape (stub prefixes deducted)."""
    if is_encdec(cfg):
        if shape.kind == "train":
            return shape.seq_len - encdec_src_len(cfg, shape)
        if shape.kind == "prefill":
            return shape.seq_len - encdec_src_len(cfg, shape)
        return shape.seq_len
    if cfg.n_patches and shape.kind in ("train", "prefill"):
        return shape.seq_len - cfg.n_patches
    return shape.seq_len


def input_specs(cfg: ArchCfg, shape: ShapeCfg):
    """ShapeDtypeStructs for the batch of the shape's entry point."""
    b = shape.global_batch
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    tl = token_len(cfg, shape)

    if shape.kind in ("train",):
        batch = {"tokens": jax.ShapeDtypeStruct((b, tl), i32),
                 "labels": jax.ShapeDtypeStruct((b, tl), i32)}
        if cfg.n_patches:
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), dt)
        if is_encdec(cfg):
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (b, encdec_src_len(cfg, shape), cfg.d_model), dt)
        return batch

    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, tl), i32)}
        if cfg.n_patches:
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), dt)
        if is_encdec(cfg):
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (b, encdec_src_len(cfg, shape), cfg.d_model), dt)
        return batch

    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    raise ValueError(shape.kind)


def cache_specs(cfg: ArchCfg, shape: ShapeCfg):
    """Abstract cache tree for serve shapes (eval_shape: no allocation)."""
    b = shape.global_batch

    def build():
        if is_encdec(cfg):
            return encdec.init_cache(
                cfg, b, shape.seq_len, encdec_src_len(cfg, shape))
        return transformer.init_cache(cfg, b, shape.seq_len)

    return jax.eval_shape(build)


def params_specs(key, cfg: ArchCfg):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def make_batch(key, cfg: ArchCfg, shape: ShapeCfg):
    """Concrete random batch (for smoke tests on reduced configs)."""
    specs = input_specs(cfg, shape)
    ks = jax.random.split(key, len(specs))
    out = {}
    for k_, (name, s) in zip(ks, sorted(specs.items())):
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(k_, s.shape, 0, cfg.vocab,
                                           jnp.int32)
        else:
            out[name] = jax.random.normal(k_, s.shape, jnp.float32).astype(
                s.dtype)
    return out
