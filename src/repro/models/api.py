"""Uniform model API + input specs for every (arch x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for the entry point that each shape kind
lowers: ``train_step`` for train shapes, ``prefill``/``decode_step`` for
inference shapes.  ``make_batch`` materializes small concrete batches for
smoke tests.

Modality stubs (per assignment): [vlm] patch embeddings and [audio] frame
embeddings enter as precomputed inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchCfg
from repro.configs.shapes import ShapeCfg
from repro.models import encdec, transformer


def is_encdec(cfg: ArchCfg) -> bool:
    return cfg.block == "encdec"


def get_module(cfg: ArchCfg):
    return encdec if is_encdec(cfg) else transformer


def init_params(key, cfg: ArchCfg):
    return get_module(cfg).init_params(key, cfg)


def loss_fn(params, batch, cfg: ArchCfg, **kw):
    return get_module(cfg).loss_fn(params, batch, cfg, **kw)


def forward(params, batch, cfg: ArchCfg, **kw):
    return get_module(cfg).forward(params, batch, cfg, **kw)


def prefill(params, batch, cfg: ArchCfg, cache, **kw):
    return get_module(cfg).prefill(params, batch, cfg, cache, **kw)


def decode_step(params, tokens, cfg: ArchCfg, cache, pos, **kw):
    return get_module(cfg).decode_step(params, tokens, cfg, cache, pos, **kw)


# --------------------------------------------------------------------------
# slot-indexed decode (continuous batching)
# --------------------------------------------------------------------------

def init_cache(cfg: ArchCfg, batch: int, max_len: int, src_len: int = 0):
    """Serve cache for either module (``src_len`` only used by enc-dec)."""
    if is_encdec(cfg):
        return encdec.init_cache(cfg, batch, max_len, src_len)
    return transformer.init_cache(cfg, batch, max_len)


def cache_batch_axes(cfg: ArchCfg, max_len: int, src_len: int = 0):
    """Per-leaf batch-axis tree for the serve cache.

    The cache pytree mixes leaves whose batch dimension sits at different
    positions (layer-stacked KV leaves carry it at axis 1, grouped
    recurrent states at axis 2, ...).  Rather than hard-coding the layout
    per architecture family, diff the abstract shapes of a batch-1 and a
    batch-2 cache: the single axis whose extent changed is the batch axis.
    The result matches the cache tree structure, so it can be passed
    directly as a ``vmap`` in/out axes tree.
    """
    one = jax.eval_shape(lambda: init_cache(cfg, 1, max_len, src_len))
    two = jax.eval_shape(lambda: init_cache(cfg, 2, max_len, src_len))

    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        if len(diffs) != 1:
            raise ValueError(
                f"ambiguous batch axis for cache leaf {a.shape}: {diffs}")
        return diffs[0]

    return jax.tree.map(axis, one, two)


def decode_step_slots(params, tokens, cfg: ArchCfg, cache, positions, *,
                      batch_axes, **kw):
    """One decode step over a slot pool with per-slot positions.

    ``tokens``: (S, 1) int32 — last sampled token per slot; ``positions``:
    (S,) int32 — the absolute position each slot's token is written at;
    ``cache``: a slot pool (batch dimension = S); ``batch_axes``: the tree
    from :func:`cache_batch_axes`.  Returns (logits (S, V), new cache).

    Implemented as a vmap of the ordinary batch-1 ``decode_step`` over the
    slot dimension, so every architecture family's decode path (padded KV,
    ring buffers, compressed MLA caches, recurrent states) gets per-slot
    position/length semantics without per-family code: cache writes become
    scatters and the kv-length masks become per-slot masks under the
    batching rules.  Free slots decode garbage that is never read — their
    writes land at positions a later prefill/decode overwrites before any
    attention mask exposes them.
    """
    def one(tok, c, pos):
        c = jax.tree.map(lambda x, a: jnp.expand_dims(x, a), c, batch_axes)
        logits, c = decode_step(params, tok[None, :], cfg, c, pos, **kw)
        c = jax.tree.map(lambda x, a: jnp.squeeze(x, a), c, batch_axes)
        return logits[0], c

    return jax.vmap(one, in_axes=(0, batch_axes, 0),
                    out_axes=(0, batch_axes))(tokens, cache, positions)


# --------------------------------------------------------------------------
# shape bookkeeping
# --------------------------------------------------------------------------

def encdec_src_len(cfg: ArchCfg, shape: ShapeCfg) -> int:
    if shape.kind == "train":
        return shape.seq_len // 2
    return min(4096, shape.seq_len // 8)


def token_len(cfg: ArchCfg, shape: ShapeCfg) -> int:
    """Decoder-token length for the given shape (stub prefixes deducted)."""
    if is_encdec(cfg):
        if shape.kind == "train":
            return shape.seq_len - encdec_src_len(cfg, shape)
        if shape.kind == "prefill":
            return shape.seq_len - encdec_src_len(cfg, shape)
        return shape.seq_len
    if cfg.n_patches and shape.kind in ("train", "prefill"):
        return shape.seq_len - cfg.n_patches
    return shape.seq_len


def input_specs(cfg: ArchCfg, shape: ShapeCfg):
    """ShapeDtypeStructs for the batch of the shape's entry point."""
    b = shape.global_batch
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    tl = token_len(cfg, shape)

    if shape.kind in ("train",):
        batch = {"tokens": jax.ShapeDtypeStruct((b, tl), i32),
                 "labels": jax.ShapeDtypeStruct((b, tl), i32)}
        if cfg.n_patches:
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), dt)
        if is_encdec(cfg):
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (b, encdec_src_len(cfg, shape), cfg.d_model), dt)
        return batch

    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, tl), i32)}
        if cfg.n_patches:
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), dt)
        if is_encdec(cfg):
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (b, encdec_src_len(cfg, shape), cfg.d_model), dt)
        return batch

    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    raise ValueError(shape.kind)


def cache_specs(cfg: ArchCfg, shape: ShapeCfg):
    """Abstract cache tree for serve shapes (eval_shape: no allocation)."""
    b = shape.global_batch

    def build():
        if is_encdec(cfg):
            return encdec.init_cache(
                cfg, b, shape.seq_len, encdec_src_len(cfg, shape))
        return transformer.init_cache(cfg, b, shape.seq_len)

    return jax.eval_shape(build)


def params_specs(key, cfg: ArchCfg):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def make_batch(key, cfg: ArchCfg, shape: ShapeCfg):
    """Concrete random batch (for smoke tests on reduced configs)."""
    specs = input_specs(cfg, shape)
    ks = jax.random.split(key, len(specs))
    out = {}
    for k_, (name, s) in zip(ks, sorted(specs.items())):
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(k_, s.shape, 0, cfg.vocab,
                                           jnp.int32)
        else:
            out[name] = jax.random.normal(k_, s.shape, jnp.float32).astype(
                s.dtype)
    return out
