"""GNMT-style stacked-LSTM language model (paper Sec. 4.2.1 workload).

4 LSTM layers by default (the paper's 4-layer GNMT); every GEMM inside the
cells is the batch-reduce building block (layers/lstm.py, Alg 2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import brgemm
from repro.layers import embeddings, lstm


@dataclasses.dataclass(frozen=True)
class LSTMLMCfg:
    vocab: int = 1024
    d_model: int = 256
    n_layers: int = 4
    dtype: str = "float32"


def init_params(key, cfg: LSTMLMCfg):
    ks = jax.random.split(key, cfg.n_layers + 1)
    dt = jnp.dtype(cfg.dtype)
    return {
        "embed": embeddings.init(ks[0], cfg.vocab, cfg.d_model, dtype=dt),
        "layers": [lstm.init(ks[i + 1], cfg.d_model, cfg.d_model, dtype=dt)
                   for i in range(cfg.n_layers)],
    }


def forward(params, tokens, cfg: LSTMLMCfg, *, backend=None):
    """tokens: (B, T) -> logits (B, T, vocab)."""
    x = embeddings.encode(params["embed"], tokens)   # (B, T, D)
    h = x.transpose(1, 0, 2)                         # (T, B, D) for scan
    for lp in params["layers"]:
        out, _ = lstm.forward(lp, h, backend=backend)
        h = h + out                                   # residual stack
    h = h.transpose(1, 0, 2)
    return embeddings.decode(params["embed"], h, backend=backend)


def loss_fn(params, batch, cfg: LSTMLMCfg, *, backend=None):
    logits = forward(params, batch["tokens"], cfg, backend=backend)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = -ll.mean()
    return loss, {"loss": loss}
