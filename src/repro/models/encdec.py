"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder: bidirectional self-attention blocks over stub frame embeddings
(the audio frontend is a stub per the assignment).  Decoder: causal
self-attention + cross-attention to the encoder memory.  Serving caches the
decoder self-attention KV plus the per-layer cross K/V computed once at
prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchCfg
from repro.core import brgemm
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import mha_ref
from repro.layers import attention, embeddings, mlp, norms
from repro.models import blocks
from repro.models.transformer import _stack_init, _stack_tree
from repro.sharding.annotate import constrain


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _cross_init(key, cfg: ArchCfg, dtype):
    acfg = blocks.attn_cfg(cfg)
    return attention.init(key, acfg, dtype)


def _enc_block_init(key, cfg: ArchCfg):
    ks = jax.random.split(key, 2)
    dt = _dt(cfg)
    return {
        "ln1": norms.rmsnorm_init(cfg.d_model, dt),
        "attn": attention.init(ks[0], blocks.attn_cfg(cfg), dt),
        "ln2": norms.rmsnorm_init(cfg.d_model, dt),
        "mlp": mlp.init(ks[1], cfg.d_model, cfg.d_ff,
                        gated=cfg.gated_mlp, dtype=dt),
    }


def _dec_block_init(key, cfg: ArchCfg):
    ks = jax.random.split(key, 3)
    dt = _dt(cfg)
    return {
        "ln1": norms.rmsnorm_init(cfg.d_model, dt),
        "self_attn": attention.init(ks[0], blocks.attn_cfg(cfg), dt),
        "ln_x": norms.rmsnorm_init(cfg.d_model, dt),
        "cross_attn": _cross_init(ks[1], cfg, dt),
        "ln2": norms.rmsnorm_init(cfg.d_model, dt),
        "mlp": mlp.init(ks[2], cfg.d_model, cfg.d_ff,
                        gated=cfg.gated_mlp, dtype=dt),
    }


def init_params(key, cfg: ArchCfg):
    ks = jax.random.split(key, 4)
    dt = _dt(cfg)
    p = {
        "embed": embeddings.init(ks[0], cfg.vocab, cfg.d_model, dtype=dt),
        "enc_blocks": _stack_init(
            ks[1], cfg.n_enc_layers, lambda k: _enc_block_init(k, cfg)),
        "dec_blocks": _stack_init(
            ks[2], cfg.n_layers, lambda k: _dec_block_init(k, cfg)),
        "enc_ln": norms.rmsnorm_init(cfg.d_model, dt),
        "final_ln": norms.rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["head"] = {"w": (jax.random.normal(ks[3],
                                             (cfg.d_model, cfg.vocab),
                                             jnp.float32)
                           * cfg.d_model ** -0.5).astype(dt)}
    return p


def _cross_kv(params, memory, cfg, backend):
    acfg = blocks.attn_cfg(cfg)
    k = attention._split_heads(
        brgemm.matmul(memory, params["wk"], backend=backend),
        acfg.n_kv_heads)
    v = attention._split_heads(
        brgemm.matmul(memory, params["wv"], backend=backend),
        acfg.n_kv_heads)
    return k, v


def _cross_apply(params, x, k, v, cfg, backend):
    acfg = blocks.attn_cfg(cfg)
    q = attention._split_heads(
        brgemm.matmul(x, params["wq"], backend=backend), acfg.n_heads)
    if x.shape[1] == 1:
        o = mha_ref(q, k, v, causal=False)
    else:
        o = flash_attention(q, k, v, causal=False, backend=backend,
                            xla_impl=cfg.attention_impl,
                            unroll=cfg.scan_unroll)
    return brgemm.matmul(attention._merge_heads(o), params["wo"],
                         backend=backend)


def encode(params, src_embeds, cfg: ArchCfg, *, backend=None):
    x = constrain(src_embeds.astype(_dt(cfg)), "activation")
    acfg = blocks.attn_cfg(cfg)

    def body(x, p):
        h = norms.rmsnorm(p["ln1"], x)
        q = attention._split_heads(
            brgemm.matmul(h, p["attn"]["wq"], backend=backend), acfg.n_heads)
        k = attention._split_heads(
            brgemm.matmul(h, p["attn"]["wk"], backend=backend),
            acfg.n_kv_heads)
        v = attention._split_heads(
            brgemm.matmul(h, p["attn"]["wv"], backend=backend),
            acfg.n_kv_heads)
        o = flash_attention(q, k, v, causal=False, backend=backend,
                            xla_impl=cfg.attention_impl,
                            unroll=cfg.scan_unroll)
        x = x + brgemm.matmul(attention._merge_heads(o), p["attn"]["wo"],
                              backend=backend)
        x = x + mlp.apply(p["mlp"], norms.rmsnorm(p["ln2"], x),
                          activation=cfg.mlp_activation, backend=backend)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                        unroll=cfg.scan_unroll)
    return norms.rmsnorm(params["enc_ln"], x)


def _dec_block_apply(p, x, memory, cfg, *, mode, cache, pos, backend,
                     cross_kv=None):
    acfg = blocks.attn_cfg(cfg)
    h = norms.rmsnorm(p["ln1"], x)
    if mode == "train":
        x = x + attention.apply(p["self_attn"], h, acfg, mode="train",
                                backend=backend)
        new_cache = cache
    else:
        y, new_cache = attention.apply(p["self_attn"], h, acfg, mode=mode,
                                       cache=cache, pos=pos, backend=backend)
        x = x + y
    h = norms.rmsnorm(p["ln_x"], x)
    if cross_kv is None:
        k, v = _cross_kv(p["cross_attn"], memory, cfg, backend)
    else:
        k, v = cross_kv
    x = x + _cross_apply(p["cross_attn"], h, k, v, cfg, backend)
    x = x + mlp.apply(p["mlp"], norms.rmsnorm(p["ln2"], x),
                      activation=cfg.mlp_activation, backend=backend)
    return x, new_cache


def _head(params, h, cfg):
    h = norms.rmsnorm(params["final_ln"], h)
    if cfg.tie_embeddings:
        return embeddings.decode(params["embed"], h)
    return brgemm.matmul(h, params["head"]["w"], out_dtype=jnp.float32)


def forward(params, batch, cfg: ArchCfg, *, backend=None):
    """Train forward. batch: {src_embeds, tokens, labels}."""
    memory = encode(params, batch["src_embeds"], cfg, backend=backend)
    x = embeddings.encode(params["embed"], batch["tokens"]).astype(_dt(cfg))
    x = constrain(x, "activation")

    def body(x, p):
        x, _ = _dec_block_apply(p, x, memory, cfg, mode="train", cache=None,
                                pos=0, backend=backend)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"],
                        unroll=cfg.scan_unroll)
    return _head(params, x, cfg), {}


def loss_fn(params, batch, cfg: ArchCfg, *, backend=None):
    logits, _ = forward(params, batch, cfg, backend=backend)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return loss, {"loss": loss, "ce_loss": loss}


def init_cache(cfg: ArchCfg, batch: int, max_len: int, src_len: int):
    acfg = blocks.attn_cfg(cfg)
    dh = acfg.dh
    self_c = attention.init_cache(acfg, batch, max_len, _dt(cfg))
    cross = {
        "k": jnp.zeros((batch, acfg.n_kv_heads, src_len, dh), _dt(cfg)),
        "v": jnp.zeros((batch, acfg.n_kv_heads, src_len, dh), _dt(cfg)),
    }
    return {"self": _stack_tree(self_c, cfg.n_layers),
            "cross": _stack_tree(cross, cfg.n_layers)}


def prefill(params, batch, cfg: ArchCfg, cache, *, backend=None,
            logit_pos=None):
    """Encode src, cache cross-KV, prefill decoder self-attn cache.

    ``logit_pos`` (traced int) selects which decoder position's logits to
    return instead of the last one (bucketed right-padded prefill)."""
    memory = encode(params, batch["src_embeds"], cfg, backend=backend)
    x = embeddings.encode(params["embed"], batch["tokens"]).astype(_dt(cfg))

    def body(x, xs):
        p, c = xs
        k, v = _cross_kv(p["cross_attn"], memory, cfg, backend)
        x, self_c = _dec_block_apply(
            p, x, memory, cfg, mode="prefill", cache=c["self"], pos=0,
            backend=backend, cross_kv=(k, v))
        return x, {"self": self_c,
                   "cross": {"k": k.astype(c["cross"]["k"].dtype),
                             "v": v.astype(c["cross"]["v"].dtype)}}

    x, new_cache = jax.lax.scan(
        body, x, (params["dec_blocks"],
                  {"self": cache["self"], "cross": cache["cross"]}),
        unroll=cfg.scan_unroll)
    if logit_pos is None:
        x_last = x[:, -1:]
    else:
        x_last = jax.lax.dynamic_slice_in_dim(x, logit_pos, 1, axis=1)
    logits = _head(params, x_last, cfg)
    return logits[:, 0], new_cache


def prefill_chunk(params, batch, cfg: ArchCfg, cache, pos, *, length=None,
                  first_chunk: bool = True, backend=None):
    """One decoder-prompt chunk at positions ``pos..pos+C-1``.

    ``first_chunk`` (static) runs the encoder and writes the per-layer
    cross-KV into the cache; later chunks reuse the cached cross-KV and
    need no ``src_embeds``.  Self-attention uses the chunked causal path
    against the cache; cross-attention always sees the full encoder
    memory.  ``length`` as in ``transformer.prefill_chunk``.
    """
    memory = (encode(params, batch["src_embeds"], cfg, backend=backend)
              if first_chunk else None)
    x = embeddings.encode(params["embed"], batch["tokens"]).astype(_dt(cfg))

    def body(x, xs):
        p, c = xs
        if first_chunk:
            k, v = _cross_kv(p["cross_attn"], memory, cfg, backend)
        else:
            k, v = c["cross"]["k"], c["cross"]["v"]
        x, self_c = _dec_block_apply(
            p, x, memory, cfg, mode="prefill_chunk", cache=c["self"],
            pos=pos, backend=backend, cross_kv=(k, v))
        return x, {"self": self_c,
                   "cross": {"k": k.astype(c["cross"]["k"].dtype),
                             "v": v.astype(c["cross"]["v"].dtype)}}

    x, new_cache = jax.lax.scan(
        body, x, (params["dec_blocks"],
                  {"self": cache["self"], "cross": cache["cross"]}),
        unroll=cfg.scan_unroll)
    idx = x.shape[1] - 1 if length is None else length - 1
    x_last = jax.lax.dynamic_slice_in_dim(x, idx, 1, axis=1)
    logits = _head(params, x_last, cfg)
    return logits[:, 0], new_cache


def decode_step(params, tokens, cfg: ArchCfg, cache, pos, *, backend=None):
    x = embeddings.encode(params["embed"], tokens).astype(_dt(cfg))

    def body(x, xs):
        p, c = xs
        x, self_c = _dec_block_apply(
            p, x, None, cfg, mode="decode", cache=c["self"], pos=pos,
            backend=backend, cross_kv=(c["cross"]["k"], c["cross"]["v"]))
        return x, {"self": self_c, "cross": c["cross"]}

    x, new_cache = jax.lax.scan(
        body, x, (params["dec_blocks"],
                  {"self": cache["self"], "cross": cache["cross"]}),
        unroll=cfg.scan_unroll)
    logits = _head(params, x, cfg)
    return logits[:, 0], new_cache
