"""Pallas TPU kernels: batch-reduce GEMM (the paper's building block),
direct convolution, and flash attention — each with kernel.py (pl.pallas_call
+ BlockSpec), ops.py (jit'd wrapper + custom VJP + backend dispatch), and
ref.py (pure-jnp oracle)."""
