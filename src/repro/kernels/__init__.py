"""Pallas TPU kernels: batch-reduce GEMM (the paper's building block),
direct convolution, and flash attention — each with kernel.py (pl.pallas_call
+ BlockSpec), ops.py (jit'd wrapper + custom VJP), and ref.py (pure-jnp
oracle).

Importing this package registers every op's backends in the
``repro.core.dispatch`` registry (the ops modules self-register at import
time); ``dispatch`` imports it lazily on first resolution.
"""
from repro.kernels.brgemm.ops import (  # noqa: F401
    batched_matmul,
    brgemm,
    matmul,
)
from repro.kernels.conv2d.ops import conv2d  # noqa: F401
from repro.kernels.flash_attention.ops import (  # noqa: F401
    flash_attention,
    flash_attention_bwd,
)
