"""Pure-jnp attention oracles (GQA, causal, sliding-window, offset).

``mha_ref``      — naive full-T^2 softmax (the semantic oracle).
``mha_chunked``  — online-softmax over KV chunks: the *same math as the
Pallas flash kernel*, expressed in lax.scan so the XLA path never
materializes the (Tq, Tk) score matrix.  This is the memory-term
optimization of §Perf iteration 3 (and doubles as a second oracle for the
Pallas kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_ref(q, k, v, *, causal: bool = True, window: int | None = None,
            scale: float | None = None, q_offset: int = 0,
            kv_len: int | None = None):
    """q: (B, Hq, Tq, d); k, v: (B, Hkv, Tk, d). Hq % Hkv == 0.

    ``q_offset``: absolute position of q[0] (decode: Tq=1, offset=pos).
    ``kv_len``: number of valid kv positions (for padded decode caches).
    ``window``: sliding-window size (positions < pos-window+1 masked).
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)

    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    q_pos = q_offset + jnp.arange(tq)[:, None]
    k_pos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    if kv_len is not None:
        mask &= k_pos < kv_len
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def mha_chunked(q, k, v, *, causal: bool = True, window: int | None = None,
                scale: float | None = None, chunk: int = 1024,
                unroll: bool = False):
    """Online-softmax attention over KV chunks (flash semantics, pure jnp).

    Peak intermediate is (B, Hq, Tq, chunk) instead of (B, Hq, Tq, Tk).
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)

    c = min(chunk, tk)
    pad = (-tk) % c
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = (tk + pad) // c
    kc = k.reshape(b, hq, nc, c, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hq, nc, c, d).transpose(2, 0, 1, 3, 4)
    k_pos = jnp.arange(nc * c).reshape(nc, c)
    q_pos = jnp.arange(tq)[:, None]

    def step(carry, xs):
        acc, m, l = carry
        kb, vb, kp = xs
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kb,
                       preferred_element_type=jnp.float32) * scale
        mask = kp[None, :] < tk
        if causal:
            mask &= kp[None, :] <= q_pos
        if window is not None:
            mask &= kp[None, :] > q_pos - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = corr * l + p.sum(axis=-1, keepdims=True)
        acc = corr * acc + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, hq, tq, d), jnp.float32)
    m0 = jnp.full((b, hq, tq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, tq, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (kc, vc, k_pos),
                                  unroll=unroll)
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l).astype(q.dtype)
