"""Fused Pallas flash-attention backward — the training-side building block.

The paper's Sec. 3 point is that backward passes are not special cases:
backward-by-data and weight-update are the *same* batch-reduce GEMM loop
with reindexed operands.  FlashAttention's recompute backward has exactly
that structure, so all three gradient kernels here are the forward kernel's
loop nest with the roles of the axes swapped:

  * dQ — outer loop over Q blocks, batch-reduce over K blocks
    (dQ += dS K).  Its first reduce step also computes
    ``delta = rowsum(dY ∘ Y)`` (the softmax-Jacobian correction term)
    into VMEM scratch — dY and Y are already resident for dS — and emits
    it as a second output, so delta costs no extra pass over HBM,
  * dK/dV — outer loop over K blocks, batch-reduce over Q blocks
    (dV += P^T dY, dK += dS^T Q accumulate in VMEM scratch across the
    whole Q axis and hit HBM once), consuming dQ's delta output.

The pre-fusion standalone delta kernel survives as
:func:`delta_rowsum_pallas`, the interpret-mode parity oracle for the
fused path.

No online-softmax recompute: the forward saved the per-row log-sum-exp, so
each score block rebuilds its softmax as ``P = exp(S - lse)`` in one shot.
GQA stays zero-copy through the K/V index_map (h -> h // group); the group
reduction of dK/dV over the q-heads sharing a kv-head happens host-side on
the fp32 kernel outputs.  Causal/window masking skips whole blocks exactly
like the forward, plus an explicit ``q_pos < tq`` guard: padded query rows
carry garbage lse, and only the mask keeps them out of the reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import dispatch
from repro.core import pallas_compat as _pc
from repro.core.blocking import AttnBwdBlocks, round_up
from repro.kernels.flash_attention.kernel import STATS_LANES


def _mask(q_start, k_start, bq, bk, tq, tk, causal, window):
    """Validity mask for one (bq, bk) score block, including padded rows."""
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (q_pos < tq) & (k_pos < tk)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask


def _block_live(q_start, k_start, bq, bk, causal, window):
    """Whether any (q, k) pair in the block can be unmasked — the same
    whole-block skip the forward kernel uses, extended to the window's
    lower bound.  Returns None when every block is live (dense case)."""
    cond = None
    if causal:
        cond = k_start <= q_start + bq - 1
    if window is not None:
        wcond = k_start + bk - 1 > q_start - window
        cond = wcond if cond is None else cond & wcond
    return cond


def _delta_body(y_ref, dy_ref, delta_ref):
    prod = (y_ref[0, 0].astype(jnp.float32)
            * dy_ref[0, 0].astype(jnp.float32))
    delta_ref[...] = jnp.broadcast_to(
        prod.sum(axis=-1, keepdims=True),
        delta_ref.shape[2:])[None, None]


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def delta_rowsum_pallas(y, dy, *, block_q: int = 128,
                        interpret: bool = False):
    """Standalone ``delta = rowsum(dY ∘ Y)`` pass over Q blocks.

    Superseded in the fused backward — the dQ kernel's first reduce step
    now computes delta in-kernel from its resident dY/Y panels, dropping
    this kernel's full HBM pass — but kept as the interpret-mode parity
    oracle for that fusion.  Returns (B, Hq, Tq) fp32.
    """
    b, hq, tq, d = y.shape
    bq = min(round_up(tq, 8), block_q)
    tqp, dp = round_up(tq, bq), round_up(d, 128)
    yp = jnp.pad(y, ((0, 0), (0, 0), (0, tqp - tq), (0, dp - d)))
    dyp = jnp.pad(dy, ((0, 0), (0, 0), (0, tqp - tq), (0, dp - d)))
    dspec = pl.BlockSpec((1, 1, bq, dp), lambda b_, h, i: (b_, h, i, 0))
    delta = pl.pallas_call(
        _delta_body,
        grid=(b, hq, tqp // bq),
        in_specs=[dspec, dspec],
        out_specs=pl.BlockSpec((1, 1, bq, STATS_LANES),
                               lambda b_, h, i: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tqp, STATS_LANES),
                                       jnp.float32),
        compiler_params=_pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(yp, dyp)
    return delta[:, :, :tq, 0]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "blocks", "interpret",
                     "acc_dtype", "return_delta"),
)
def flash_attention_bwd_pallas(
    q,
    k,
    v,
    y,
    lse,
    dy,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    blocks: AttnBwdBlocks | None = None,
    interpret: bool = False,
    acc_dtype=jnp.float32,
    return_delta: bool = False,
):
    """Fused backward: (dq, dk, dv) from the forward's (y, lse) residuals.

    q, dy, y: (B, Hq, Tq, d); k, v: (B, Hkv, Tk, d); lse: (B, Hq, Tq)
    fp32.  Tile geometry comes from ``blocks`` (an ``AttnBwdBlocks``);
    when unset it resolves through ``dispatch.resolve_blocks`` under the
    active block policy — tuned independently of the forward tile.  Score
    and dS blocks are fp32; ``acc_dtype`` governs the dq/dk/dv
    accumulators (``repro.use(accum_dtype=...)`` reaches here through the
    dispatch layer).

    ``delta = rowsum(dY ∘ Y)`` is fused into dQ's first reduce step (no
    standalone pass over dY/Y); ``return_delta=True`` appends the fused
    (B, Hq, Tq) delta to the outputs for parity testing against
    :func:`delta_rowsum_pallas`.
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    blk = blocks or dispatch.resolve_blocks(
        "flash_attention_bwd", tq, tk, d, q.dtype, backend="pallas")
    bq = min(round_up(tq, 8), blk.block_q)
    bk = min(round_up(tk, 128), blk.block_k)
    tqp, tkp = round_up(tq, bq), round_up(tk, bk)
    dp = round_up(d, 128)
    nq, nk = tqp // bq, tkp // bk

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, tqp - tq), (0, dp - d)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, tkp - tk), (0, dp - d)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, tkp - tk), (0, dp - d)))
    yp = jnp.pad(y, ((0, 0), (0, 0), (0, tqp - tq), (0, dp - d)))
    dyp = jnp.pad(dy, ((0, 0), (0, 0), (0, tqp - tq), (0, dp - d)))
    # lse rides in the forward's stats layout: broadcast across lanes so
    # the (1, 1, bq, STATS_LANES) block is TPU-legal; padded rows are
    # masked in-kernel so their value never matters.
    lsep = jnp.pad(lse.astype(jnp.float32),
                   ((0, 0), (0, 0), (0, tqp - tq)))
    lsep = jnp.broadcast_to(lsep[..., None], (b, hq, tqp, STATS_LANES))

    def _specs(qi, kj, tail):
        """in_specs for (q, k, v, dy, lse, *tail) given which of the two
        inner grid axes indexes Q blocks (qi) and K blocks (kj); ``tail``
        names extra row-shaped ("row") or stats-shaped ("stats") inputs."""
        row = pl.BlockSpec((1, 1, bq, dp),
                           lambda b_, h, g0, g1: (b_, h, qi(g0, g1), 0))
        stats = pl.BlockSpec((1, 1, bq, STATS_LANES),
                             lambda b_, h, g0, g1: (b_, h, qi(g0, g1), 0))
        kv = pl.BlockSpec(
            (1, 1, bk, dp),
            lambda b_, h, g0, g1: (b_, h // group, kj(g0, g1), 0))
        named = {"row": row, "stats": stats}
        return [row, kv, kv, row, stats] + [named[t] for t in tail]

    # ---- shared score-block recompute -----------------------------------

    def _p_ds(q_ref, k_ref, v_ref, dy_ref, lse_ref, delta_col,
              q_start, k_start):
        """Rebuild P = exp(S - lse) and dS for one (bq, bk) block;
        ``delta_col`` is the (bq, 1) softmax-Jacobian correction."""
        qb = q_ref[0, 0]
        kb = k_ref[0, 0]
        s = jax.lax.dot_general(
            qb, kb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = _mask(q_start, k_start, bq, bk, tq, tk, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse_ref[0, 0][:, :1]), 0.0)
        dp_ = jax.lax.dot_general(
            dy_ref[0, 0], v_ref[0, 0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        ds = p * (dp_ - delta_col) * scale
        return qb, kb, p, ds

    # ---- dQ (+ fused delta): outer over Q, batch-reduce over K ----------

    def dq_body(q_ref, k_ref, v_ref, dy_ref, lse_ref, y_ref,
                dq_ref, delta_ref, dq_acc, delta_acc):
        i, j = pl.program_id(2), pl.program_id(3)
        q_start, k_start = i * bq, j * bk

        @pl.when(j == 0)
        def _():
            dq_acc[...] = jnp.zeros_like(dq_acc)
            # delta = rowsum(dY ∘ Y) rides with the first reduce step:
            # the dY panel is already resident for dS, Y replaces the
            # delta input this kernel used to read.  Unconditional (not
            # under _block_live) — dK/dV needs delta for every Q row,
            # including rows whose (i, j) score block is masked here.
            prod = (y_ref[0, 0].astype(jnp.float32)
                    * dy_ref[0, 0].astype(jnp.float32))
            delta_acc[...] = jnp.broadcast_to(
                prod.sum(axis=-1, keepdims=True), delta_acc.shape)

        def compute():
            _, kb, _, ds = _p_ds(q_ref, k_ref, v_ref, dy_ref, lse_ref,
                                 delta_acc[:, :1], q_start, k_start)
            dq_acc[...] += jax.lax.dot_general(
                ds.astype(kb.dtype), kb, (((1,), (0,)), ((), ())),
                preferred_element_type=acc_dtype).astype(acc_dtype)

        live = _block_live(q_start, k_start, bq, bk, causal, window)
        if live is None:
            compute()
        else:
            pl.when(live)(compute)

        @pl.when(j == nk - 1)
        def _():
            dq_ref[...] = dq_acc[...].astype(jnp.float32)[None, None]
            delta_ref[...] = delta_acc[...][None, None]

    dq, delta = pl.pallas_call(
        dq_body,
        grid=(b, hq, nq, nk),
        in_specs=_specs(qi=lambda i, j: i, kj=lambda i, j: j,
                        tail=("row",)),
        out_specs=[
            pl.BlockSpec((1, 1, bq, dp),
                         lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq, STATS_LANES),
                         lambda b_, h, i, j: (b_, h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, tqp, dp), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, tqp, STATS_LANES), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, dp), acc_dtype),
            pltpu.VMEM((bq, STATS_LANES), jnp.float32),
        ],
        compiler_params=_pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(qp, kp, vp, dyp, lsep, yp)

    # ---- dK/dV: outer over K blocks, batch-reduce over Q blocks ---------

    def dkdv_body(q_ref, k_ref, v_ref, dy_ref, lse_ref, delta_ref,
                  dk_ref, dv_ref, dk_acc, dv_acc):
        j, i = pl.program_id(2), pl.program_id(3)
        q_start, k_start = i * bq, j * bk

        @pl.when(i == 0)
        def _():
            dk_acc[...] = jnp.zeros_like(dk_acc)
            dv_acc[...] = jnp.zeros_like(dv_acc)

        def compute():
            qb, _, p, ds = _p_ds(q_ref, k_ref, v_ref, dy_ref, lse_ref,
                                 delta_ref[0, 0][:, :1], q_start, k_start)
            dv_acc[...] += jax.lax.dot_general(
                p.astype(v_ref.dtype), dy_ref[0, 0],
                (((0,), (0,)), ((), ())),
                preferred_element_type=acc_dtype).astype(acc_dtype)
            dk_acc[...] += jax.lax.dot_general(
                ds.astype(qb.dtype), qb, (((0,), (0,)), ((), ())),
                preferred_element_type=acc_dtype).astype(acc_dtype)

        live = _block_live(q_start, k_start, bq, bk, causal, window)
        if live is None:
            compute()
        else:
            pl.when(live)(compute)

        @pl.when(i == nq - 1)
        def _():
            dk_ref[...] = dk_acc[...].astype(jnp.float32)[None, None]
            dv_ref[...] = dv_acc[...].astype(jnp.float32)[None, None]

    dk, dv = pl.pallas_call(
        dkdv_body,
        grid=(b, hq, nk, nq),
        in_specs=_specs(qi=lambda j, i: i, kj=lambda j, i: j,
                        tail=("stats",)),
        out_specs=[
            pl.BlockSpec((1, 1, bk, dp),
                         lambda b_, h, j, i: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, bk, dp),
                         lambda b_, h, j, i: (b_, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, tkp, dp), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, tkp, dp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, dp), acc_dtype),
            pltpu.VMEM((bk, dp), acc_dtype),
        ],
        compiler_params=_pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(qp, kp, vp, dyp, lsep, delta)

    dq = dq[:, :, :tq, :d]
    dk = dk[:, :, :tk, :d]
    dv = dv[:, :, :tk, :d]
    if group > 1:
        # GQA: kv-head gradients sum over the q-heads sharing the head.
        dk = dk.reshape(b, hkv, group, tk, d).sum(axis=2)
        dv = dv.reshape(b, hkv, group, tk, d).sum(axis=2)
    out = (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))
    if return_delta:
        return out + (delta[:, :, :tq, 0],)
    return out
