"""FlashAttention as a batch-reduce GEMM — the beyond-paper unification.

Online-softmax attention is *exactly* the paper's kernel with a rescaling
epilogue: the output block O accumulates `sum_j P_j @ V_j` over KV blocks
(the reduce batch), with the running-max/denominator correction applied to
the VMEM-resident accumulator between steps.  Structure shared with
``kernels/brgemm``:

  * grid = (batch, q_heads, q_blocks, kv_blocks); last axis "arbitrary",
  * fp32 accumulator + (m, l) running statistics in VMEM scratch,
  * GQA is zero-copy: the K/V BlockSpec index_map maps q-head -> kv-head
    (h // group) — the paper's pointer-list trick again,
  * causal/sliding-window masks applied in-register on the scores block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import dispatch
from repro.core import pallas_compat as _pc
from repro.core.blocking import AttnBlocks, round_up

NEG_INF = -1e30
STATS_LANES = 128


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "blocks", "interpret",
                     "acc_dtype", "return_residuals"),
)
def flash_attention_pallas(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    blocks: AttnBlocks | None = None,
    interpret: bool = False,
    acc_dtype=jnp.float32,
    return_residuals: bool = False,
):
    """q: (B, Hq, Tq, d); k, v: (B, Hkv, Tk, d) -> (B, Hq, Tq, d).

    Tile geometry comes from ``blocks`` (an ``AttnBlocks``); when unset it
    resolves through ``dispatch.resolve_blocks`` under the active block
    policy — the kernel itself makes no geometry choices.  The running
    softmax statistics (m, l) always stay fp32; ``acc_dtype`` governs the
    output accumulator only.

    With ``return_residuals=True`` the kernel additionally emits the
    per-row log-sum-exp statistics ``lse = m + log(l)`` (fp32,
    (B, Hq, Tq)) — the VJP residual that lets the fused backward kernels
    rebuild the softmax blocks without re-running the online reduction.
    Fully-masked rows get ``lse = NEG_INF`` (log-sum-exp of an empty set).
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    blk = blocks or dispatch.resolve_blocks(
        "flash_attention", tq, tk, d, q.dtype, backend="pallas")
    bq = min(round_up(tq, 8), blk.block_q)
    bk = min(round_up(tk, 128), blk.block_k)
    tqp, tkp = round_up(tq, bq), round_up(tk, bk)
    dp = round_up(d, 128)

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, tqp - tq), (0, dp - d)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, tkp - tk), (0, dp - d)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, tkp - tk), (0, dp - d)))

    grid = (b, hq, tqp // bq, tkp // bk)
    nkv = tkp // bk

    def body(q_ref, k_ref, v_ref, o_ref, *rest):
        if return_residuals:
            lse_ref, acc_ref, m_ref, l_ref = rest
        else:
            lse_ref, (acc_ref, m_ref, l_ref) = None, rest
        j = pl.program_id(3)

        @pl.when(j == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        i = pl.program_id(2)
        q_start = i * bq
        k_start = j * bk

        def compute():
            qb = q_ref[0, 0]          # (bq, dp)
            kb = k_ref[0, 0]          # (bk, dp)
            s = jax.lax.dot_general(
                qb, kb, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale  # (bq, bk)

            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = k_pos < tk  # padded kv positions
            if causal:
                mask &= k_pos <= q_pos
            if window is not None:
                mask &= k_pos > q_pos - window
            s = jnp.where(mask, s, NEG_INF)

            m_prev = m_ref[:, :1]                       # (bq, 1)
            l_prev = l_ref[:, :1]
            m_cur = s.max(axis=-1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new)                      # (bq, bk)
            corr = jnp.exp(m_prev - m_new)              # (bq, 1)
            l_new = corr * l_prev + p.sum(axis=-1, keepdims=True)
            pv = jax.lax.dot_general(
                p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
                preferred_element_type=acc_dtype)
            acc_ref[...] = (acc_ref[...] * corr.astype(acc_dtype)
                            + pv).astype(acc_dtype)
            m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
            l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

        if causal:
            # Skip blocks strictly above the diagonal (no valid positions).
            @pl.when(k_start <= q_start + bq - 1)
            def _():
                compute()
        else:
            compute()

        @pl.when(j == nkv - 1)
        def _():
            l = l_ref[:, :1]
            lsafe = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 out
            o_ref[...] = (acc_ref[...].astype(jnp.float32)
                          / lsafe).astype(o_ref.dtype)[None, None]
            if lse_ref is not None:
                lse = jnp.where(l > 0.0, m_ref[:, :1] + jnp.log(lsafe),
                                NEG_INF)
                lse_ref[...] = jnp.broadcast_to(
                    lse, lse_ref.shape[2:])[None, None]

    q_spec = pl.BlockSpec((1, 1, bq, dp), lambda b_, h, i, j: (b_, h, i, 0))
    out_specs = [q_spec]
    out_shape = [jax.ShapeDtypeStruct((b, hq, tqp, dp), q.dtype)]
    if return_residuals:
        out_specs.append(pl.BlockSpec((1, 1, bq, STATS_LANES),
                                      lambda b_, h, i, j: (b_, h, i, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((b, hq, tqp, STATS_LANES), jnp.float32))

    outs = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            q_spec,
            pl.BlockSpec((1, 1, bk, dp),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, dp),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq, dp), acc_dtype),
            pltpu.VMEM((bq, STATS_LANES), jnp.float32),
            pltpu.VMEM((bq, STATS_LANES), jnp.float32),
        ],
        compiler_params=_pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(qp, kp, vp)
    out = outs[0][:, :, :tq, :d]
    if return_residuals:
        return out, outs[1][:, :, :tq, 0]
    return out
