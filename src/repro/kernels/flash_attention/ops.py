"""Differentiable flash-attention entry point with backend dispatch.

Backward uses the standard recompute strategy (FlashAttention-style): the
VJP re-runs attention score blocks and accumulates dQ/dK/dV through the same
batch-reduce structure.  On the XLA path autodiff handles it natively; on
the Pallas path we use jax.custom_vjp with a jnp-recompute backward (the
forward stays the fused kernel — the hot path for serving/prefill).
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.blocking import AttnBlocks
from repro.kernels.flash_attention import ref as R
from repro.kernels.flash_attention.kernel import flash_attention_pallas


class _Cfg(NamedTuple):
    causal: bool
    window: int | None
    scale: float | None
    blocks: AttnBlocks | None
    interpret: bool
    acc_dtype: object


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_p(cfg: _Cfg, q, k, v):
    return flash_attention_pallas(
        q, k, v, causal=cfg.causal, window=cfg.window, scale=cfg.scale,
        blocks=cfg.blocks, interpret=cfg.interpret,
        acc_dtype=cfg.acc_dtype)


def _flash_fwd(cfg, q, k, v):
    y = _flash_p(cfg, q, k, v)
    return y, (q, k, v)


def _flash_bwd(cfg, res, dy):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: R.mha_ref(
            q_, k_, v_, causal=cfg.causal, window=cfg.window,
            scale=cfg.scale),
        q, k, v)
    return vjp(dy)


_flash_p.defvjp(_flash_fwd, _flash_bwd)


@dispatch.register("flash_attention", "pallas",
                   available=dispatch.pallas_available, priority=10)
def _flash_pallas_backend(q, k, v, *, causal, window, scale, xla_impl,
                          unroll, blocks):
    del xla_impl, unroll  # XLA-path-only knobs
    tq, d = q.shape[-2:]
    tk = k.shape[-2]
    blk = dispatch.resolve_blocks("flash_attention", tq, tk, d, q.dtype,
                                  backend="pallas", blocks=blocks)
    cfg = _Cfg(causal, window, scale, blk, dispatch.resolve_interpret(),
               dispatch.resolve_accum_dtype())
    return _flash_p(cfg, q, k, v)


@dispatch.register("flash_attention", "xla")
def _flash_xla_backend(q, k, v, *, causal, window, scale, xla_impl, unroll,
                       blocks):
    del blocks  # tiling is an XLA-internal decision on this path
    if xla_impl == "chunked":
        return R.mha_chunked(q, k, v, causal=causal, window=window,
                             scale=scale, unroll=unroll)
    return R.mha_ref(q, k, v, causal=causal, window=window, scale=scale)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    backend: str | None = None, xla_impl: str = "naive",
                    unroll: bool = False,
                    blocks: AttnBlocks | None = None,
                    block_q: int | None = None, block_k: int | None = None):
    """xla_impl: 'naive' (full T^2 softmax) or 'chunked' (online softmax,
    flash semantics — the XLA-path memory optimization).

    ``blocks`` (an ``AttnBlocks``) is the explicit tier-1 geometry
    override; by default the tile resolves through
    ``dispatch.resolve_blocks`` under the active block policy.  The old
    per-dimension ``block_q=``/``block_k=`` kwargs still work but are
    deprecated in favor of ``blocks=``.
    """
    # Validated here, not in the xla impl: a typo'd value must fail the
    # same way whichever backend dispatch resolves to.
    if xla_impl not in ("naive", "chunked"):
        raise ValueError(
            f"unknown xla_impl {xla_impl!r}; expected 'naive' or 'chunked'")
    if block_q is not None or block_k is not None:
        warnings.warn(
            "flash_attention(block_q=..., block_k=...) is deprecated; pass "
            "blocks=AttnBlocks(block_q, block_k) instead",
            DeprecationWarning, stacklevel=2)
        if blocks is not None:
            raise ValueError(
                "pass either blocks= or the deprecated block_q=/block_k=, "
                "not both")
        blocks = AttnBlocks(block_q=block_q if block_q is not None else 128,
                            block_k=block_k if block_k is not None else 128)
    impl = dispatch.get_impl("flash_attention", backend)
    return impl(q, k, v, causal=causal, window=window, scale=scale,
                xla_impl=xla_impl, unroll=unroll, blocks=blocks)
