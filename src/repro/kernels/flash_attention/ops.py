"""Differentiable flash-attention entry point with backend dispatch.

Backward uses the standard recompute strategy (FlashAttention-style): the
VJP re-runs attention score blocks and accumulates dQ/dK/dV through the same
batch-reduce structure.  On the XLA path autodiff handles it natively; on
the Pallas path we use jax.custom_vjp with a jnp-recompute backward (the
forward stays the fused kernel — the hot path for serving/prefill).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.kernels.flash_attention import ref as R
from repro.kernels.flash_attention.kernel import flash_attention_pallas


class _Cfg(NamedTuple):
    causal: bool
    window: int | None
    scale: float | None
    interpret: bool


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_p(cfg: _Cfg, q, k, v):
    return flash_attention_pallas(
        q, k, v, causal=cfg.causal, window=cfg.window, scale=cfg.scale,
        interpret=cfg.interpret)


def _flash_fwd(cfg, q, k, v):
    y = _flash_p(cfg, q, k, v)
    return y, (q, k, v)


def _flash_bwd(cfg, res, dy):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: R.mha_ref(
            q_, k_, v_, causal=cfg.causal, window=cfg.window,
            scale=cfg.scale),
        q, k, v)
    return vjp(dy)


_flash_p.defvjp(_flash_fwd, _flash_bwd)


@dispatch.register("flash_attention", "pallas",
                   available=dispatch.pallas_available, priority=10)
def _flash_pallas_backend(q, k, v, *, causal, window, scale, xla_impl,
                          unroll):
    del xla_impl, unroll  # XLA-path-only knobs
    cfg = _Cfg(causal, window, scale, dispatch.resolve_interpret())
    return _flash_p(cfg, q, k, v)


@dispatch.register("flash_attention", "xla")
def _flash_xla_backend(q, k, v, *, causal, window, scale, xla_impl, unroll):
    if xla_impl == "chunked":
        return R.mha_chunked(q, k, v, causal=causal, window=window,
                             scale=scale, unroll=unroll)
    return R.mha_ref(q, k, v, causal=causal, window=window, scale=scale)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    backend: str | None = None, xla_impl: str = "naive",
                    unroll: bool = False):
    """xla_impl: 'naive' (full T^2 softmax) or 'chunked' (online softmax,
    flash semantics — the XLA-path memory optimization)."""
    # Validated here, not in the xla impl: a typo'd value must fail the
    # same way whichever backend dispatch resolves to.
    if xla_impl not in ("naive", "chunked"):
        raise ValueError(
            f"unknown xla_impl {xla_impl!r}; expected 'naive' or 'chunked'")
    impl = dispatch.get_impl("flash_attention", backend)
    return impl(q, k, v, causal=causal, window=window, scale=scale,
                xla_impl=xla_impl, unroll=unroll)
