"""Differentiable flash-attention entry point with backend dispatch.

Training is a first-class fused workload: on the Pallas path the forward
kernel saves the per-row log-sum-exp statistics as VJP residuals, and the
backward runs the fused Pallas kernels (``bwd.py``) — the `delta`
precompute plus dK/dV and dQ, each a batch-reduce GEMM loop over the
other axis.  Backward tile geometry resolves through
``dispatch.resolve_blocks("flash_attention_bwd", ...)`` at backward trace
time, so a ``repro.use(blocks_policy="autotune")`` context wrapping the
train step (as ``make_train_step`` installs) tunes backward tiles
independently of forward ones.  On the XLA path autodiff handles the
backward natively; the jnp-recompute VJP survives as the registered
``xla`` implementation of the ``flash_attention_bwd`` op — the reference
the fused kernels are tested against, and the deterministic fallback on
platforms without Pallas.
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple

import jax

from repro.core import dispatch
from repro.core.blocking import AttnBlocks, AttnBwdBlocks
from repro.kernels.flash_attention import ref as R
from repro.kernels.flash_attention.bwd import flash_attention_bwd_pallas
from repro.kernels.flash_attention.kernel import flash_attention_pallas


class _Cfg(NamedTuple):
    causal: bool
    window: int | None
    scale: float | None
    blocks: AttnBlocks | None
    blocks_bwd: AttnBwdBlocks | None
    interpret: bool
    acc_dtype: object


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_p(cfg: _Cfg, q, k, v):
    return flash_attention_pallas(
        q, k, v, causal=cfg.causal, window=cfg.window, scale=cfg.scale,
        blocks=cfg.blocks, interpret=cfg.interpret,
        acc_dtype=cfg.acc_dtype)


def _flash_fwd(cfg, q, k, v):
    y, lse = flash_attention_pallas(
        q, k, v, causal=cfg.causal, window=cfg.window, scale=cfg.scale,
        blocks=cfg.blocks, interpret=cfg.interpret,
        acc_dtype=cfg.acc_dtype, return_residuals=True)
    return y, (q, k, v, y, lse)


def _fused_bwd(q, k, v, y, lse, dy, *, causal, window, scale, blocks,
               interpret, acc_dtype):
    """Resolve the backward tile and run the fused kernels.

    The resolve happens *outside* the kernel's jit so the tile is part of
    the jit key — a different policy context retraces instead of silently
    reusing whatever tile the first ``blocks=None`` trace captured."""
    blk = blocks or dispatch.resolve_blocks(
        "flash_attention_bwd", q.shape[-2], k.shape[-2], q.shape[-1],
        q.dtype, backend="pallas")
    return flash_attention_bwd_pallas(
        q, k, v, y, lse, dy, causal=causal, window=window, scale=scale,
        blocks=blk, interpret=interpret, acc_dtype=acc_dtype)


def _flash_bwd(cfg, res, dy):
    q, k, v, y, lse = res
    # Tile resolution lands here (not at forward dispatch) so
    # inference-only traces never pay for backward tuning, and so the
    # policy active when the cotangent pulls back — e.g. make_train_step's
    # tuned context — is the one that picks the tile.
    return _fused_bwd(q, k, v, y, lse, dy, causal=cfg.causal,
                      window=cfg.window, scale=cfg.scale,
                      blocks=cfg.blocks_bwd, interpret=cfg.interpret,
                      acc_dtype=cfg.acc_dtype)


_flash_p.defvjp(_flash_fwd, _flash_bwd)


@dispatch.register("flash_attention", "pallas",
                   available=dispatch.pallas_available, priority=10)
def _flash_pallas_backend(q, k, v, *, causal, window, scale, xla_impl,
                          unroll, blocks, blocks_bwd=None):
    del xla_impl, unroll  # XLA-path-only knobs
    tq, d = q.shape[-2:]
    tk = k.shape[-2]
    blk = dispatch.resolve_blocks("flash_attention", tq, tk, d, q.dtype,
                                  backend="pallas", blocks=blocks)
    cfg = _Cfg(causal, window, scale, blk, blocks_bwd,
               dispatch.resolve_interpret(), dispatch.resolve_accum_dtype())
    return _flash_p(cfg, q, k, v)


@dispatch.register("flash_attention", "xla")
def _flash_xla_backend(q, k, v, *, causal, window, scale, xla_impl, unroll,
                       blocks, blocks_bwd=None):
    del blocks, blocks_bwd  # tiling is XLA-internal on this path
    if xla_impl == "chunked":
        return R.mha_chunked(q, k, v, causal=causal, window=window,
                             scale=scale, unroll=unroll)
    return R.mha_ref(q, k, v, causal=causal, window=window, scale=scale)


# --------------------------------------------------------------------------
# the backward as a registered op in its own right
# --------------------------------------------------------------------------

@dispatch.register("flash_attention_bwd", "pallas",
                   available=dispatch.pallas_available, priority=10)
def _flash_bwd_pallas_backend(q, k, v, y, lse, dy, *, causal, window, scale,
                              blocks):
    return _fused_bwd(q, k, v, y, lse, dy, causal=causal, window=window,
                      scale=scale, blocks=blocks,
                      interpret=dispatch.resolve_interpret(),
                      acc_dtype=dispatch.resolve_accum_dtype())


@dispatch.register("flash_attention_bwd", "xla")
def _flash_bwd_xla_backend(q, k, v, y, lse, dy, *, causal, window, scale,
                           blocks):
    del y, lse, blocks  # the recompute reference rebuilds everything
    _, vjp = jax.vjp(
        lambda q_, k_, v_: R.mha_ref(
            q_, k_, v_, causal=causal, window=window, scale=scale),
        q, k, v)
    return vjp(dy)


def flash_attention_bwd(q, k, v, y, lse, dy, *, causal: bool = True,
                        window: int | None = None,
                        scale: float | None = None,
                        backend: str | None = None,
                        blocks: AttnBwdBlocks | None = None):
    """Standalone fused backward: (dq, dk, dv) from the forward residuals.

    ``jax.grad`` through :func:`flash_attention` reaches this computation
    automatically; the direct entry exists for benchmarks, parity tests,
    and callers managing their own residuals.  ``y``/``lse`` are the
    forward output and per-row log-sum-exp
    (``flash_attention_pallas(..., return_residuals=True)``); the ``xla``
    backend is the jnp-recompute reference and ignores them.
    """
    impl = dispatch.get_impl("flash_attention_bwd", backend)
    return impl(q, k, v, y, lse, dy, causal=causal, window=window,
                scale=scale, blocks=blocks)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    backend: str | None = None, xla_impl: str = "naive",
                    unroll: bool = False,
                    blocks: AttnBlocks | None = None,
                    blocks_bwd: AttnBwdBlocks | None = None,
                    block_q: int | None = None, block_k: int | None = None):
    """xla_impl: 'naive' (full T^2 softmax) or 'chunked' (online softmax,
    flash semantics — the XLA-path memory optimization).

    ``blocks`` (an ``AttnBlocks``) is the explicit tier-1 geometry
    override for the forward tile; ``blocks_bwd`` (an ``AttnBwdBlocks``)
    is the same for the fused backward kernels — by default both resolve
    through ``dispatch.resolve_blocks`` under the active block policy (the
    backward at backward-trace time, under its own
    ``flash_attention_bwd`` cache entry).  Under ``repro.use(mesh=...)``
    the default (tq, tk, d) triple is mesh-invariant — the model axis
    shards heads, which sit outside it — but sequence-parallel setups can
    localize tq/tk via ``use(axis_specs={"flash_attention": ...})``.  The
    old per-dimension ``block_q=``/``block_k=`` kwargs still work but are
    deprecated in favor of ``blocks=``.
    """
    # Validated here, not in the xla impl: a typo'd value must fail the
    # same way whichever backend dispatch resolves to.
    if xla_impl not in ("naive", "chunked"):
        raise ValueError(
            f"unknown xla_impl {xla_impl!r}; expected 'naive' or 'chunked'")
    if block_q is not None or block_k is not None:
        warnings.warn(
            "flash_attention(block_q=..., block_k=...) is deprecated; pass "
            "blocks=AttnBlocks(block_q, block_k) instead",
            DeprecationWarning, stacklevel=2)
        if blocks is not None:
            raise ValueError(
                "pass either blocks= or the deprecated block_q=/block_k=, "
                "not both")
        if block_q is None or block_k is None:
            # A single-dimension override keeps the other dimension on the
            # active block policy instead of a hard-coded default.
            resolved = dispatch.resolve_blocks(
                "flash_attention", q.shape[-2], k.shape[-2], q.shape[-1],
                q.dtype, backend=dispatch.resolve("flash_attention",
                                                  backend))
            block_q = block_q if block_q is not None else resolved.block_q
            block_k = block_k if block_k is not None else resolved.block_k
        blocks = AttnBlocks(block_q=block_q, block_k=block_k)
    impl = dispatch.get_impl("flash_attention", backend)
    return impl(q, k, v, causal=causal, window=window, scale=scale,
                xla_impl=xla_impl, unroll=unroll, blocks=blocks,
                blocks_bwd=blocks_bwd)
