"""Differentiable flash-attention entry point with backend dispatch.

Backward uses the standard recompute strategy (FlashAttention-style): the
VJP re-runs attention score blocks and accumulates dQ/dK/dV through the same
batch-reduce structure.  On the XLA path autodiff handles it natively; on
the Pallas path we use jax.custom_vjp with a jnp-recompute backward (the
forward stays the fused kernel — the hot path for serving/prefill).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.brgemm.ops import resolve_backend, _interpret
from repro.kernels.flash_attention import ref as R
from repro.kernels.flash_attention.kernel import flash_attention_pallas


class _Cfg(NamedTuple):
    causal: bool
    window: int | None
    scale: float | None
    interpret: bool


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_p(cfg: _Cfg, q, k, v):
    return flash_attention_pallas(
        q, k, v, causal=cfg.causal, window=cfg.window, scale=cfg.scale,
        interpret=cfg.interpret)


def _flash_fwd(cfg, q, k, v):
    y = _flash_p(cfg, q, k, v)
    return y, (q, k, v)


def _flash_bwd(cfg, res, dy):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: R.mha_ref(
            q_, k_, v_, causal=cfg.causal, window=cfg.window,
            scale=cfg.scale),
        q, k, v)
    return vjp(dy)


_flash_p.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    backend: str | None = None, xla_impl: str = "naive",
                    unroll: bool = False):
    """xla_impl: 'naive' (full T^2 softmax) or 'chunked' (online softmax,
    flash semantics — the XLA-path memory optimization)."""
    be = resolve_backend(backend)
    if be == "xla":
        if xla_impl == "chunked":
            return R.mha_chunked(q, k, v, causal=causal, window=window,
                                 scale=scale, unroll=unroll)
        return R.mha_ref(q, k, v, causal=causal, window=window, scale=scale)
    cfg = _Cfg(causal, window, scale, _interpret())
    return _flash_p(cfg, q, k, v)
