from repro.core.blocking import AttnBlocks  # noqa: F401
from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
