"""The batch-reduce GEMM Pallas TPU kernel.

Paper Algorithm 1, adapted for TPU (see DESIGN.md Sec. 2):

  * the fp32 accumulator block lives in VMEM scratch and is carried across
    the innermost ("arbitrary") grid axis — the TPU analogue of keeping the
    accumulation chain in registers,
  * the paper's pointer lists A_ptrs/B_ptrs become ``BlockSpec.index_map``
    functions: arbitrary sub-blocks of the input tensors are streamed into
    VMEM with no copies/reformatting,
  * the epilogue (alpha/beta scaling, bias, activation) is fused on the
    VMEM-resident accumulator before the single HBM write-back,
  * Mosaic double-buffers the A/B panel DMAs across grid steps (the
    software-prefetch analogue).

Three entry points share one kernel body:
  - ``matmul_pallas``:          C = act(alpha * X @ W + bias)            (K-block reduce)
  - ``brgemm_stacked_pallas``:  C = act(alpha * sum_i A_i @ B_i + ...)   (paper's literal interface)
  - ``batched_matmul_pallas``:  C_i = act(alpha * A_i @ B_i + bias)      (the baseline "batched GEMM";
                                 supports broadcast of either operand with zero copies via index_map)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import pallas_compat as _pc
from repro.core import fusion
from repro.core.blocking import Blocks, choose_blocks, round_up


def _pad2(x, bm, bn):
    m, n = x.shape
    pm, pn = round_up(m, bm), round_up(n, bn)
    if (pm, pn) != (m, n):
        x = jnp.pad(x, ((0, pm - m), (0, pn - n)))
    return x


def _pad3(x, bb, bm, bn):
    b, m, n = x.shape
    pm, pn = round_up(m, bm), round_up(n, bn)
    if (pm, pn) != (m, n):
        x = jnp.pad(x, ((0, 0), (0, pm - m), (0, pn - n)))
    return x


def _make_body(
    *,
    reduce_axis: int,
    has_c0: bool,
    has_bias: bool,
    alpha: float,
    beta: float,
    activation: str,
    out_dtype,
    block_rank3: bool,
    acc_dtype=jnp.float32,
):
    """Build the kernel body. Ref order: a, b, [c0], [bias], out, acc."""

    def body(*refs):
        idx = 0
        a_ref = refs[idx]; idx += 1
        b_ref = refs[idx]; idx += 1
        c0_ref = None
        bias_ref = None
        if has_c0:
            c0_ref = refs[idx]; idx += 1
        if has_bias:
            bias_ref = refs[idx]; idx += 1
        out_ref = refs[idx]; idx += 1
        acc_ref = refs[idx]

        r = pl.program_id(reduce_axis)
        nr = pl.num_programs(reduce_axis)

        @pl.when(r == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a = a_ref[...]
        b = b_ref[...]
        if block_rank3:  # leading singleton batch-block dim
            a = a[0]
            b = b[0]
        acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_dtype)

        @pl.when(r == nr - 1)
        def _finish():
            acc = acc_ref[...] * jnp.float32(alpha)
            if c0_ref is not None:
                # c0 blocks are always 2-D (bm, bn), independent of the
                # rank of the A/B blocks.
                acc += jnp.float32(beta) * c0_ref[...].astype(jnp.float32)
            if bias_ref is not None:
                acc += bias_ref[...].astype(jnp.float32)
            acc = fusion.apply(activation, acc)
            out = acc.astype(out_dtype)
            if out_ref.ndim == 3:
                out = out[None]
            out_ref[...] = out

    return body


@functools.partial(
    jax.jit,
    static_argnames=(
        "activation", "alpha", "beta", "out_dtype", "blocks", "interpret",
        "acc_dtype",
    ),
)
def matmul_pallas(
    x,
    w,
    bias=None,
    c0=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    beta: float = 0.0,
    out_dtype=None,
    blocks: Blocks | None = None,
    interpret: bool = False,
    acc_dtype=jnp.float32,
):
    """C = act(alpha * X @ W + beta * C0 + bias); X: (m,k), W: (k,n).

    The K dimension is the batch-reduce axis: the grid walks K blocks while
    the fp32 accumulator stays resident in VMEM.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    out_dtype = out_dtype or x.dtype
    blk = blocks or choose_blocks(m, n, k, x.dtype)
    bm, bn, bk = blk.astuple()

    xp = _pad2(x, bm, bk)
    wp = _pad2(w, bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, r: (i, r)),
        pl.BlockSpec((bk, bn), lambda i, j, r: (r, j)),
    ]
    operands = [xp, wp]
    has_c0 = c0 is not None and beta != 0.0
    if has_c0:
        operands.append(_pad2(c0, bm, bn))
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, r: (i, j)))
    has_bias = bias is not None
    if has_bias:
        bp = _pad2(bias.reshape(1, -1), 1, bn)
        operands.append(bp)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, r: (0, j)))

    body = _make_body(
        reduce_axis=2, has_c0=has_c0, has_bias=has_bias, alpha=alpha,
        beta=beta, activation=activation, out_dtype=out_dtype,
        block_rank3=False, acc_dtype=acc_dtype,
    )
    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=_pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "activation", "alpha", "beta", "out_dtype", "blocks", "interpret",
        "acc_dtype",
    ),
)
def brgemm_stacked_pallas(
    a,
    b,
    c0=None,
    bias=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    beta: float = 0.0,
    out_dtype=None,
    blocks: Blocks | None = None,
    interpret: bool = False,
    acc_dtype=jnp.float32,
):
    """Paper's literal interface: C = act(alpha * sum_i A_i@B_i + beta*C0 + bias).

    a: (B, m, k), b: (B, k, n) -> (m, n).  The reduction grid axis walks
    (batch x K-blocks); the accumulator is written to HBM exactly once.
    """
    nb, m, k = a.shape
    nb2, k2, n = b.shape
    assert nb == nb2 and k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    blk = blocks or choose_blocks(m, n, k, a.dtype)
    bm, bn, bk = blk.astuple()

    ap = _pad3(a, 1, bm, bk)
    bp = _pad3(b, 1, bk, bn)
    kp = ap.shape[2]
    kb = kp // bk  # K blocks per batch entry
    grid = (ap.shape[1] // bm, bp.shape[2] // bn, nb * kb)

    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda i, j, r: (r // kb, i, r % kb)),
        pl.BlockSpec((1, bk, bn), lambda i, j, r: (r // kb, r % kb, j)),
    ]
    operands = [ap, bp]
    has_c0 = c0 is not None and beta != 0.0
    if has_c0:
        operands.append(_pad2(c0, bm, bn))
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, r: (i, j)))
    has_bias = bias is not None
    if has_bias:
        operands.append(_pad2(bias.reshape(1, -1), 1, bn))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, r: (0, j)))

    body = _make_body(
        reduce_axis=2, has_c0=has_c0, has_bias=has_bias, alpha=alpha,
        beta=beta, activation=activation, out_dtype=out_dtype,
        block_rank3=True, acc_dtype=acc_dtype,
    )
    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[1], bp.shape[2]), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=_pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("activation", "alpha", "out_dtype", "blocks", "interpret",
                     "acc_dtype"),
)
def batched_matmul_pallas(
    a,
    b,
    bias=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    out_dtype=None,
    blocks: Blocks | None = None,
    interpret: bool = False,
    acc_dtype=jnp.float32,
):
    """Strided-batched GEMM baseline; broadcast either operand zero-copy.

    a: (B, m, k) or (m, k); b: (B, k, n) or (k, n) -> (B, m, n).
    Broadcasting is expressed through the index_map (the paper's pointer-list
    trick): a 2-D operand is re-read for every batch entry without ever being
    materialized B times.
    """
    a_bcast = a.ndim == 2
    b_bcast = b.ndim == 2
    assert not (a_bcast and b_bcast)
    nb = b.shape[0] if a_bcast else a.shape[0]
    m, k = a.shape[-2:]
    k2, n = b.shape[-2:]
    assert k == k2
    out_dtype = out_dtype or a.dtype
    blk = blocks or choose_blocks(m, n, k, a.dtype)
    bm, bn, bk = blk.astuple()

    ap = _pad2(a, bm, bk) if a_bcast else _pad3(a, 1, bm, bk)
    bp = _pad2(b, bk, bn) if b_bcast else _pad3(b, 1, bk, bn)
    mp = ap.shape[-2]
    np_ = bp.shape[-1]
    kp = ap.shape[-1]
    grid = (nb, mp // bm, np_ // bn, kp // bk)

    if a_bcast:
        a_spec = pl.BlockSpec((bm, bk), lambda bi, i, j, r: (i, r))
    else:
        a_spec = pl.BlockSpec((1, bm, bk), lambda bi, i, j, r: (bi, i, r))
    if b_bcast:
        b_spec = pl.BlockSpec((bk, bn), lambda bi, i, j, r: (r, j))
    else:
        b_spec = pl.BlockSpec((1, bk, bn), lambda bi, i, j, r: (bi, r, j))

    in_specs = [a_spec, b_spec]
    operands = [ap, bp]
    has_bias = bias is not None
    if has_bias:
        operands.append(_pad2(bias.reshape(1, -1), 1, bn))
        in_specs.append(pl.BlockSpec((1, bn), lambda bi, i, j, r: (0, j)))

    # block_rank3 handling differs per operand; use a dedicated body.
    acts = fusion.ACTIVATIONS[activation]

    def body(*refs):
        idx = 0
        a_ref = refs[idx]; idx += 1
        b_ref = refs[idx]; idx += 1
        bias_ref = refs[idx] if has_bias else None
        idx += 1 if has_bias else 0
        out_ref = refs[idx]; idx += 1
        acc_ref = refs[idx]

        r = pl.program_id(3)
        nr = pl.num_programs(3)

        @pl.when(r == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        av = a_ref[...] if a_ref.ndim == 2 else a_ref[0]
        bv = b_ref[...] if b_ref.ndim == 2 else b_ref[0]
        acc_ref[...] += jnp.dot(av, bv, preferred_element_type=acc_dtype)

        @pl.when(r == nr - 1)
        def _():
            acc = acc_ref[...] * jnp.float32(alpha)
            if bias_ref is not None:
                acc += bias_ref[...].astype(jnp.float32)
            out_ref[...] = acts(acc).astype(out_dtype)[None]

    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda bi, i, j, r: (bi, i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=_pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:, :m, :n]
