from repro.kernels.brgemm.ops import (  # noqa: F401
    batched_matmul,
    brgemm,
    matmul,
    resolve_backend,      # deprecated shim (see repro.core.dispatch)
    set_default_backend,  # deprecated shim (see repro.core.dispatch)
)
from repro.kernels.brgemm.quant import (  # noqa: F401
    batched_matmul_q,
    batched_matmul_q_ref,
    brgemm_q,
    brgemm_q_ref,
    matmul_q,
    matmul_q_ref,
)
