from repro.kernels.brgemm.ops import (  # noqa: F401
    batched_matmul,
    brgemm,
    matmul,
    resolve_backend,
    set_default_backend,
)
