from repro.kernels.brgemm.ops import (  # noqa: F401
    batched_matmul,
    brgemm,
    matmul,
    resolve_backend,      # deprecated shim (see repro.core.dispatch)
    set_default_backend,  # deprecated shim (see repro.core.dispatch)
)
