"""Quantized-GEMM routing + XLA fake-quant reference.

This sits between the public entry points (``ops.py``) and the two
execution paths for quantized GEMMs:

  * the Pallas kernels (``quant_kernel.py``) — int8/fp8 storage, fused
    dequant epilogue, quant-aware block tuning;
  * the XLA reference here — the capability-fallback path and the parity
    oracle.  Int8 mirrors the kernel *exactly* (same int8 operands, same
    int32 accumulation, same fp32 dequant epilogue) so pallas-vs-xla
    parity tests can use tight tolerances; fp8 upcasts the quantized
    storage to fp32 before the dot (CPU has no fp8 matmul units) — the
    values are identical since every fp8 number is exactly representable
    in fp32.

Routing rules (``active_quant``): an explicit ``quant=`` call argument
wins, else the ambient ``repro.use(quant=...)`` context, else a
pre-quantized :class:`~repro.core.quantize.QuantizedTensor` weight
implies its own config.  Backend choice reuses the dispatch resolution
for the op, then applies the quant capability gate: int8 runs wherever
the pallas backend runs (interpret on CPU, Mosaic on TPU); fp8 matmul
units exist only on TPU, so off-TPU the quantized op falls back
deterministically to the XLA reference — unless the caller *explicitly*
pinned ``backend="pallas"``, which refuses to fall back, same as
unquantized dispatch.

The quantized path is inference-only (no custom VJP) and does not
support ``c0``/``beta`` accumulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dispatch, fusion
from repro.core.quantize import (
    QuantConfig,
    QuantizedTensor,
    quantize,
    quantize_weight,
)
from repro.kernels.brgemm import quant_kernel as QK


def active_quant(w, quant=None) -> QuantConfig | None:
    """The QuantConfig governing this call, or None for full precision.

    Precedence: explicit ``quant=`` call arg > ``use(quant=...)`` context
    > config implied by a pre-quantized weight.  A calibrated param tree
    is therefore inference-ready without any ambient context.
    """
    qcfg = dispatch.resolve_quant(quant)
    if qcfg is not None:
        return qcfg
    if isinstance(w, QuantizedTensor):
        name = str(w.q.dtype)
        return QuantConfig(
            w_dtype=name, a_dtype=name,
            granularity=("per_channel" if w.scale.ndim == w.q.ndim - 1
                         else "per_tensor"))
    return None


def _pallas_quant_ok(qcfg: QuantConfig) -> bool:
    """int8 runs wherever pallas runs; fp8 matmul is TPU-only."""
    if not dispatch.pallas_available():
        return False
    if qcfg.integer:
        return True
    return jax.default_backend() == "tpu"


def _resolve_backend(op: str, backend, qcfg: QuantConfig) -> str:
    """Dispatch resolution + the quant capability gate.

    Explicit ``backend="pallas"`` never falls back (mirror of the
    unquantized rule); everything else degrades to the XLA reference
    when the pallas variant can't run this config here.
    """
    if "int8" in (qcfg.w_dtype, qcfg.a_dtype) and qcfg.w_dtype != qcfg.a_dtype:
        raise NotImplementedError(
            f"mixed integer/float quant storage (w={qcfg.w_dtype}, "
            f"a={qcfg.a_dtype}) has no accumulator dtype; use matching "
            f"int8 or fp8 families")
    name = dispatch.resolve(op, backend)
    if name != "pallas":
        return name
    if _pallas_quant_ok(qcfg):
        return "pallas"
    if backend == "pallas":
        raise RuntimeError(
            f"backend='pallas' was requested explicitly but the quantized "
            f"{op} ({qcfg.tag()}) is not available on "
            f"{jax.default_backend()!r}; fp8 GEMM requires TPU")
    return "xla"


def _check_no_accum(op: str, c0, beta: float):
    if c0 is not None and float(beta) != 0.0:
        raise NotImplementedError(
            f"quantized {op} does not support c0/beta accumulation; "
            f"run the epilogue-accumulating call in full precision")


def _weight_qparams(w, qcfg: QuantConfig, *, batch_shared: bool = False):
    """Quantized storage + per-output-channel fp32 scales for a weight.

    Returns ``(wq, sw)`` with ``sw`` broadcast to the kernel's expected
    per-channel vector: ``(n,)`` for 2-D weights (scalar per-tensor
    scales broadcast), ``(B, n)`` for stacked per-batch weights unless
    ``batch_shared`` (the brgemm reduction) requires one shared vector.
    """
    n = w.shape[-1]
    if isinstance(w, QuantizedTensor):
        if str(w.q.dtype) != qcfg.w_dtype:
            raise ValueError(
                f"pre-quantized weight storage {w.q.dtype} does not match "
                f"QuantConfig.w_dtype={qcfg.w_dtype}")
        wq, sw = w.q, w.scale
    else:
        qt = quantize_weight(
            w, QuantConfig(w_dtype=qcfg.w_dtype, a_dtype=qcfg.a_dtype,
                           granularity=qcfg.granularity))
        wq, sw = qt.q, qt.scale
    if wq.ndim == 2:
        return wq, jnp.broadcast_to(jnp.atleast_1d(sw), (n,))
    # stacked (B, k, n) weights
    if batch_shared:
        if sw.ndim != 0:
            raise ValueError(
                "brgemm sums int32 products across the whole (B, k) "
                "reduction, so weight scales must be batch-shared; "
                "calibrate stacked brgemm weights with per-tensor "
                "granularity, or pass the full-precision weight and let "
                "the op quantize dynamically")
        return wq, jnp.broadcast_to(jnp.atleast_1d(sw), (n,))
    nb = wq.shape[0]
    if sw.ndim == 0:
        return wq, jnp.broadcast_to(sw, (nb, n))
    if sw.ndim == 1:  # per-batch per-tensor (B,)
        return wq, jnp.broadcast_to(sw[:, None], (nb, n))
    return wq, sw  # (B, n)


def _quantize_act(x, qcfg: QuantConfig, *, axis):
    """Dynamic activation quantization; scales keep the unreduced dims."""
    if qcfg.a_granularity == "per_tensor":
        axis = None
    xq, sx = quantize(x, qcfg.a_dtype, axis=axis)
    return xq, sx


def _dequant_epilogue(acc, scale2d, bias, alpha, activation, out_dtype):
    acc = acc.astype(jnp.float32) * scale2d * jnp.float32(alpha)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    acc = fusion.apply(activation, acc)
    return acc.astype(out_dtype)


def _ref_dot(xq, wq, qcfg: QuantConfig):
    """The reference contraction: int32 dot for int8 (bit-identical to the
    kernel), fp32 upcast for fp8 (identical values, CPU-safe)."""
    if qcfg.integer:
        return jnp.dot(xq, wq, preferred_element_type=jnp.int32)
    return jnp.dot(xq.astype(jnp.float32), wq.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

def matmul_q(x, w, bias=None, c0=None, *, activation="none", alpha=1.0,
             beta=0.0, out_dtype=None, backend=None, blocks=None,
             qcfg: QuantConfig):
    """Quantized C = act(alpha * dequant(Xq @ Wq) + bias).  x: (m, k)."""
    _check_no_accum("matmul", c0, beta)
    out_dtype = out_dtype or x.dtype
    name = _resolve_backend("matmul", backend, qcfg)
    xq, sx = _quantize_act(x, qcfg, axis=(-1,))
    wq, sw = _weight_qparams(w, qcfg)
    m = x.shape[0]
    sx = jnp.broadcast_to(jnp.atleast_1d(sx), (m,))
    if name == "pallas":
        n, k = wq.shape[-1], wq.shape[-2]
        blk = dispatch.resolve_blocks("matmul", m, n, k, wq.dtype,
                                      backend="pallas", blocks=blocks,
                                      quant=qcfg)
        return QK.matmul_q_pallas(
            xq, wq, sx, sw, bias, activation=activation, alpha=float(alpha),
            out_dtype=out_dtype, blocks=blk,
            interpret=dispatch.resolve_interpret())
    return matmul_q_ref(xq, wq, sx, sw, bias, activation=activation,
                        alpha=alpha, out_dtype=out_dtype, qcfg=qcfg)


def matmul_q_ref(xq, wq, sx, sw, bias=None, *, activation="none", alpha=1.0,
                 out_dtype=jnp.float32, qcfg: QuantConfig):
    """XLA fake-quant reference on already-quantized operands."""
    acc = _ref_dot(xq, wq, qcfg)
    scale2d = sx.astype(jnp.float32)[:, None] * sw.astype(jnp.float32)[None, :]
    return _dequant_epilogue(acc, scale2d, bias, alpha, activation, out_dtype)


# --------------------------------------------------------------------------
# brgemm (stacked blocks, batch-shared scales)
# --------------------------------------------------------------------------

def brgemm_q(a, b, bias=None, c0=None, *, activation="none", alpha=1.0,
             beta=0.0, out_dtype=None, backend=None, blocks=None,
             qcfg: QuantConfig):
    """Quantized batch-reduce GEMM.  a: (B, m, k), b: (B, k, n) -> (m, n).

    Scales are *batch-shared* (absmax over the whole (B, k) panel per
    row/channel): the int32 accumulator sums across the entire reduction
    before the single fused dequant, so per-batch scales would change
    the math, not just the layout.
    """
    _check_no_accum("brgemm", c0, beta)
    out_dtype = out_dtype or a.dtype
    name = _resolve_backend("brgemm", backend, qcfg)
    aq, sa = _quantize_act(a, qcfg, axis=(0, 2))
    m = a.shape[1]
    sa = jnp.broadcast_to(jnp.atleast_1d(sa), (m,))
    if isinstance(b, QuantizedTensor):
        bq, sb = _weight_qparams(b, qcfg, batch_shared=True)
    else:
        w_axis = (0, 1) if qcfg.granularity == "per_channel" else None
        bq, sb = quantize(b, qcfg.w_dtype, axis=w_axis)
        sb = jnp.broadcast_to(jnp.atleast_1d(sb), (b.shape[-1],))
    if name == "pallas":
        n, k = bq.shape[-1], bq.shape[-2]
        blk = dispatch.resolve_blocks("brgemm", m, n, k, bq.dtype,
                                      backend="pallas", blocks=blocks,
                                      quant=qcfg)
        return QK.brgemm_q_pallas(
            aq, bq, sa, sb, bias, activation=activation, alpha=float(alpha),
            out_dtype=out_dtype, blocks=blk,
            interpret=dispatch.resolve_interpret())
    return brgemm_q_ref(aq, bq, sa, sb, bias, activation=activation,
                        alpha=alpha, out_dtype=out_dtype, qcfg=qcfg)


def brgemm_q_ref(aq, bq, sa, sb, bias=None, *, activation="none", alpha=1.0,
                 out_dtype=jnp.float32, qcfg: QuantConfig):
    if qcfg.integer:
        acc = jnp.einsum("imk,ikn->mn", aq, bq,
                         preferred_element_type=jnp.int32)
    else:
        acc = jnp.einsum("imk,ikn->mn", aq.astype(jnp.float32),
                         bq.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    scale2d = sa.astype(jnp.float32)[:, None] * sb.astype(jnp.float32)[None, :]
    return _dequant_epilogue(acc, scale2d, bias, alpha, activation, out_dtype)


# --------------------------------------------------------------------------
# batched_matmul (per-batch scales, no cross-batch reduction)
# --------------------------------------------------------------------------

def batched_matmul_q(a, b, bias=None, *, activation="none", alpha=1.0,
                     out_dtype=None, backend=None, blocks=None,
                     qcfg: QuantConfig):
    """Quantized strided-batched GEMM.  Per-batch scales: no cross-batch
    reduction, so each batch entry dequants independently.

    2-D broadcast operands (shared A or shared B) route to the XLA
    reference — the pallas quant kernel is 3-D-only.
    """
    out_dtype = out_dtype or a.dtype
    name = _resolve_backend("batched_matmul", backend, qcfg)
    if a.ndim == 2 or getattr(b, "ndim", 3) == 2:
        if backend == "pallas":
            raise RuntimeError(
                "backend='pallas' was requested explicitly but the "
                "quantized batched_matmul requires 3-D operands; "
                "broadcast operands run on the XLA reference")
        name = "xla"
    if name == "xla":
        return _batched_ref_from_raw(a, b, bias, activation=activation,
                                     alpha=alpha, out_dtype=out_dtype,
                                     qcfg=qcfg)
    aq, sa = _quantize_act(a, qcfg, axis=(-1,))
    nb, m = a.shape[0], a.shape[1]
    sa = jnp.broadcast_to(jnp.atleast_2d(sa), (nb, m))
    bq, sb = _weight_qparams(b, qcfg)
    if sb.ndim == 1:
        sb = jnp.broadcast_to(sb[None, :], (nb, sb.shape[0]))
    n, k = bq.shape[-1], bq.shape[-2]
    blk = dispatch.resolve_blocks("batched_matmul", m, n, k, bq.dtype,
                                  backend="pallas", blocks=blocks,
                                  quant=qcfg)
    return QK.batched_matmul_q_pallas(
        aq, bq, sa, sb, bias, activation=activation, alpha=float(alpha),
        out_dtype=out_dtype, blocks=blk,
        interpret=dispatch.resolve_interpret())


def _batched_ref_from_raw(a, b, bias, *, activation, alpha, out_dtype, qcfg):
    """Quantize raw (possibly broadcast-2-D) operands and run the ref."""
    aq, sa = _quantize_act(a, qcfg, axis=(-1,))
    sa = jnp.broadcast_to(jnp.atleast_1d(sa), a.shape[:-1])
    if isinstance(b, QuantizedTensor):
        bq, sb = _weight_qparams(b, qcfg)
    else:
        w_axis = (-2,) if qcfg.granularity == "per_channel" else None
        bq, sb = quantize(b, qcfg.w_dtype, axis=w_axis)
    if sb.ndim == 0:
        sb = jnp.broadcast_to(sb, (b.shape[-1],))
    return batched_matmul_q_ref(aq, bq, sa, sb, bias, activation=activation,
                                alpha=alpha, out_dtype=out_dtype, qcfg=qcfg)


def batched_matmul_q_ref(aq, bq, sa, sb, bias=None, *, activation="none",
                         alpha=1.0, out_dtype=jnp.float32,
                         qcfg: QuantConfig):
    """Reference C_i = dequant(Aq_i @ Bq_i).  Operands may be broadcast
    2-D; scales carry matching leading dims."""
    if qcfg.integer:
        pet = jnp.int32
        aq32, bq32 = aq, bq
    else:
        pet = jnp.float32
        aq32, bq32 = aq.astype(jnp.float32), bq.astype(jnp.float32)
    if aq.ndim == 2:
        acc = jnp.einsum("mk,ikn->imn", aq32, bq32,
                         preferred_element_type=pet)
    elif bq.ndim == 2:
        acc = jnp.einsum("imk,kn->imn", aq32, bq32,
                         preferred_element_type=pet)
    else:
        acc = jnp.einsum("imk,ikn->imn", aq32, bq32,
                         preferred_element_type=pet)
    sa = sa.astype(jnp.float32)
    sb = sb.astype(jnp.float32)
    row = sa[..., :, None] if sa.ndim >= 1 else sa
    col = sb[..., None, :] if sb.ndim >= 1 else sb
    scale = row * col
    return _dequant_epilogue(acc, scale, bias, alpha, activation, out_dtype)
