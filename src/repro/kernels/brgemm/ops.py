"""Public, differentiable entry points for the batch-reduce GEMM kernel.

Backend dispatch:
  * ``pallas``  — the Pallas TPU kernel (kernel.py). On CPU it runs in
    interpret mode (Python evaluation of the kernel body) for correctness
    validation; on TPU it compiles via Mosaic.
  * ``xla``     — the pure-jnp reference (ref.py). Bit-comparable numerics
    (fp32 accumulation, identical epilogues). This path is used for the
    512-device dry-run and CPU-scale smoke tests, where interpreting a
    Python kernel under a production mesh is meaningless.

The custom VJP expresses the backward passes through the *same* building
block, mirroring the paper's claim that fwd/bwd/upd all reduce to
batch-reduce GEMM calls:
    dX = dPre @ W^T        (brgemm over K-blocks)
    dW = X^T @ dPre        (brgemm: reduction dim = minibatch, cf. paper 4.1.1 "upd")
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fusion
from repro.core.blocking import Blocks
from repro.kernels.brgemm import kernel as K
from repro.kernels.brgemm import ref as R

_BACKEND_OVERRIDE: str | None = None


def set_default_backend(name: str | None) -> None:
    global _BACKEND_OVERRIDE
    assert name in (None, "xla", "pallas"), name
    _BACKEND_OVERRIDE = name


def resolve_backend(backend: str | None = None) -> str:
    if backend is not None:
        return backend
    if _BACKEND_OVERRIDE is not None:
        return _BACKEND_OVERRIDE
    env = os.environ.get("REPRO_BRGEMM_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


class _Cfg(NamedTuple):
    activation: str
    alpha: float
    beta: float
    out_dtype: object
    blocks: Blocks | None
    interpret: bool


# --------------------------------------------------------------------------
# matmul: C = act(alpha * X @ W + beta * C0 + bias)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _matmul_p(cfg: _Cfg, x, w, bias, c0):
    return K.matmul_pallas(
        x, w, bias, c0,
        activation=cfg.activation, alpha=cfg.alpha, beta=cfg.beta,
        out_dtype=cfg.out_dtype, blocks=cfg.blocks, interpret=cfg.interpret,
    )


def _matmul_fwd(cfg, x, w, bias, c0):
    y = _matmul_p(cfg, x, w, bias, c0)
    return y, (x, w, bias, c0, y)


def _act_bar(cfg, res, dy):
    """dy * act'(pre) in fp32, recomputing pre only when required."""
    x, w, bias, c0, y = res
    dy32 = dy.astype(jnp.float32)
    if not fusion.needs_preact(cfg.activation):
        return dy32 * fusion.GRAD_FROM_OUTPUT[cfg.activation](
            y.astype(jnp.float32))
    pre = K.matmul_pallas(
        x, w, bias, c0, activation="none", alpha=cfg.alpha, beta=cfg.beta,
        out_dtype=jnp.float32, blocks=cfg.blocks, interpret=cfg.interpret)
    return dy32 * fusion.GRAD_FROM_PREACT[cfg.activation](pre)


def _matmul_bwd(cfg, res, dy):
    x, w, bias, c0, y = res
    g = _act_bar(cfg, res, dy)  # fp32, (m, n)
    galpha = (g * jnp.float32(cfg.alpha)).astype(x.dtype)
    dx = K.matmul_pallas(
        galpha, w.T, interpret=cfg.interpret).astype(x.dtype)
    dw = K.matmul_pallas(
        x.T, galpha, interpret=cfg.interpret).astype(w.dtype)
    dbias = None
    if bias is not None:
        dbias = g.sum(axis=0).astype(bias.dtype)
    dc0 = None
    if c0 is not None:
        dc0 = (g * jnp.float32(cfg.beta)).astype(c0.dtype)
    return dx, dw, dbias, dc0


_matmul_p.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(
    x,
    w,
    bias=None,
    c0=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    beta: float = 0.0,
    out_dtype=None,
    backend: str | None = None,
    blocks: Blocks | None = None,
):
    """Batch-reduce GEMM over K blocks; x may have any leading dims."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    c02 = c0.reshape(-1, c0.shape[-1]) if c0 is not None else None
    be = resolve_backend(backend)
    if be == "xla":
        y = R.matmul_ref(
            x2, w, bias, activation=activation, alpha=alpha, beta=beta,
            c0=c02, out_dtype=out_dtype)
    else:
        cfg = _Cfg(activation, float(alpha), float(beta), out_dtype, blocks,
                   _interpret())
        y = _matmul_p(cfg, x2, w, bias, c02)
    return y.reshape(*lead, w.shape[-1])


# --------------------------------------------------------------------------
# brgemm (stacked blocks): C = act(alpha * sum_i A_i @ B_i + beta*C0 + bias)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _brgemm_p(cfg: _Cfg, a, b, bias, c0):
    return K.brgemm_stacked_pallas(
        a, b, c0, bias,
        activation=cfg.activation, alpha=cfg.alpha, beta=cfg.beta,
        out_dtype=cfg.out_dtype, blocks=cfg.blocks, interpret=cfg.interpret,
    )


def _brgemm_fwd(cfg, a, b, bias, c0):
    y = _brgemm_p(cfg, a, b, bias, c0)
    return y, (a, b, bias, c0, y)


def _brgemm_bwd(cfg, res, dy):
    a, b, bias, c0, y = res
    dy32 = dy.astype(jnp.float32)
    if not fusion.needs_preact(cfg.activation):
        g = dy32 * fusion.GRAD_FROM_OUTPUT[cfg.activation](
            y.astype(jnp.float32))
    else:
        pre = K.brgemm_stacked_pallas(
            a, b, c0, bias, activation="none", alpha=cfg.alpha, beta=cfg.beta,
            out_dtype=jnp.float32, blocks=cfg.blocks, interpret=cfg.interpret)
        g = dy32 * fusion.GRAD_FROM_PREACT[cfg.activation](pre)
    galpha = (g * jnp.float32(cfg.alpha)).astype(a.dtype)
    # dA_i = g @ B_i^T : batched GEMM with g broadcast (zero-copy index_map)
    da = K.batched_matmul_pallas(
        galpha, jnp.swapaxes(b, -1, -2), interpret=cfg.interpret
    ).astype(a.dtype)
    # dB_i = A_i^T @ g
    db = K.batched_matmul_pallas(
        jnp.swapaxes(a, -1, -2), galpha, interpret=cfg.interpret
    ).astype(b.dtype)
    dbias = g.sum(axis=0).astype(bias.dtype) if bias is not None else None
    dc0 = (g * jnp.float32(cfg.beta)).astype(c0.dtype) if c0 is not None else None
    return da, db, dbias, dc0


_brgemm_p.defvjp(_brgemm_fwd, _brgemm_bwd)


def brgemm(
    a,
    b,
    bias=None,
    c0=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    beta: float = 0.0,
    out_dtype=None,
    backend: str | None = None,
    blocks: Blocks | None = None,
):
    """The paper's batch-reduce GEMM. a: (B, m, k), b: (B, k, n) -> (m, n)."""
    be = resolve_backend(backend)
    if be == "xla":
        return R.brgemm_ref(
            a, b, c0, bias, activation=activation, alpha=alpha, beta=beta,
            out_dtype=out_dtype)
    cfg = _Cfg(activation, float(alpha), float(beta), out_dtype, blocks,
               _interpret())
    return _brgemm_p(cfg, a, b, bias, c0)


def batched_matmul(
    a,
    b,
    bias=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    out_dtype=None,
    backend: str | None = None,
    blocks: Blocks | None = None,
):
    """Strided-batched GEMM baseline (no cross-batch reduction)."""
    be = resolve_backend(backend)
    if be == "xla":
        return R.batched_matmul_ref(
            a, b, bias, activation=activation, alpha=alpha,
            out_dtype=out_dtype)
    return K.batched_matmul_pallas(
        a, b, bias, activation=activation, alpha=float(alpha),
        out_dtype=out_dtype, blocks=blocks, interpret=_interpret())
