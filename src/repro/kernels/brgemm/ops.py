"""Public, differentiable entry points for the batch-reduce GEMM kernel.

Backend dispatch goes through ``repro.core.dispatch``: each primitive
(``matmul``, ``brgemm``, ``batched_matmul``) registers two backends,

  * ``pallas``  — the Pallas TPU kernel (kernel.py). On CPU it runs in
    interpret mode (Python evaluation of the kernel body) for correctness
    validation; on TPU it compiles via Mosaic.
  * ``xla``     — the pure-jnp reference (ref.py). Bit-comparable numerics
    (fp32 accumulation, identical epilogues). This path is used for the
    512-device dry-run and CPU-scale smoke tests, where interpreting a
    Python kernel under a production mesh is meaningless.

and the ``backend=`` kwarg is the explicit-call-argument tier of the
dispatch precedence (call arg > context > env > hardware default).  Block
geometry and interpret mode resolve through the active
``repro.use(...)`` context; block selection is memoized in the dispatch
tuning cache keyed (op, backend, shapes, dtype, policy, mesh signature).
The (m, n, k) each entry point reports to ``resolve_blocks`` is the
*global* problem it was called with — under ``repro.use(mesh=...)``
dispatch maps it to the per-device local shard before tuning, so the same
call site gets global-shape tiles in single-device runs and per-shard
tiles under a production mesh with no threading here.

The custom VJP expresses the backward passes through the *same* building
block, mirroring the paper's claim that fwd/bwd/upd all reduce to
batch-reduce GEMM calls:
    dX = dPre @ W^T        (brgemm over K-blocks)
    dW = X^T @ dPre        (brgemm: reduction dim = minibatch, cf. paper 4.1.1 "upd")
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dispatch, fusion
from repro.core.blocking import Blocks
from repro.core.dispatch import (  # noqa: F401  (deprecated shims, re-exported)
    resolve_backend,
    set_default_backend,
)
from repro.kernels.brgemm import kernel as K
from repro.kernels.brgemm import quant as Q
from repro.kernels.brgemm import ref as R


class _Cfg(NamedTuple):
    activation: str
    alpha: float
    beta: float
    out_dtype: object
    blocks: Blocks | None
    interpret: bool
    acc_dtype: object


def _make_cfg(op, m, n, k, dtype, activation, alpha, beta, out_dtype,
              blocks) -> _Cfg:
    """Resolve context-dependent knobs (trace-time) into a hashable config."""
    blk = dispatch.resolve_blocks(op, m, n, k, dtype, backend="pallas",
                                  blocks=blocks)
    return _Cfg(activation, float(alpha), float(beta), out_dtype, blk,
                dispatch.resolve_interpret(), dispatch.resolve_accum_dtype())


# --------------------------------------------------------------------------
# matmul: C = act(alpha * X @ W + beta * C0 + bias)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _matmul_p(cfg: _Cfg, x, w, bias, c0):
    return K.matmul_pallas(
        x, w, bias, c0,
        activation=cfg.activation, alpha=cfg.alpha, beta=cfg.beta,
        out_dtype=cfg.out_dtype, blocks=cfg.blocks, interpret=cfg.interpret,
        acc_dtype=cfg.acc_dtype,
    )


def _matmul_fwd(cfg, x, w, bias, c0):
    y = _matmul_p(cfg, x, w, bias, c0)
    return y, (x, w, bias, c0, y)


def _act_bar(cfg, res, dy):
    """dy * act'(pre) in fp32, recomputing pre only when required."""
    x, w, bias, c0, y = res
    dy32 = dy.astype(jnp.float32)
    if not fusion.needs_preact(cfg.activation):
        return dy32 * fusion.GRAD_FROM_OUTPUT[cfg.activation](
            y.astype(jnp.float32))
    pre = K.matmul_pallas(
        x, w, bias, c0, activation="none", alpha=cfg.alpha, beta=cfg.beta,
        out_dtype=jnp.float32, blocks=cfg.blocks, interpret=cfg.interpret)
    return dy32 * fusion.GRAD_FROM_PREACT[cfg.activation](pre)


def _matmul_bwd(cfg, res, dy):
    x, w, bias, c0, y = res
    g = _act_bar(cfg, res, dy)  # fp32, (m, n)
    galpha = (g * jnp.float32(cfg.alpha)).astype(x.dtype)
    dx = K.matmul_pallas(
        galpha, w.T, interpret=cfg.interpret).astype(x.dtype)
    dw = K.matmul_pallas(
        x.T, galpha, interpret=cfg.interpret).astype(w.dtype)
    dbias = None
    if bias is not None:
        dbias = g.sum(axis=0).astype(bias.dtype)
    dc0 = None
    if c0 is not None:
        dc0 = (g * jnp.float32(cfg.beta)).astype(c0.dtype)
    return dx, dw, dbias, dc0


_matmul_p.defvjp(_matmul_fwd, _matmul_bwd)


@dispatch.register("matmul", "pallas", available=dispatch.pallas_available,
                   priority=10)
def _matmul_pallas_backend(x, w, bias, c0, *, activation, alpha, beta,
                           out_dtype, blocks):
    m, k = x.shape
    n = w.shape[-1]
    cfg = _make_cfg("matmul", m, n, k, x.dtype, activation, alpha, beta,
                    out_dtype, blocks)
    return _matmul_p(cfg, x, w, bias, c0)


@dispatch.register("matmul", "xla")
def _matmul_xla_backend(x, w, bias, c0, *, activation, alpha, beta,
                        out_dtype, blocks):
    del blocks  # tiling is an XLA-internal decision on this path
    return R.matmul_ref(
        x, w, bias, activation=activation, alpha=alpha, beta=beta, c0=c0,
        out_dtype=out_dtype, acc_dtype=dispatch.resolve_accum_dtype())


def matmul(
    x,
    w,
    bias=None,
    c0=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    beta: float = 0.0,
    out_dtype=None,
    backend: str | None = None,
    blocks: Blocks | None = None,
    quant=None,
):
    """Batch-reduce GEMM over K blocks; x may have any leading dims.

    Quantized execution is ambient: an active ``repro.use(quant=...)``
    context, an explicit ``quant=`` spec, or a pre-quantized
    :class:`~repro.core.quantize.QuantizedTensor` weight routes this call
    to the int8/fp8 kernel with its fused dequant epilogue — same
    signature, no call-site changes.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    qcfg = Q.active_quant(w, quant)
    if qcfg is not None and quant is None and c0 is not None and beta != 0.0:
        # Accumulator-chained GEMMs (LSTM gates) have no quantized form;
        # an *ambient* context degrades them to full precision.  An
        # explicit quant= arg falls through and raises.
        qcfg = None
        if isinstance(w, Q.QuantizedTensor):
            w = w.dequantize().astype(x.dtype)
    if qcfg is not None:
        y = Q.matmul_q(x2, w, bias, c0, activation=activation, alpha=alpha,
                       beta=beta, out_dtype=out_dtype, backend=backend,
                       blocks=blocks, qcfg=qcfg)
        return y.reshape(*lead, w.shape[-1])
    c02 = c0.reshape(-1, c0.shape[-1]) if c0 is not None else None
    impl = dispatch.get_impl("matmul", backend)
    y = impl(x2, w, bias, c02, activation=activation, alpha=alpha,
             beta=beta, out_dtype=out_dtype, blocks=blocks)
    return y.reshape(*lead, w.shape[-1])


# --------------------------------------------------------------------------
# brgemm (stacked blocks): C = act(alpha * sum_i A_i @ B_i + beta*C0 + bias)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _brgemm_p(cfg: _Cfg, a, b, bias, c0):
    return K.brgemm_stacked_pallas(
        a, b, c0, bias,
        activation=cfg.activation, alpha=cfg.alpha, beta=cfg.beta,
        out_dtype=cfg.out_dtype, blocks=cfg.blocks, interpret=cfg.interpret,
        acc_dtype=cfg.acc_dtype,
    )


def _brgemm_fwd(cfg, a, b, bias, c0):
    y = _brgemm_p(cfg, a, b, bias, c0)
    return y, (a, b, bias, c0, y)


def _brgemm_bwd(cfg, res, dy):
    a, b, bias, c0, y = res
    dy32 = dy.astype(jnp.float32)
    if not fusion.needs_preact(cfg.activation):
        g = dy32 * fusion.GRAD_FROM_OUTPUT[cfg.activation](
            y.astype(jnp.float32))
    else:
        pre = K.brgemm_stacked_pallas(
            a, b, c0, bias, activation="none", alpha=cfg.alpha, beta=cfg.beta,
            out_dtype=jnp.float32, blocks=cfg.blocks, interpret=cfg.interpret)
        g = dy32 * fusion.GRAD_FROM_PREACT[cfg.activation](pre)
    galpha = (g * jnp.float32(cfg.alpha)).astype(a.dtype)
    # dA_i = g @ B_i^T : batched GEMM with g broadcast (zero-copy index_map)
    da = K.batched_matmul_pallas(
        galpha, jnp.swapaxes(b, -1, -2), interpret=cfg.interpret
    ).astype(a.dtype)
    # dB_i = A_i^T @ g
    db = K.batched_matmul_pallas(
        jnp.swapaxes(a, -1, -2), galpha, interpret=cfg.interpret
    ).astype(b.dtype)
    dbias = g.sum(axis=0).astype(bias.dtype) if bias is not None else None
    dc0 = (g * jnp.float32(cfg.beta)).astype(c0.dtype) if c0 is not None else None
    return da, db, dbias, dc0


_brgemm_p.defvjp(_brgemm_fwd, _brgemm_bwd)


@dispatch.register("brgemm", "pallas", available=dispatch.pallas_available,
                   priority=10)
def _brgemm_pallas_backend(a, b, bias, c0, *, activation, alpha, beta,
                           out_dtype, blocks):
    _, m, k = a.shape
    n = b.shape[-1]
    cfg = _make_cfg("brgemm", m, n, k, a.dtype, activation, alpha, beta,
                    out_dtype, blocks)
    return _brgemm_p(cfg, a, b, bias, c0)


@dispatch.register("brgemm", "xla")
def _brgemm_xla_backend(a, b, bias, c0, *, activation, alpha, beta,
                        out_dtype, blocks):
    del blocks
    return R.brgemm_ref(
        a, b, c0, bias, activation=activation, alpha=alpha, beta=beta,
        out_dtype=out_dtype, acc_dtype=dispatch.resolve_accum_dtype())


def brgemm(
    a,
    b,
    bias=None,
    c0=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    beta: float = 0.0,
    out_dtype=None,
    backend: str | None = None,
    blocks: Blocks | None = None,
    quant=None,
):
    """The paper's batch-reduce GEMM. a: (B, m, k), b: (B, k, n) -> (m, n)."""
    qcfg = Q.active_quant(b, quant)
    if qcfg is not None and quant is None and c0 is not None and beta != 0.0:
        qcfg = None  # see matmul: ambient quant skips accumulator chains
        if isinstance(b, Q.QuantizedTensor):
            b = b.dequantize().astype(a.dtype)
    if qcfg is not None:
        return Q.brgemm_q(a, b, bias, c0, activation=activation, alpha=alpha,
                          beta=beta, out_dtype=out_dtype, backend=backend,
                          blocks=blocks, qcfg=qcfg)
    impl = dispatch.get_impl("brgemm", backend)
    return impl(a, b, bias, c0, activation=activation, alpha=alpha,
                beta=beta, out_dtype=out_dtype, blocks=blocks)


# --------------------------------------------------------------------------
# batched_matmul: C_i = act(alpha * A_i @ B_i + bias)   (baseline, no reduce)
# --------------------------------------------------------------------------

@dispatch.register("batched_matmul", "pallas",
                   available=dispatch.pallas_available, priority=10)
def _batched_matmul_pallas_backend(a, b, bias, *, activation, alpha,
                                   out_dtype, blocks):
    m, k = a.shape[-2:]
    n = b.shape[-1]
    blk = dispatch.resolve_blocks("batched_matmul", m, n, k, a.dtype,
                                  backend="pallas", blocks=blocks)
    return K.batched_matmul_pallas(
        a, b, bias, activation=activation, alpha=float(alpha),
        out_dtype=out_dtype, blocks=blk,
        interpret=dispatch.resolve_interpret(),
        acc_dtype=dispatch.resolve_accum_dtype())


@dispatch.register("batched_matmul", "xla")
def _batched_matmul_xla_backend(a, b, bias, *, activation, alpha, out_dtype,
                                blocks):
    del blocks
    return R.batched_matmul_ref(
        a, b, bias, activation=activation, alpha=alpha, out_dtype=out_dtype,
        acc_dtype=dispatch.resolve_accum_dtype())


def batched_matmul(
    a,
    b,
    bias=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    out_dtype=None,
    backend: str | None = None,
    blocks: Blocks | None = None,
    quant=None,
):
    """Strided-batched GEMM baseline (no cross-batch reduction)."""
    qcfg = Q.active_quant(b, quant)
    if qcfg is not None:
        return Q.batched_matmul_q(a, b, bias, activation=activation,
                                  alpha=alpha, out_dtype=out_dtype,
                                  backend=backend, blocks=blocks, qcfg=qcfg)
    impl = dispatch.get_impl("batched_matmul", backend)
    return impl(a, b, bias, activation=activation, alpha=alpha,
                out_dtype=out_dtype, blocks=blocks)
