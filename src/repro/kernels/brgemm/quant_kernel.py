"""Quantized batch-reduce GEMM Pallas kernels with fused dequant epilogue.

The same loop nest as ``kernel.py`` — grid walks the reduce axis while the
accumulator block stays resident in VMEM — but the operands are int8 (or
fp8) storage and the accumulator is the dtype-implied one (int32 for int8
via the MXU's integer path, fp32 for fp8).  Dequantization is *never* a
separate pass: the per-row activation scales and per-channel weight scales
multiply the accumulator in the epilogue, fused with alpha/bias/activation
before the single HBM write-back, so the quantized kernel touches HBM
exactly as often as the full-precision one while streaming operand panels
at 1/2 (vs bf16) or 1/4 (vs fp32) the bytes.

Scales ride in TPU-legal layouts borrowed from the library's existing
idioms: row scales broadcast across ``SCALE_LANES`` lanes (the
flash-attention stats layout) so a ``(bm, SCALE_LANES)`` block is legal,
and channel scales use bias-style ``(1, bn)`` blocks.

For the stacked brgemm the scales are *batch-shared* (one absmax over the
whole (B, k) reduction panel per output row/channel): the accumulator sums
int32 products across the entire reduction before the one dequant, so
per-batch scales would be mathematically wrong, not just slower.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fusion
from repro.core import pallas_compat as _pc
from repro.core.blocking import Blocks, choose_blocks, round_up
from repro.kernels.brgemm.kernel import _pad2, _pad3

SCALE_LANES = 128  # lane-broadcast width for row-scale blocks


def _acc_dtype(storage_dtype) -> object:
    """int32 accumulation for int8 storage, fp32 for fp8."""
    return jnp.int32 if jnp.dtype(storage_dtype) == jnp.int8 else jnp.float32


def _row_scales(s, pm: int):
    """(rows,) fp32 -> (pm, SCALE_LANES) lane-broadcast, row-padded."""
    s = s.astype(jnp.float32)
    if s.shape[0] != pm:
        s = jnp.pad(s, (0, pm - s.shape[0]))
    return jnp.broadcast_to(s[:, None], (pm, SCALE_LANES))


def _col_scales(s, pn: int):
    """(cols,) fp32 -> (1, pn) bias-style block row."""
    return _pad2(s.astype(jnp.float32).reshape(1, -1), 1, pn)


def _dequant_finish(acc, sx_block, sw_block, bias_ref, alpha, activation,
                    out_dtype):
    """The fused epilogue: dequant x epilogue on the VMEM accumulator."""
    acc = acc.astype(jnp.float32)
    acc = acc * (sx_block[:, :1] * sw_block.astype(jnp.float32))
    acc = acc * jnp.float32(alpha)
    if bias_ref is not None:
        acc = acc + bias_ref[...].astype(jnp.float32)
    acc = fusion.apply(activation, acc)
    return acc.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "alpha", "out_dtype", "blocks",
                     "interpret"),
)
def matmul_q_pallas(
    xq,
    wq,
    sx,
    sw,
    bias=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    out_dtype=jnp.float32,
    blocks: Blocks | None = None,
    interpret: bool = False,
):
    """C = act(alpha * (Xq @ Wq) * (sx x sw) + bias).

    xq: (m, k) quantized activations with per-row scales sx: (m,) fp32;
    wq: (k, n) quantized weights with per-channel scales sw: (n,) fp32
    (per-tensor configs pass broadcast scales).  The K grid axis is the
    batch-reduce; dequant happens once, in the epilogue.
    """
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2, (xq.shape, wq.shape)
    acc_dtype = _acc_dtype(xq.dtype)
    blk = blocks or choose_blocks(m, n, k, xq.dtype)
    bm, bn, bk = blk.astuple()

    xp = _pad2(xq, bm, bk)
    wp = _pad2(wq, bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, r: (i, r)),
        pl.BlockSpec((bk, bn), lambda i, j, r: (r, j)),
        pl.BlockSpec((bm, SCALE_LANES), lambda i, j, r: (i, 0)),
        pl.BlockSpec((1, bn), lambda i, j, r: (0, j)),
    ]
    operands = [xp, wp, _row_scales(sx, mp), _col_scales(sw, np_)]
    has_bias = bias is not None
    if has_bias:
        operands.append(_pad2(bias.reshape(1, -1), 1, bn))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, r: (0, j)))

    def body(x_ref, w_ref, sx_ref, sw_ref, *rest):
        bias_ref = rest[0] if has_bias else None
        out_ref = rest[1] if has_bias else rest[0]
        acc_ref = rest[-1]
        r = pl.program_id(2)

        @pl.when(r == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=acc_dtype)

        @pl.when(r == pl.num_programs(2) - 1)
        def _finish():
            out_ref[...] = _dequant_finish(
                acc_ref[...], sx_ref[...], sw_ref[...], bias_ref, alpha,
                activation, out_dtype)

    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=_pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("activation", "alpha", "out_dtype", "blocks",
                     "interpret"),
)
def brgemm_q_pallas(
    aq,
    bq,
    sa,
    sb,
    bias=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    out_dtype=jnp.float32,
    blocks: Blocks | None = None,
    interpret: bool = False,
):
    """C = act(alpha * (sum_i Aq_i @ Bq_i) * (sa x sb) + bias).

    aq: (B, m, k), bq: (B, k, n); sa: (m,), sb: (n,) fp32 — batch-shared
    scales (absmax over the full (B, k) reduction panel), so the single
    end-of-reduction dequant is exact for the summed accumulator.
    """
    nb, m, k = aq.shape
    nb2, k2, n = bq.shape
    assert nb == nb2 and k == k2, (aq.shape, bq.shape)
    acc_dtype = _acc_dtype(aq.dtype)
    blk = blocks or choose_blocks(m, n, k, aq.dtype)
    bm, bn, bk = blk.astuple()

    ap = _pad3(aq, 1, bm, bk)
    bp = _pad3(bq, 1, bk, bn)
    mp, kp = ap.shape[1], ap.shape[2]
    np_ = bp.shape[2]
    kb = kp // bk
    grid = (mp // bm, np_ // bn, nb * kb)

    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda i, j, r: (r // kb, i, r % kb)),
        pl.BlockSpec((1, bk, bn), lambda i, j, r: (r // kb, r % kb, j)),
        pl.BlockSpec((bm, SCALE_LANES), lambda i, j, r: (i, 0)),
        pl.BlockSpec((1, bn), lambda i, j, r: (0, j)),
    ]
    operands = [ap, bp, _row_scales(sa, mp), _col_scales(sb, np_)]
    has_bias = bias is not None
    if has_bias:
        operands.append(_pad2(bias.reshape(1, -1), 1, bn))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, r: (0, j)))

    def body(a_ref, b_ref, sa_ref, sb_ref, *rest):
        bias_ref = rest[0] if has_bias else None
        out_ref = rest[1] if has_bias else rest[0]
        acc_ref = rest[-1]
        r = pl.program_id(2)

        @pl.when(r == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                                preferred_element_type=acc_dtype)

        @pl.when(r == pl.num_programs(2) - 1)
        def _finish():
            out_ref[...] = _dequant_finish(
                acc_ref[...], sa_ref[...], sb_ref[...], bias_ref, alpha,
                activation, out_dtype)

    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=_pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("activation", "alpha", "out_dtype", "blocks",
                     "interpret"),
)
def batched_matmul_q_pallas(
    aq,
    bq,
    sa,
    sb,
    bias=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    out_dtype=jnp.float32,
    blocks: Blocks | None = None,
    interpret: bool = False,
):
    """C_i = act(alpha * (Aq_i @ Bq_i) * (sa_i x sb_i) + bias).

    aq: (B, m, k) with per-batch-per-row scales sa: (B, m); bq: (B, k, n)
    with per-batch-per-channel scales sb: (B, n).  No cross-batch
    reduction, so scales are free to vary per batch entry.
    """
    nb, m, k = aq.shape
    nb2, k2, n = bq.shape
    assert nb == nb2 and k == k2, (aq.shape, bq.shape)
    acc_dtype = _acc_dtype(aq.dtype)
    blk = blocks or choose_blocks(m, n, k, aq.dtype)
    bm, bn, bk = blk.astuple()

    ap = _pad3(aq, 1, bm, bk)
    bp = _pad3(bq, 1, bk, bn)
    mp, kp = ap.shape[1], ap.shape[2]
    np_ = bp.shape[2]
    grid = (nb, mp // bm, np_ // bn, kp // bk)

    sa3 = sa.astype(jnp.float32)
    if sa3.shape[1] != mp:
        sa3 = jnp.pad(sa3, ((0, 0), (0, mp - sa3.shape[1])))
    sa3 = jnp.broadcast_to(sa3[..., None], (nb, mp, SCALE_LANES))
    sb3 = sb.astype(jnp.float32)[:, None, :]
    if sb3.shape[2] != np_:
        sb3 = jnp.pad(sb3, ((0, 0), (0, 0), (0, np_ - sb3.shape[2])))

    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda bi, i, j, r: (bi, i, r)),
        pl.BlockSpec((1, bk, bn), lambda bi, i, j, r: (bi, r, j)),
        pl.BlockSpec((1, bm, SCALE_LANES), lambda bi, i, j, r: (bi, i, 0)),
        pl.BlockSpec((1, 1, bn), lambda bi, i, j, r: (bi, 0, j)),
    ]
    operands = [ap, bp, sa3, sb3]
    has_bias = bias is not None
    if has_bias:
        operands.append(_pad2(bias.reshape(1, -1), 1, bn))
        in_specs.append(pl.BlockSpec((1, bn), lambda bi, i, j, r: (0, j)))

    def body(a_ref, b_ref, sa_ref, sb_ref, *rest):
        bias_ref = rest[0] if has_bias else None
        out_ref = rest[1] if has_bias else rest[0]
        acc_ref = rest[-1]
        r = pl.program_id(3)

        @pl.when(r == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                                preferred_element_type=acc_dtype)

        @pl.when(r == pl.num_programs(3) - 1)
        def _finish():
            out_ref[...] = _dequant_finish(
                acc_ref[...], sa_ref[0], sb_ref[0], bias_ref, alpha,
                activation, out_dtype)[None]

    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda bi, i, j, r: (bi, i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=_pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:, :m, :n]
