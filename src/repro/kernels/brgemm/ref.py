"""Pure-jnp oracle for the batch-reduce GEMM kernel.

Implements exactly   C = act( alpha * sum_i A_i @ B_i + beta * C0 + bias )
with fp32 accumulation, mirroring the Pallas kernel's numerics: inputs may be
bf16/fp32, the reduction and epilogue run in fp32, and the result is cast to
``out_dtype`` (default: the input dtype).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import fusion


def _finish(acc, c0, bias, alpha, beta, activation, out_dtype):
    acc = acc * jnp.float32(alpha)
    if c0 is not None and beta != 0.0:
        acc = acc + jnp.float32(beta) * c0.astype(jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    acc = fusion.apply(activation, acc)
    return acc.astype(out_dtype)


def brgemm_ref(
    a,
    b,
    c0=None,
    bias=None,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    activation: str = "none",
    out_dtype=None,
    acc_dtype=jnp.float32,
):
    """Stacked-blocks batch-reduce GEMM. a: (B, m, k), b: (B, k, n)."""
    out_dtype = out_dtype or a.dtype
    acc = jnp.einsum(
        "imk,ikn->mn", a, b, preferred_element_type=acc_dtype
    )
    return _finish(acc, c0, bias, alpha, beta, activation, out_dtype)


def matmul_ref(
    x,
    w,
    bias=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    beta: float = 0.0,
    c0=None,
    out_dtype=None,
    acc_dtype=jnp.float32,
):
    """Plain GEMM viewed as a batch-reduce over K blocks. x: (m,k), w: (k,n)."""
    out_dtype = out_dtype or x.dtype
    acc = jnp.dot(x, w, preferred_element_type=acc_dtype)
    return _finish(acc, c0, bias, alpha, beta, activation, out_dtype)


def batched_matmul_ref(
    a,
    b,
    bias=None,
    *,
    activation: str = "none",
    alpha: float = 1.0,
    out_dtype=None,
    acc_dtype=jnp.float32,
):
    """Strided-batched GEMM (the *baseline* the paper compares against).

    a: (B, m, k) or (m, k) broadcast; b: (B, k, n) or (k, n) broadcast.
    Returns (B, m, n).  No cross-batch reduction.
    """
    out_dtype = out_dtype or a.dtype
    if a.ndim == 2:
        acc = jnp.einsum("mk,ikn->imn", a, b, preferred_element_type=acc_dtype)
    elif b.ndim == 2:
        acc = jnp.einsum("imk,kn->imn", a, b, preferred_element_type=acc_dtype)
    else:
        acc = jnp.einsum("imk,ikn->imn", a, b, preferred_element_type=acc_dtype)
    acc = acc * jnp.float32(alpha)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    acc = fusion.apply(activation, acc)
    return acc.astype(out_dtype)
