"""Reference oracles for the direct convolution (paper Algorithms 3/4).

Two references:
  * ``conv2d_ref``      — lax.conv_general_dilated (NHWC / RSCK), the fast
    oracle used by tests and the XLA backend path.
  * ``conv2d_loops_ref``— the paper's Algorithm 3 loop nest in pure Python/
    numpy, used on tiny shapes to pin the *semantics* (stride handling,
    padding, channel blocking) independently of XLA.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fusion


def conv2d_ref(x, w, bias=None, *, stride: int = 1, padding: int = 0,
               activation: str = "none", out_dtype=None):
    """x: (N, H, W, C), w: (R, S, C, K) -> (N, P, Q, K)."""
    out_dtype = out_dtype or x.dtype
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    y = fusion.apply(activation, y)
    return y.astype(out_dtype)


def conv2d_loops_ref(x, w, *, stride: int = 1, padding: int = 0):
    """Paper Algorithm 3 as literal loops (tiny shapes only)."""
    x = np.asarray(x, np.float32)
    w = np.asarray(w, np.float32)
    n_, h, wi, c = x.shape
    r_, s_, _, k = w.shape
    xp = np.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    p = (h + 2 * padding - r_) // stride + 1
    q = (wi + 2 * padding - s_) // stride + 1
    out = np.zeros((n_, p, q, k), np.float32)
    for n in range(n_):
        for oj in range(p):
            for oi in range(q):
                for r in range(r_):
                    for s in range(s_):
                        ij = oj * stride + r
                        ii = oi * stride + s
                        out[n, oj, oi, :] += xp[n, ij, ii, :] @ w[r, s, :, :]
    return jnp.asarray(out)
