"""Differentiable conv2d with dual-convolution backward — paper Sec. 3.2.2.

The paper (citing [27]) implements backward-by-data and weight-update as
"dual convolutions": linear index transformations of the forward loop nest,
reusing the same batch-reduce building block.  Here:

  * dgrad  = conv2d( dilate_{stride}(g), rot180(W) swapped C<->K, stride=1 )
             — runs through the *same* Pallas conv kernel,
  * wgrad  = per-(r,s) batch-reduce GEMM  X_(r,s)^T @ g  with the minibatch
             as the reduction dimension (the paper's "upd" pass, Sec. 4.1.1),
  * bias   = spatial/minibatch sum of g.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dispatch, fusion
from repro.core.blocking import ConvBlocks, ConvGeometry
from repro.kernels.brgemm import kernel as BK
from repro.kernels.conv2d import ref as R
from repro.kernels.conv2d.kernel import conv2d_pallas


class _Cfg(NamedTuple):
    stride: int
    padding: int
    activation: str
    out_dtype: object
    blocks: ConvBlocks | None
    interpret: bool
    acc_dtype: object


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _conv_p(cfg: _Cfg, x, w, bias):
    return conv2d_pallas(
        x, w, bias, stride=cfg.stride, padding=cfg.padding,
        activation=cfg.activation, out_dtype=cfg.out_dtype,
        blocks=cfg.blocks, interpret=cfg.interpret,
        acc_dtype=cfg.acc_dtype)


def _conv_fwd(cfg, x, w, bias):
    y = _conv_p(cfg, x, w, bias)
    return y, (x, w, bias, y)


def _dilate(g, stride):
    if stride == 1:
        return g
    n, p, q, k = g.shape
    out = jnp.zeros((n, (p - 1) * stride + 1, (q - 1) * stride + 1, k),
                    g.dtype)
    return out.at[:, ::stride, ::stride, :].set(g)


def _conv_bwd(cfg, res, dy):
    x, w, bias, y = res
    st, pad = cfg.stride, cfg.padding
    n, h, wi, c = x.shape
    r_, s_, _, k = w.shape
    p = (h + 2 * pad - r_) // st + 1
    q = (wi + 2 * pad - s_) // st + 1

    dy32 = dy.astype(jnp.float32)
    if not fusion.needs_preact(cfg.activation):
        g = dy32 * fusion.GRAD_FROM_OUTPUT[cfg.activation](
            y.astype(jnp.float32))
    else:
        pre = conv2d_pallas(
            x, w, bias, stride=st, padding=pad, activation="none",
            out_dtype=jnp.float32, blocks=cfg.blocks,
            interpret=cfg.interpret)
        g = dy32 * fusion.GRAD_FROM_PREACT[cfg.activation](pre)
    g = g.astype(x.dtype)

    # --- dgrad: dual convolution through the same Pallas kernel ----------
    g_dil = _dilate(g, st)
    # bottom/right coverage pad so the dual conv reproduces dx of shape H, W
    extra_h = h - ((p - 1) * st + r_ - 2 * pad)
    extra_w = wi - ((q - 1) * st + s_ - 2 * pad)
    g_dil = jnp.pad(g_dil, ((0, 0), (0, extra_h), (0, extra_w), (0, 0)))
    w_dual = jnp.flip(w, axis=(0, 1)).swapaxes(2, 3)  # (R, S, K, C)
    dx = conv2d_pallas(
        g_dil, w_dual, stride=1, padding=r_ - 1 - pad,
        out_dtype=jnp.float32, interpret=cfg.interpret).astype(x.dtype)

    # --- wgrad: batch-reduce GEMM per (r, s); reduce dim = minibatch -----
    xpad = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    g2 = g.reshape(n * p * q, k)
    dw_rs = []
    for r in range(r_):
        for s in range(s_):
            xs = jax.lax.slice(
                xpad,
                (0, r, s, 0),
                (n, r + (p - 1) * st + 1, s + (q - 1) * st + 1, c),
                (1, st, st, 1),
            ).reshape(n * p * q, c)
            dw_rs.append(BK.matmul_pallas(
                xs.T, g2, out_dtype=jnp.float32,
                interpret=cfg.interpret))
    dw = jnp.stack(dw_rs).reshape(r_, s_, c, k).astype(w.dtype)

    dbias = None
    if bias is not None:
        dbias = g.astype(jnp.float32).sum(axis=(0, 1, 2)).astype(bias.dtype)
    return dx, dw, dbias


_conv_p.defvjp(_conv_fwd, _conv_bwd)


@dispatch.register("conv2d", "pallas", available=dispatch.pallas_available,
                   priority=10)
def _conv2d_pallas_backend(x, w, bias, *, stride, padding, activation,
                           out_dtype, blocks):
    n, h, wi, c = x.shape
    r_, s_, _, k = w.shape
    q = (wi + 2 * padding - s_) // stride + 1
    blk = dispatch.resolve_blocks("conv2d", q, c, k, x.dtype,
                                  backend="pallas", blocks=blocks,
                                  geometry=ConvGeometry(stride, r_, s_))
    cfg = _Cfg(stride, padding, activation, out_dtype, blk,
               dispatch.resolve_interpret(), dispatch.resolve_accum_dtype())
    return _conv_p(cfg, x, w, bias)


@dispatch.register("conv2d", "xla")
def _conv2d_xla_backend(x, w, bias, *, stride, padding, activation,
                        out_dtype, blocks):
    del blocks  # tiling is an XLA-internal decision on this path
    return R.conv2d_ref(
        x, w, bias, stride=stride, padding=padding, activation=activation,
        out_dtype=out_dtype)


def conv2d(
    x,
    w,
    bias=None,
    *,
    stride: int = 1,
    padding: int = 0,
    activation: str = "none",
    out_dtype=None,
    backend: str | None = None,
    blocks: ConvBlocks | None = None,
):
    """Direct convolution via batch-reduce GEMM. NHWC x RSCK -> NHWC.

    ``blocks`` (a ``ConvBlocks``) is the explicit tier-1 geometry override;
    by default the tile resolves through ``dispatch.resolve_blocks`` under
    the active ``repro.use(blocks_policy=...)`` — and per-shard under
    ``repro.use(mesh=...)``, where the out-channel dim (the canonical
    ``k``) localizes over the model axis before tuning.
    """
    impl = dispatch.get_impl("conv2d", backend)
    return impl(x, w, bias, stride=stride, padding=padding,
                activation=activation, out_dtype=out_dtype, blocks=blocks)
