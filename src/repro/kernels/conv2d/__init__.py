from repro.core.blocking import ConvBlocks  # noqa: F401
from repro.kernels.conv2d.ops import conv2d  # noqa: F401
