from repro.kernels.conv2d.ops import conv2d  # noqa: F401
