"""Direct convolution as a batch-reduce GEMM Pallas kernel — paper Alg 4.

Mapping (DESIGN.md Sec. 2):

  * the paper's pointer-list walk over (r, s, c_b) becomes the innermost
    ("arbitrary") grid axis of size R*S*Cb; the ``BlockSpec.index_map``
    computes which weight panel and which input row each step needs — the
    TPU-native expression of A_ptrs/B_ptrs,
  * the output block O[n, oj, oi:oi+bq, kb*bk:...] accumulates in fp32 VMEM
    scratch across all R*S*Cb steps and is written to HBM exactly once —
    the paper's "accumulation chain stays in registers",
  * no im2col: the input stays in its (N, H, W, C) layout; each grid step
    streams one (row, channel-block) panel into VMEM and the in-kernel
    dynamic slice picks the (s, stride) phase,
  * bias + activation are fused on the accumulator (paper Sec. 3.2.2).

Stride handling: BlockSpecs cannot stride within a block, so the kernel
loads ``bq*stride`` contiguous input columns and subsamples in-register
(``reshape(bq, stride, bc)[:, 0]``) — the TPU-legal analogue of the paper's
``leading dimension = str * b_c`` trick.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import dispatch
from repro.core import pallas_compat as _pc
from repro.core import fusion
from repro.core.blocking import ConvBlocks, ConvGeometry, round_up


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "activation", "out_dtype",
                     "blocks", "interpret", "acc_dtype"),
)
def conv2d_pallas(
    x,
    w,
    bias=None,
    *,
    stride: int = 1,
    padding: int = 0,
    activation: str = "none",
    out_dtype=None,
    blocks: ConvBlocks | None = None,
    interpret: bool = False,
    acc_dtype=jnp.float32,
):
    """x: (N, H, W, C), w: (R, S, C, K) -> (N, P, Q, K).

    Tile geometry comes from ``blocks`` (a ``ConvBlocks``); when unset it
    resolves through ``dispatch.resolve_blocks`` under the active block
    policy — the kernel itself makes no geometry choices.  The requested
    tile is clipped to the padded problem so any VMEM-feasible candidate
    is legal.
    """
    n, h, wi, c = x.shape
    r_, s_, c2, k = w.shape
    assert c == c2, (x.shape, w.shape)
    out_dtype = out_dtype or x.dtype
    p = (h + 2 * padding - r_) // stride + 1
    q = (wi + 2 * padding - s_) // stride + 1

    blk = blocks or dispatch.resolve_blocks(
        "conv2d", q, c, k, x.dtype, backend="pallas",
        geometry=ConvGeometry(stride, r_, s_))
    bq = min(round_up(q, 8), blk.bq)
    bc = min(round_up(c, 128), blk.bc)
    bk = min(round_up(k, 128), blk.bk)
    qp = round_up(q, bq)
    cp = round_up(c, bc)
    kp = round_up(k, bk)
    cb_ = cp // bc
    kb_ = kp // bk

    # Host-side one-time padding (amortized like the paper's weight
    # reformatting): spatial pad + right-pad W so every (oib, s, stride)
    # dynamic slice stays in bounds.
    need_w = (qp - 1) * stride + (s_ - 1) + stride
    xp = jnp.pad(
        x,
        ((0, 0), (padding, padding),
         (padding, max(padding, need_w - wi - padding)), (0, cp - c)),
    )
    wp_ = jnp.pad(w, ((0, 0), (0, 0), (0, cp - c), (0, kp - k)))
    wf = wp_.reshape(r_ * s_, cp, kp)  # (RS, C, K): panel per (r, s)
    wpad = xp.shape[2]

    nsteps = r_ * s_ * cb_
    grid = (n, kb_, p, qp // bq, nsteps)

    def x_index(ni, kbi, oj, oib, rsc):
        r = rsc // (s_ * cb_)
        cb = rsc % cb_
        return (ni, oj * stride + r, 0, cb)

    def w_index(ni, kbi, oj, oib, rsc):
        rs = rsc // cb_
        cb = rsc % cb_
        return (rs, cb, kbi)

    in_specs = [
        pl.BlockSpec((1, 1, wpad, bc), x_index),
        pl.BlockSpec((1, bc, bk), w_index),
    ]
    operands = [xp, wf]
    has_bias = bias is not None
    if has_bias:
        bp = jnp.pad(bias.reshape(1, -1), ((0, 0), (0, kp - k)))
        operands.append(bp)
        in_specs.append(
            pl.BlockSpec((1, bk), lambda ni, kbi, oj, oib, rsc: (0, kbi)))

    def body(*refs):
        x_ref, w_ref = refs[0], refs[1]
        bias_ref = refs[2] if has_bias else None
        out_ref = refs[3] if has_bias else refs[2]
        acc_ref = refs[-1]

        rsc = pl.program_id(4)
        oib = pl.program_id(3)

        @pl.when(rsc == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        s = (rsc // cb_) % s_
        row = x_ref[0, 0]                      # (wpad, bc)
        start = oib * (bq * stride) + s
        patch = jax.lax.dynamic_slice(
            row, (start, 0), (bq * stride, bc))
        if stride > 1:
            patch = patch.reshape(bq, stride, bc)[:, 0, :]
        acc_ref[...] += jnp.dot(
            patch, w_ref[0], preferred_element_type=acc_dtype)

        @pl.when(rsc == nsteps - 1)
        def _():
            acc = acc_ref[...]
            if bias_ref is not None:
                acc += bias_ref[...].astype(jnp.float32)
            acc = fusion.apply(activation, acc)
            out_ref[...] = acc.astype(out_dtype)[None, None]

    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, bq, bk), lambda ni, kbi, oj, oib, rsc: (ni, oj, oib, kbi)),
        out_shape=jax.ShapeDtypeStruct((n, p, qp, kp), out_dtype),
        scratch_shapes=[pltpu.VMEM((bq, bk), acc_dtype)],
        compiler_params=_pc.CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:, :, :q, :k]
