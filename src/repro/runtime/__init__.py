"""Runtime services shared by training and serving.

The fault-tolerance primitives here are used in two places: the training
driver (``run_with_restarts`` around a checkpointed step function) and
the serving cluster (``repro.serve.health.ClusterHealth`` builds its
per-step watchdog on ``HeartbeatMonitor`` and its straggler quarantine
on ``StragglerDetector``), so they are exported at package level.
"""
from repro.runtime.fault_tolerance import (  # noqa: F401
    HeartbeatMonitor,
    StragglerDetector,
    WorkerFailure,
    run_with_restarts,
)
