"""Fault-tolerance runtime: restart-from-latest, heartbeats, stragglers,
elastic rescale.

On a real cluster the coordinator runs per-host; here the same logic is
driven by a simulated host set so the policies are testable on CPU:

  * ``HeartbeatMonitor`` — hosts report (step, timestamp); a host silent for
    ``timeout_s`` is declared dead -> triggers restore-from-latest on a
    shrunken mesh (elastic rescale, see CheckpointManager.restore).
  * ``StragglerDetector`` — per-step durations; a host slower than
    ``factor`` x median for ``patience`` consecutive steps is flagged for
    eviction (at scale: replaced by a hot spare; the checkpoint/restore path
    is identical to failure recovery).
  * ``run_with_restarts`` — the training-driver wrapper: catches worker
    failure, restores the latest checkpoint, rebuilds the data stream at
    the restored step, and continues.

The serving stack reuses the first two for self-healing
(``repro.serve.health``): one heartbeat host per engine replica — a beat
immediately before each step attempt makes ``dead_hosts`` the per-step
hang watchdog — and the straggler detector quarantines replicas that
drag cluster p99.  Import them via ``repro.runtime``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np


@dataclasses.dataclass
class HostStatus:
    step: int = -1
    last_seen: float = 0.0
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, *, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.hosts = {i: HostStatus() for i in range(n_hosts)}

    def beat(self, host: int, step: int, now: float | None = None):
        now = time.monotonic() if now is None else now
        st = self.hosts[host]
        st.step, st.last_seen, st.alive = step, now, True

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        out = []
        for h, st in self.hosts.items():
            if st.alive and now - st.last_seen > self.timeout_s:
                st.alive = False
            if not st.alive:
                out.append(h)
        return out


class StragglerDetector:
    def __init__(self, n_hosts: int, *, factor: float = 2.0,
                 patience: int = 3):
        self.factor = factor
        self.patience = patience
        self.strikes = {i: 0 for i in range(n_hosts)}

    def observe(self, durations: dict[int, float]) -> list[int]:
        """durations: host -> step wall time. Returns flagged hosts."""
        med = float(np.median(list(durations.values())))
        flagged = []
        for h, d in durations.items():
            if d > self.factor * med:
                self.strikes[h] += 1
            else:
                self.strikes[h] = 0
            if self.strikes[h] >= self.patience:
                flagged.append(h)
        return flagged


class WorkerFailure(RuntimeError):
    pass


def run_with_restarts(
    *,
    total_steps: int,
    ckpt,
    make_state: Callable[[], dict],
    step_fn: Callable[[dict, int], dict],
    save_every: int = 50,
    max_restarts: int = 10,
):
    """Generic driver: run step_fn with checkpoint/restart on failure.

    ``step_fn(state, step)`` may raise WorkerFailure (simulated or real);
    the driver restores the latest checkpoint and resumes.  Returns
    (final_state, n_restarts, steps_executed).
    """
    restarts = 0
    executed = 0
    state = make_state()
    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        state, start = ckpt.restore(state, latest)
        start += 1

    step = start
    while step < total_steps:
        try:
            state = step_fn(state, step)
            executed += 1
            if step % save_every == 0:
                ckpt.save(step, state)
            step += 1
        except WorkerFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            latest = ckpt.latest_step()
            state = make_state()
            if latest is not None:
                state, restored_step = ckpt.restore(state, latest)
                step = restored_step + 1
            else:
                step = 0
    return state, restarts, executed
