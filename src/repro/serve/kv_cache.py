"""KV-cache pools for continuous batching: slotted and paged.

``SlotKVCache`` is the slot-span pool: one device-resident cache tree sized
``(n_slots, max_len, ...)``, a host-side free-list allocator over slot
indices.  Capacity is bound by the *longest* request — every slot reserves
``max_len`` positions whether it needs them or not.

``PagedKVCache`` replaces the span per slot with fixed-size *pages*: each
growing cache leaf becomes a pool of ``n_pages`` pages (``page_size``
positions each) and every slot holds a page *table* — the address list a
paged decode batch-reduces over (``api.decode_step_paged``).  Pages are
allocated lazily as generation crosses page boundaries, so KV memory is
bound by *live tokens* (rounded up to a page), not by worst-case request
length; at equal memory the pool admits several times more concurrent
requests on mixed-length workloads.  Leaves whose shape does not grow with
``max_len`` (enc-dec cross-KV, recurrent states) stay slot-resident,
exactly as in the slotted pool.

Freeing a slot (or page) is purely host-side bookkeeping: stale device
state is never read again — page-table sentinels clip/drop on
gather/scatter and the attention length mask (``kv_len = pos + 1``) hides
anything beyond the live prefix.

With ``kv_quant="int8"`` the paged leaves are stored int8 with one fp32
absmax scale per page; dequantization is fused into the decode gather.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchCfg
from repro.models import api


class SlotKVCache:
    """Fixed-capacity slot pool with a free-list allocator.

    Attributes
    ----------
    cache:       the pooled cache pytree (batch dimension = ``n_slots``).
    batch_axes:  per-leaf batch-axis tree (``api.cache_batch_axes``) —
                 pass to ``api.decode_step_slots``.
    lengths:     (n_slots,) int32, valid kv length per slot (prompt +
                 generated); 0 for free slots.
    positions:   (n_slots,) int32, absolute position the slot's pending
                 token will be written at on the next decode step.
    alloc_count / free_count: lifetime counters (leak check:
                 after drain, ``alloc_count == free_count`` and
                 ``n_free == n_slots``).
    """

    def __init__(self, cfg: ArchCfg, n_slots: int, max_len: int, *,
                 src_len: int = 0):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.src_len = src_len
        self.cache = api.init_cache(cfg, n_slots, max_len, src_len)
        self.batch_axes = api.cache_batch_axes(cfg, max_len, src_len)
        self.lengths = np.zeros(n_slots, np.int32)
        self.positions = np.zeros(n_slots, np.int32)
        self.alloc_count = 0
        self.free_count = 0
        # LIFO over a descending stack => lowest free slot allocated first
        # (deterministic placement for tests and reproducible runs).
        self._free = list(range(n_slots - 1, -1, -1))

        def insert(pool, one, slot):
            return jax.tree.map(
                lambda p, o, a: jax.lax.dynamic_update_slice_in_dim(
                    p, o.astype(p.dtype), slot, axis=a),
                pool, one, self.batch_axes)

        self._insert = jax.jit(insert)

    # ---------------- allocator ----------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_slots

    def alloc(self) -> int | None:
        """Pop a free slot index, or None when the pool is full."""
        if not self._free:
            return None
        self.alloc_count += 1
        return self._free.pop()

    def free(self, slot: int) -> None:
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range [0, {self.n_slots})")
        if slot in self._free:
            raise ValueError(f"double free of slot {slot}")
        self.free_count += 1
        self.lengths[slot] = 0
        self.positions[slot] = 0
        self._free.append(slot)

    # ---------------- device state ----------------

    def request_cache(self):
        """A zeroed batch-1 cache in the pool's layout (prefill target).

        Built once and shared: jax arrays are immutable, and prefill
        returns an updated copy rather than mutating its input."""
        if not hasattr(self, "_request_cache"):
            self._request_cache = api.init_cache(self.cfg, 1, self.max_len,
                                                 self.src_len)
        return self._request_cache

    def insert(self, slot: int, request_cache) -> None:
        """Scatter a prefilled batch-1 cache into ``slot``."""
        self.cache = self._insert(self.cache, request_cache,
                                  jnp.int32(slot))

    def kv_bytes(self) -> int:
        """Device bytes held by the pool (for capacity-per-GB reporting)."""
        return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in jax.tree.leaves(self.cache))


class PagedKVCache:
    """Paged KV pool: page-pool leaves + per-slot page tables.

    Layout
    ------
    data:        the pool pytree.  Pageable leaves (``time_axes[leaf] >=
                 0``) hold ``n_pages`` pages at the leaf's batch axis and
                 ``page_size`` positions at its time axis; slot-resident
                 leaves keep ``n_slots`` at the batch axis.
    page_tables: (n_slots, pages_per_slot) int32.  Row ``s`` lists slot
                 ``s``'s pages in position order; entries past the
                 allocation hold the sentinel ``n_pages`` (clipped on
                 gather, dropped on scatter).
    scales:      with ``kv_quant``, one (n_pages,) fp32 scale array per
                 pageable leaf (flatten order), else None.
    lengths / positions: as in :class:`SlotKVCache`.

    The allocator is host-side and O(1) per op: a slot free-list plus a
    page free-list, with lifetime counters for leak checks
    (``page_alloc_count == page_free_count`` after drain).
    """

    def __init__(self, cfg: ArchCfg, n_slots: int, max_len: int, *,
                 page_size: int, n_pages: int | None = None,
                 src_len: int = 0, kv_quant: str | None = None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if not api.supports_paging(cfg):
            raise ValueError(
                f"paging is not supported for block={cfg.block!r} "
                f"(window={cfg.window}, n_patches={cfg.n_patches})")
        if kv_quant is not None and kv_quant != "int8":
            raise ValueError(
                f"kv_quant={kv_quant!r}: only 'int8' page storage is "
                "supported")
        self.cfg = cfg
        self.n_slots = n_slots
        self.page_size = page_size
        self.pages_per_slot = -(-max_len // page_size)
        self.max_len = self.pages_per_slot * page_size   # page-aligned view
        self.src_len = src_len
        self.n_pages = (n_pages if n_pages is not None
                        else n_slots * self.pages_per_slot)
        if self.n_pages < self.pages_per_slot:
            raise ValueError(
                f"n_pages={self.n_pages} cannot hold even one full slot "
                f"({self.pages_per_slot} pages)")
        self.kv_quant = kv_quant

        self.batch_axes = api.cache_batch_axes(cfg, self.max_len, src_len)
        self.time_axes = api.cache_time_axes(cfg, src_len)
        paged_tmpl = api.init_cache(cfg, self.n_pages, page_size, src_len)
        resident_tmpl = api.init_cache(cfg, n_slots, page_size, src_len)
        self.view_dtypes = tuple(
            x.dtype for x, t in zip(jax.tree.leaves(paged_tmpl),
                                    jax.tree.leaves(self.time_axes))
            if t != -1)
        if kv_quant:
            paged_tmpl = jax.tree.map(
                lambda x, t: (jnp.zeros(x.shape, jnp.int8) if t != -1
                              else x),
                paged_tmpl, self.time_axes)
            self.scales = tuple(
                jnp.zeros((self.n_pages,), jnp.float32)
                for t in jax.tree.leaves(self.time_axes) if t != -1)
        else:
            self.scales = None
        self.data = jax.tree.map(
            lambda pg, res, t: pg if t != -1 else res,
            paged_tmpl, resident_tmpl, self.time_axes)

        self.lengths = np.zeros(n_slots, np.int32)
        self.positions = np.zeros(n_slots, np.int32)
        # sentinel n_pages: clipped on gather, dropped on scatter
        self.page_tables = np.full((n_slots, self.pages_per_slot),
                                   self.n_pages, np.int32)
        self.pages_used = np.zeros(n_slots, np.int32)
        self.alloc_count = 0
        self.free_count = 0
        self.page_alloc_count = 0
        self.page_free_count = 0
        self._free = list(range(n_slots - 1, -1, -1))
        self._free_pages = list(range(self.n_pages - 1, -1, -1))

        page_size_ = page_size
        batch_axes, time_axes = self.batch_axes, self.time_axes

        def insert(data, scales, one, slot, page_ids):
            """Scatter a prefilled batch-1 view: pageable leaves split into
            pages and land at ``page_ids``; resident leaves slice in at
            ``slot``."""
            leaves, treedef = jax.tree.flatten(data)
            ones = treedef.flatten_up_to(one)
            a_l = treedef.flatten_up_to(batch_axes)
            t_l = treedef.flatten_up_to(time_axes)
            new_scales = list(scales) if scales is not None else None
            out, pi = [], 0
            for x, o, a, t in zip(leaves, ones, a_l, t_l):
                if t == -1:
                    out.append(jax.lax.dynamic_update_slice_in_dim(
                        x, o.astype(x.dtype), slot, axis=a))
                    continue
                pages = api.view_to_pages(o, a, t, page_size_)
                if scales is not None:
                    pages, sc = api._quant_pages(pages, a)
                    new_scales[pi] = new_scales[pi].at[page_ids].set(
                        sc, mode="drop")
                idx = (slice(None),) * a + (page_ids,)
                out.append(x.at[idx].set(pages.astype(x.dtype),
                                         mode="drop"))
                pi += 1
            new_data = jax.tree.unflatten(treedef, out)
            if scales is None:
                return new_data, None
            return new_data, tuple(new_scales)

        self._insert = jax.jit(insert)

    # ---------------- allocator ----------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_slots

    @property
    def page_occupancy(self) -> float:
        return 1.0 - len(self._free_pages) / self.n_pages

    @property
    def fragmentation(self) -> float:
        """Allocated-but-dead fraction: 1 - live tokens / paged capacity.

        Internal fragmentation only (partially filled trailing pages) —
        fixed-size pages cannot fragment externally.
        """
        cap = int(self.pages_used.sum()) * self.page_size
        if cap == 0:
            return 0.0
        return 1.0 - float(self.lengths.sum()) / cap

    def alloc(self) -> int | None:
        """Pop a free slot index, or None when the pool is full."""
        if not self._free:
            return None
        self.alloc_count += 1
        return self._free.pop()

    def alloc_pages(self, slot: int, n: int) -> bool:
        """Append ``n`` pages to ``slot``'s table; all-or-nothing."""
        if n <= 0:
            return True
        used = int(self.pages_used[slot])
        if used + n > self.pages_per_slot:
            raise ValueError(
                f"slot {slot}: {used}+{n} pages exceeds pages_per_slot="
                f"{self.pages_per_slot}")
        if len(self._free_pages) < n:
            return False
        for i in range(n):
            self.page_tables[slot, used + i] = self._free_pages.pop()
        self.pages_used[slot] = used + n
        self.page_alloc_count += n
        return True

    def ensure(self, slot: int, pos: int) -> bool:
        """Make sure the page containing position ``pos`` is allocated."""
        need = pos // self.page_size + 1
        return self.alloc_pages(slot, need - int(self.pages_used[slot]))

    def free(self, slot: int) -> None:
        """Release a slot and every page it holds."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range [0, {self.n_slots})")
        if slot in self._free:
            raise ValueError(f"double free of slot {slot}")
        used = int(self.pages_used[slot])
        for i in range(used):
            self._free_pages.append(int(self.page_tables[slot, i]))
        self.page_free_count += used
        self.page_tables[slot, :] = self.n_pages
        self.pages_used[slot] = 0
        self.free_count += 1
        self.lengths[slot] = 0
        self.positions[slot] = 0
        self._free.append(slot)

    # ---------------- device state ----------------

    def request_cache(self):
        """A zeroed batch-1 cache view (prefill target), length
        ``pages_per_slot * page_size``.  Built once and shared."""
        if not hasattr(self, "_request_cache"):
            self._request_cache = api.init_cache(self.cfg, 1, self.max_len,
                                                 self.src_len)
        return self._request_cache

    def insert(self, slot: int, request_cache, n_valid: int) -> bool:
        """Allocate pages for ``n_valid`` positions and scatter a prefilled
        batch-1 view into them.  False (nothing changed) when the page
        pool cannot cover the request yet — retryable next step."""
        need = -(-n_valid // self.page_size) - int(self.pages_used[slot])
        if not self.alloc_pages(slot, need):
            return False
        self.data, self.scales = self._insert(
            self.data, self.scales, request_cache, jnp.int32(slot),
            jnp.asarray(self.page_tables[slot]))
        return True

    def kv_bytes(self) -> int:
        """Device bytes held by the pool (pages + scales + resident)."""
        total = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                    for x in jax.tree.leaves(self.data))
        if self.scales is not None:
            total += sum(int(np.prod(s.shape)) * s.dtype.itemsize
                         for s in self.scales)
        return total
