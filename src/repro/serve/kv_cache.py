"""Slotted (paged-lite) KV-cache pool for continuous batching.

One device-resident cache tree sized ``(n_slots, max_len, ...)`` holds every
running request's KV/ring/recurrent state; a host-side free-list allocator
hands out slot indices.  The pool reuses the exact ``transformer.init_cache``
/ ``encdec.init_cache`` layouts, so batched decode stays a single
jit-compiled step over the full slot dimension — per-slot validity is
enforced by the existing attention length masking (``kv_len = pos + 1``),
not by reshaping the pool.

Slots are written two ways:

  * ``insert(slot, request_cache)`` scatters a freshly prefilled batch-1
    cache into the slot (one jit-compiled ``dynamic_update_slice`` per
    leaf, at that leaf's batch axis), and
  * the engine's batched decode step overwrites the pool wholesale with
    per-slot scatter updates (``api.decode_step_slots``).

Freeing a slot is purely a host-side bookkeeping operation: the stale
device state is never read again (length masking) and is overwritten by the
next prefill into that slot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchCfg
from repro.models import api


class SlotKVCache:
    """Fixed-capacity slot pool with a free-list allocator.

    Attributes
    ----------
    cache:       the pooled cache pytree (batch dimension = ``n_slots``).
    batch_axes:  per-leaf batch-axis tree (``api.cache_batch_axes``) —
                 pass to ``api.decode_step_slots``.
    lengths:     (n_slots,) int32, valid kv length per slot (prompt +
                 generated); 0 for free slots.
    positions:   (n_slots,) int32, absolute position the slot's pending
                 token will be written at on the next decode step.
    alloc_count / free_count: lifetime counters (leak check:
                 after drain, ``alloc_count == free_count`` and
                 ``n_free == n_slots``).
    """

    def __init__(self, cfg: ArchCfg, n_slots: int, max_len: int, *,
                 src_len: int = 0):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.src_len = src_len
        self.cache = api.init_cache(cfg, n_slots, max_len, src_len)
        self.batch_axes = api.cache_batch_axes(cfg, max_len, src_len)
        self.lengths = np.zeros(n_slots, np.int32)
        self.positions = np.zeros(n_slots, np.int32)
        self.alloc_count = 0
        self.free_count = 0
        # LIFO over a descending stack => lowest free slot allocated first
        # (deterministic placement for tests and reproducible runs).
        self._free = list(range(n_slots - 1, -1, -1))

        def insert(pool, one, slot):
            return jax.tree.map(
                lambda p, o, a: jax.lax.dynamic_update_slice_in_dim(
                    p, o.astype(p.dtype), slot, axis=a),
                pool, one, self.batch_axes)

        self._insert = jax.jit(insert)

    # ---------------- allocator ----------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_slots

    def alloc(self) -> int | None:
        """Pop a free slot index, or None when the pool is full."""
        if not self._free:
            return None
        self.alloc_count += 1
        return self._free.pop()

    def free(self, slot: int) -> None:
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range [0, {self.n_slots})")
        if slot in self._free:
            raise ValueError(f"double free of slot {slot}")
        self.free_count += 1
        self.lengths[slot] = 0
        self.positions[slot] = 0
        self._free.append(slot)

    # ---------------- device state ----------------

    def request_cache(self):
        """A zeroed batch-1 cache in the pool's layout (prefill target).

        Built once and shared: jax arrays are immutable, and prefill
        returns an updated copy rather than mutating its input."""
        if not hasattr(self, "_request_cache"):
            self._request_cache = api.init_cache(self.cfg, 1, self.max_len,
                                                 self.src_len)
        return self._request_cache

    def insert(self, slot: int, request_cache) -> None:
        """Scatter a prefilled batch-1 cache into ``slot``."""
        self.cache = self._insert(self.cache, request_cache,
                                  jnp.int32(slot))
