"""Batched serving engine: prefill + greedy/temperature decode loop.

Tracks the absolute-position offset introduced by modality prefixes (VLM
patches) and drives the jit-compiled prefill/decode_step entry points.  The
decode loop is a host loop (one jit call per token), matching the
decode_32k/long_500k shape semantics: one new token against a standing
cache/state.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchCfg
from repro.core import dispatch
from repro.models import api, encdec, transformer


@dataclasses.dataclass
class ServeConfig:
    max_len: int
    temperature: float = 0.0   # 0 => greedy
    src_len: int = 0           # enc-dec encoder memory length


class Engine:
    def __init__(self, cfg: ArchCfg, params, scfg: ServeConfig, *,
                 backend: str | None = None,
                 blocks_policy=None, accum_dtype=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.backend = backend
        self.blocks_policy = blocks_policy
        self.accum_dtype = accum_dtype

        # The engine's serving tier (backend, block policy, accumulation
        # dtype) scopes through the execution context; it is captured at
        # trace time, so each jit entry point re-enters the engine's
        # context when it traces.  With blocks_policy="autotune" the first
        # trace pays the measured search (or reads the persisted
        # REPRO_TUNING_CACHE) and every later request reuses the winners.
        def _prefill(p, b, c):
            with dispatch.use(backend=self.backend,
                              blocks_policy=self.blocks_policy,
                              accum_dtype=self.accum_dtype):
                return api.prefill(p, b, cfg, c)

        def _decode(p, t, c, pos):
            with dispatch.use(backend=self.backend,
                              blocks_policy=self.blocks_policy,
                              accum_dtype=self.accum_dtype):
                return api.decode_step(p, t, cfg, c, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def _init_cache(self, batch_size: int):
        if api.is_encdec(self.cfg):
            return encdec.init_cache(self.cfg, batch_size,
                                     self.scfg.max_len, self.scfg.src_len)
        return transformer.init_cache(self.cfg, batch_size,
                                      self.scfg.max_len)

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch, *, n_tokens: int, key=None):
        """batch: prefill inputs. Returns (B, n_tokens) generated ids."""
        key = key if key is not None else jax.random.PRNGKey(0)
        b = batch["tokens"].shape[0]
        prompt_len = batch["tokens"].shape[1]
        pos_off = (self.cfg.n_patches or 0) if not api.is_encdec(
            self.cfg) else 0

        cache = self._init_cache(b)
        logits, cache = self._prefill(self.params, batch, cache)
        out = []
        tok = self._sample(logits, key)
        out.append(tok)
        pos = prompt_len + pos_off
        for i in range(n_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, tok[:, None], cache,
                                         jnp.int32(pos))
            tok = self._sample(logits, sub)
            out.append(tok)
            pos += 1
        return jnp.stack(out, axis=1)
