"""Serving engines: static batch (reference) and continuous batching.

``Engine`` is the original static-batch path: prefill one fixed batch, then
host-loop decode.  It stays as the semantic reference — ``ContinuousEngine``
must match its greedy outputs token-for-token.

``ContinuousEngine`` is the production loop around the tuned kernels: a
slotted KV-cache pool (``serve.kv_cache``), an admission + step scheduler
(``serve.scheduler``), and two jit entry points — per-request prefill and a
single batched decode step over the full slot dimension with per-slot
positions (``api.decode_step_slots``).  Requests join mid-stream as slots
free up, so decode batches stay full and a single long request no longer
stalls the batch.

Both engines scope their serving tier (backend, block policy, accumulation
dtype, interpret mode, mesh) through ``dispatch.use``: the context is
captured at trace time, so each jit entry point re-enters the engine's
context when it traces.  Two engines at different tiers resolve tuned
blocks independently; with ``blocks_policy="autotune"`` the first trace
pays the measured search (or reads the persisted ``REPRO_TUNING_CACHE``)
and every later request reuses the winners.  Under a ``mesh`` (explicit,
or installed by the launcher via ``sharding.annotate.use_rules``) block
resolution is per-shard: tiles are tuned for the local problem each
device runs, not the global batch shape.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ArchCfg
from repro.core import dispatch
from repro.models import api
from repro.sharding import annotate
from repro.serve.kv_cache import PagedKVCache, SlotKVCache
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Request, RequestState, Scheduler


def completed_lengths(ids, stop_tokens) -> np.ndarray:
    """Per-row generated length of a (B, T) id array: index of the first
    stop token + 1 (the stop token is part of the output), else T."""
    arr = np.asarray(ids)
    lens = np.full(arr.shape[0], arr.shape[1], np.int64)
    stops = list(stop_tokens)
    if not stops:
        return lens
    for b in range(arr.shape[0]):
        hits = np.nonzero(np.isin(arr[b], stops))[0]
        if hits.size:
            lens[b] = hits[0] + 1
    return lens


@dataclasses.dataclass
class ServeConfig:
    max_len: int
    temperature: float = 0.0   # 0 => greedy
    src_len: int = 0           # enc-dec encoder memory length


def _tier_context(backend, blocks_policy, accum_dtype, interpret=None,
                  mesh=None, axis_specs=None, quant=None):
    """The ``dispatch.use`` kwargs of one serving tier, resolved at trace
    time: an unset mesh falls back to whatever the launcher installed via
    ``sharding.annotate.use_rules`` *when the jit entry traces*."""
    return dict(backend=backend, blocks_policy=blocks_policy,
                accum_dtype=accum_dtype, interpret=interpret,
                mesh=mesh if mesh is not None else annotate.current_mesh(),
                axis_specs=axis_specs, quant=quant)


class Engine:
    def __init__(self, cfg: ArchCfg, params, scfg: ServeConfig, *,
                 backend: str | None = None,
                 blocks_policy=None, accum_dtype=None,
                 mesh=None, axis_specs=None,
                 quant=None, decode_quant=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.backend = backend
        self.blocks_policy = blocks_policy
        self.accum_dtype = accum_dtype
        self.mesh = mesh
        self.axis_specs = axis_specs
        # Per-phase quant tiers: prefill is compute-bound (quantization
        # rarely pays), decode streams weights (int8 halves the bytes), so
        # decode_quant defaults to quant but can diverge — the canonical
        # production mix is quant=None + decode_quant="int8".
        self.quant = quant
        self.decode_quant = decode_quant if decode_quant is not None else quant

        def _tier(q):
            return _tier_context(self.backend, self.blocks_policy,
                                 self.accum_dtype, mesh=self.mesh,
                                 axis_specs=self.axis_specs, quant=q)

        def _prefill(p, b, c):
            with dispatch.use(**_tier(self.quant)):
                return api.prefill(p, b, cfg, c)

        def _decode(p, t, c, pos):
            with dispatch.use(**_tier(self.decode_quant)):
                return api.decode_step(p, t, cfg, c, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def _init_cache(self, batch_size: int):
        return api.init_cache(self.cfg, batch_size, self.scfg.max_len,
                              self.scfg.src_len)

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch, *, n_tokens: int, key=None, stop_tokens=None):
        """batch: prefill inputs. Returns (B, T) generated ids, T <= n_tokens.

        ``stop_tokens=None`` defaults to ``(cfg.eos_token,)`` when the
        config defines one (pass ``()`` to disable).  With stop tokens, the
        loop ends as soon as every row has emitted one, so T can be shorter
        than ``n_tokens``; rows that finish early keep decoding
        (deterministically) until the slowest row is done — use
        :func:`completed_lengths` to truncate per row.
        """
        key = key if key is not None else jax.random.PRNGKey(0)
        if stop_tokens is None:
            stop_tokens = ((self.cfg.eos_token,)
                           if self.cfg.eos_token is not None else ())
        stops = tuple(stop_tokens)
        b = batch["tokens"].shape[0]
        prompt_len = batch["tokens"].shape[1]
        pos_off = (self.cfg.n_patches or 0) if not api.is_encdec(
            self.cfg) else 0

        cache = self._init_cache(b)
        logits, cache = self._prefill(self.params, batch, cache)
        out = []
        # Split before the first sample: sampling with `key` itself and then
        # splitting the same key would correlate the first two steps.
        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub)
        out.append(tok)
        finished = np.isin(np.asarray(tok), stops) if stops else None
        pos = prompt_len + pos_off
        for _ in range(n_tokens - 1):
            if stops and finished.all():
                break
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, tok[:, None], cache,
                                         jnp.int32(pos))
            tok = self._sample(logits, sub)
            out.append(tok)
            if stops:
                finished |= np.isin(np.asarray(tok), stops)
            pos += 1
        return jnp.stack(out, axis=1)


# ==========================================================================
# continuous batching
# ==========================================================================

@dataclasses.dataclass
class PoolConfig:
    """KV pool sizing + prefill shaping.

    ``n_slots`` bounds concurrent requests (decode cost is O(n_slots) every
    step, so size it to the target batch).  ``max_len`` bounds
    prompt + generated tokens per slot.  ``prefill_bucket`` rounds prompt
    lengths up to a multiple (right-padding) so distinct prompt lengths
    share prefill compilations; only valid for architectures where pad
    tokens cannot perturb real ones (full causal attention, no capacity-
    routed MoE, no recurrence): plain dense decoders and enc-dec.

    Paged pool knobs (see ``serve.kv_cache.PagedKVCache``):

    ``page_size`` switches the engine to the paged KV cache — KV memory is
    then budgeted in *pages*, not slot spans, and slots only hold page
    tables.  ``n_pages`` is the page budget (default: enough for every
    slot at full ``max_len``, i.e. no memory saving — size it below that
    to overcommit; the engine preempts the newest request when the pool
    runs dry).  On architectures where paging can't apply (sliding-window
    ring buffers, recurrent state, VLM prefixes) the engine silently
    falls back to the slotted pool.

    ``prefill_chunk`` caps prefill work per scheduler step: prompts longer
    than the chunk are split into ``prefill_chunk``-token chunks processed
    across steps (one per step), so a long prompt never stalls running
    decodes for more than one chunk's compute; shorter prompts share the
    same per-step token budget.  ``kv_quant="int8"`` stores paged KV as
    int8 with per-page scales (requires ``page_size``).
    """
    n_slots: int
    max_len: int
    src_len: int = 0
    prefill_bucket: int | None = None
    page_size: int | None = None
    n_pages: int | None = None
    prefill_chunk: int | None = None
    kv_quant: str | None = None


def _supports_bucketing(cfg: ArchCfg) -> bool:
    return (cfg.block in ("dense", "encdec") and not cfg.window
            and not cfg.n_patches)


def _sample_tokens(logits, temps, top_k, key):
    """Vectorized per-slot sampling: greedy where temp==0, else categorical
    at that slot's temperature, optionally top-k filtered (top_k==0: off)."""
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    v = logits.shape[-1]
    sorted_desc = jnp.sort(logits, axis=-1)[:, ::-1]
    kth = jnp.clip(top_k, 1, v) - 1
    thresh = jnp.take_along_axis(sorted_desc, kth[:, None], axis=-1)
    masked = jnp.where((top_k[:, None] > 0) & (logits < thresh),
                       -jnp.inf, logits)
    t = jnp.where(temps > 0, temps, 1.0)
    samp = jax.random.categorical(key, masked / t[:, None],
                                  axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, samp, greedy)


def _as_batch1(x, name: str):
    if x is None:
        raise ValueError(f"request requires {name} for this architecture")
    x = jnp.asarray(x)
    return x if x.ndim == 3 else x[None]


class ContinuousEngine:
    """Continuous-batching engine: ``submit() + step()`` or ``serve()``.

    Each step admits waiting requests into free KV-cache slots (prefill +
    first token), runs one batched decode step over the full slot pool with
    per-slot positions, and evicts finished requests the same step.  Greedy
    outputs match the static ``Engine`` token-for-token.
    """

    def __init__(self, cfg: ArchCfg, params, pool: PoolConfig, *,
                 backend: str | None = None, blocks_policy=None,
                 accum_dtype=None, interpret: bool | None = None,
                 mesh=None, axis_specs=None,
                 quant=None, decode_quant=None,
                 priority_fn=None, key=None,
                 trace_sample_rate: int | None = None,
                 clock: Callable[[], float] = time.perf_counter):
        if pool.prefill_bucket is not None and not _supports_bucketing(cfg):
            raise ValueError(
                f"prefill_bucket is not supported for block={cfg.block!r} "
                f"(window={cfg.window}, n_patches={cfg.n_patches}): pad "
                "tokens could perturb real ones")
        if pool.prefill_chunk is not None and not api.supports_paging(cfg):
            raise ValueError(
                f"prefill_chunk is not supported for block={cfg.block!r} "
                f"(window={cfg.window}, n_patches={cfg.n_patches}): chunk "
                "attention needs position-indexed, length-masked KV")
        if pool.prefill_chunk is not None and pool.prefill_bucket is not None:
            raise ValueError("prefill_chunk and prefill_bucket are "
                             "mutually exclusive")
        if (pool.prefill_chunk is not None and pool.page_size
                and pool.prefill_chunk % pool.page_size):
            raise ValueError(
                f"prefill_chunk ({pool.prefill_chunk}) must be a multiple "
                f"of page_size ({pool.page_size}) so chunks stay "
                "page-aligned")
        if pool.kv_quant is not None and not pool.page_size:
            raise ValueError("kv_quant requires page_size (paged pool)")
        self.cfg = cfg
        self.params = params
        self.pool_cfg = pool
        # paged pool where the architecture allows it; slotted fallback
        # (ring buffers / recurrent states have no pageable time axis)
        self.paged = bool(pool.page_size) and api.supports_paging(cfg)
        if self.paged:
            self.pool = PagedKVCache(cfg, pool.n_slots, pool.max_len,
                                     page_size=pool.page_size,
                                     n_pages=pool.n_pages,
                                     src_len=pool.src_len,
                                     kv_quant=pool.kv_quant)
        else:
            self.pool = SlotKVCache(cfg, pool.n_slots, pool.max_len,
                                    src_len=pool.src_len)
        self.scheduler = Scheduler(priority_fn=priority_fn)
        self.metrics = ServeMetrics()
        # every lifecycle stamp (submit/admit/prefill-end/first-token)
        # comes from this one clock, so TTFT breakdown segments telescope
        # exactly; injectable for deterministic tests
        self._clock = clock
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self._pos_off = (cfg.n_patches or 0) if not api.is_encdec(cfg) else 0
        # Host-side per-slot sampling state, fed into the jit entries each
        # step; free slots hold zeros and decode as ignored garbage.
        self._tokens = np.zeros(pool.n_slots, np.int32)
        self._temps = np.zeros(pool.n_slots, np.float32)
        self._topk = np.zeros(pool.n_slots, np.int32)
        # request_id -> on_token callback for streaming consumers
        self._on_token: dict[int, Any] = {}
        # chunked prefill in flight (at most one: head-of-line admission
        # keeps staging memory bounded to a single batch-1 view)
        self._staging: dict | None = None
        # sampled per-request tracing: every Nth submitted request gets
        # the full span tree; counters stay always-on for the rest
        self.trace_sample_rate = trace_sample_rate
        self._trace_count = 0
        self._trace_ids: set[int] = set()

        # decode is weight-streaming-bound, so it gets its own quant tier
        # (int8 decode + full-precision prefill is the production mix)
        decode_quant = decode_quant if decode_quant is not None else quant

        def tier(q):
            # Resolved inside the jit closures, i.e. at *trace* time, so
            # an annotate-installed mesh active when the entry first
            # compiles shapes the tier's block resolution.
            return _tier_context(backend, blocks_policy, accum_dtype,
                                 interpret, mesh, axis_specs, quant=q)

        batch_axes = self.pool.batch_axes

        def _prefill(p, batch, cache, logit_pos):
            with dispatch.use(**tier(quant)):
                return api.prefill(p, batch, cfg, cache,
                                   logit_pos=logit_pos)

        if self.paged:
            time_axes = self.pool.time_axes
            page_size = self.pool.page_size
            view_dtypes = self.pool.view_dtypes

            def _decode(p, tokens, data, scales, page_tables, positions):
                with dispatch.use(**tier(decode_quant)):
                    return api.decode_step_paged(
                        p, tokens, cfg, data, page_tables, positions,
                        batch_axes=batch_axes, time_axes=time_axes,
                        page_size=page_size, scales=scales,
                        view_dtypes=view_dtypes)
        else:
            def _decode(p, tokens, cache, positions):
                with dispatch.use(**tier(decode_quant)):
                    return api.decode_step_slots(p, tokens, cfg, cache,
                                                 positions,
                                                 batch_axes=batch_axes)

        def _make_chunk(first):
            def _chunk(p, batch, cache, pos):
                with dispatch.use(**tier(quant)):
                    return api.prefill_chunk(p, batch, cfg, cache, pos,
                                             first_chunk=first)
            return jax.jit(_chunk)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        if pool.prefill_chunk:
            self._chunk_first = _make_chunk(True)
            self._chunk_rest = _make_chunk(False)
        self._sample = jax.jit(_sample_tokens)
        # greedy fast path: skips the sort/categorical work (and its
        # dispatch cost) when no active slot samples
        self._greedy = jax.jit(
            lambda logits: jnp.argmax(logits, axis=-1).astype(jnp.int32))

    # ---------------- request lifecycle ----------------

    def submit(self, request: Request, *,
               on_token: Callable[[int, int, bool], Any] | None = None,
               trace: str | None = None) -> int:
        """Queue a request; returns its id (see ``scheduler.finished``).

        ``on_token(request_id, token, finished)`` streams the request's
        tokens as they are produced: it fires once per event, inside the
        ``step()`` that generated the token and in generation order, and
        never again after the ``finished=True`` call.  Exceptions from the
        callback propagate out of ``step()``/``serve()``.

        ``trace`` is an opaque trace id stamped onto the request's spans
        and events (the router passes its ticket id, so one client request
        is followable across retries/replicas); defaults to ``req<id>``.
        An explicit id forces the request to be span-sampled; ``""`` opts
        it out; ``None`` defers to the engine's ``trace_sample_rate``
        (every Nth submitted request gets the full span tree, counters
        stay always-on for the rest; ``None`` rate samples everything).
        """
        n_prompt = len(request.prompt)
        if n_prompt < 1:
            raise ValueError("empty prompt")
        need = self._pos_off + n_prompt + request.max_tokens
        if need > self.pool_cfg.max_len:
            raise ValueError(
                f"prompt ({n_prompt}) + max_tokens ({request.max_tokens}) "
                f"exceeds pool max_len ({self.pool_cfg.max_len})")
        stops = request.stop_tokens
        if stops is None:
            stops = ((self.cfg.eos_token,)
                     if self.cfg.eos_token is not None else ())
        self.metrics.requests_submitted += 1
        self._trace_count += 1
        if trace == "":
            sampled, trace = False, None
        elif trace is not None:
            sampled = True
        else:
            rate = self.trace_sample_rate
            sampled = (rate is None or rate <= 1
                       or (self._trace_count - 1) % rate == 0)
        rid = self.scheduler.submit(request, stop_tokens=tuple(stops),
                                    step=self.metrics.steps,
                                    now=self._clock(), trace=trace)
        if trace is None:
            self.scheduler.waiting[-1].trace = f"req{rid}"
        if sampled:
            self._trace_ids.add(rid)
        if on_token is not None:
            self._on_token[rid] = on_token
        obs.event("engine.submit", request_id=rid,
                  trace=self.scheduler.waiting[-1].trace,
                  prompt_len=n_prompt, max_tokens=request.max_tokens)
        return rid

    def _emit(self, request_id: int, token: int, finished: bool):
        """Build one step event, streaming it to the request's callback."""
        cb = self._on_token.get(request_id)
        if cb is not None:
            cb(request_id, token, finished)
            if finished:
                self._on_token.pop(request_id, None)
        return request_id, token, finished

    def _prompt_batch(self, request: Request):
        """(batch dict, logit_pos) for one request's prefill, optionally
        right-padded to the prefill bucket."""
        prompt = np.asarray(request.prompt, np.int32)
        n = len(prompt)
        pad_to = n
        bucket = self.pool_cfg.prefill_bucket
        if bucket:
            pad_to = min(self.pool_cfg.max_len, -(-n // bucket) * bucket)
        tokens = np.zeros((1, pad_to), np.int32)
        tokens[0, :n] = prompt
        batch = {"tokens": jnp.asarray(tokens)}
        if api.is_encdec(self.cfg):
            src = _as_batch1(request.src_embeds, "src_embeds")
            if src.shape[1] != self.pool_cfg.src_len:
                raise ValueError(
                    f"src_embeds length {src.shape[1]} != pool src_len "
                    f"{self.pool_cfg.src_len}")
            batch["src_embeds"] = src
        if self.cfg.n_patches:
            batch["patch_embeds"] = _as_batch1(request.patch_embeds,
                                               "patch_embeds")
        return batch, self._pos_off + n - 1

    def _admit(self, state: RequestState, slot: int):
        """Prefill + first token; returns the (id, token, finished) event."""
        req = state.request
        state.admit_time = self._clock()
        batch, logit_pos = self._prompt_batch(req)
        tr = obs.current_tracer()
        span = (tr.span("prefill", request_id=state.request_id,
                        trace=state.trace, prompt_len=len(req.prompt),
                        slot=slot)
                if tr is not None and state.request_id in self._trace_ids
                else obs.NULL_SPAN)
        with span:
            logits, rcache = self._prefill(self.params, batch,
                                           self.pool.request_cache(),
                                           jnp.int32(logit_pos))
            if self.paged:
                n_valid = self._pos_off + len(req.prompt)
                if not self.pool.insert(slot, rcache, n_valid):
                    # step() pre-checks the page budget, so this only
                    # trips on a logic error — fail loudly, not silently
                    raise RuntimeError(
                        f"page pool exhausted admitting request "
                        f"{state.request_id}")
            else:
                self.pool.insert(slot, rcache)
        return self._first_token(state, slot, logits)

    def _first_token(self, state: RequestState, slot: int, logits):
        """Sample the first token from prefill logits and activate the
        slot.  Shared tail of one-shot admission (``_admit``) and chunked
        prefill completion (``_staging_step``)."""
        req = state.request
        # prefill dispatch is async; the sample below syncs, so the
        # first_decode segment includes waiting out the prefill tail
        state.prefill_end_time = self._clock()
        self.metrics.prefills += 1
        self.scheduler.start(state, slot, self.metrics.steps)

        # first token comes from the prefill logits
        if req.temperature <= 0.0:
            tok = int(np.asarray(self._greedy(logits))[0])
        else:
            self._key, sub = jax.random.split(self._key)
            tok = int(np.asarray(self._sample(
                logits, jnp.full((1,), req.temperature, jnp.float32),
                jnp.full((1,), req.top_k, jnp.int32), sub))[0])
        self.metrics.tokens_generated += 1
        # a preempted request re-admits with its tokens folded into the
        # prompt: its TTFT was already recorded at first admission
        first = state.first_token_time is None
        if first:
            self.metrics.ttft_steps_sum += (self.metrics.steps
                                            - state.submit_step)
            self.metrics.ttft_count += 1
        finished = self.scheduler.record_token(state, tok,
                                               self.metrics.steps,
                                               now=self._clock())
        # first token always lands at admission => wall-clock TTFT is known
        if first and state.ttft_s is not None:
            self.metrics.ttft_s_sum += state.ttft_s
            self.metrics.ttft_hist.observe(state.ttft_s)
        if finished:
            self._evict(state)
            return state.request_id, tok, True
        n_valid = self._pos_off + len(req.prompt)
        self._tokens[slot] = tok
        self._temps[slot] = req.temperature
        self._topk[slot] = req.top_k
        self.pool.positions[slot] = n_valid   # next decode writes here
        self.pool.lengths[slot] = n_valid
        return state.request_id, tok, False

    def _evict(self, state: RequestState) -> None:
        self._release_slot(state.slot)
        self.metrics.requests_completed += 1
        tr = obs.current_tracer()
        if tr is not None and state.request_id in self._trace_ids:
            self._trace_request(tr, state)
        self._trace_ids.discard(state.request_id)

    def _trace_request(self, tracer, state: RequestState) -> None:
        """Emit the request's lifecycle as synthetic spans at eviction.

        A request lives across many ``step()`` calls, so its spans can't be
        open context managers; instead the scheduler's lifecycle stamps are
        replayed as one ``request`` span with ``request.queue`` /
        ``request.prefill`` / ``request.first_decode`` children cut from
        the same stamps as ``ttft_breakdown`` (they telescope exactly).
        """
        end = (state.finish_time if state.finish_time is not None
               else self._clock())
        root = tracer.add_span(
            "request", state.submit_time, end,
            request_id=state.request_id, trace=state.trace,
            status=state.status, finish_reason=state.finish_reason,
            tokens=len(state.generated), ttft_s=state.ttft_s)
        bd = state.ttft_breakdown
        if bd is None:
            return
        tracer.add_span("request.queue", state.submit_time,
                        state.admit_time, parent_id=root.span_id,
                        trace=state.trace)
        tracer.add_span("request.prefill", state.admit_time,
                        state.prefill_end_time, parent_id=root.span_id,
                        trace=state.trace)
        tracer.add_span("request.first_decode", state.prefill_end_time,
                        state.first_token_time, parent_id=root.span_id,
                        trace=state.trace)

    def _release_slot(self, slot: int) -> None:
        self.pool.free(slot)
        self._tokens[slot] = 0
        self._temps[slot] = 0.0
        self._topk[slot] = 0

    # ---------------- chunked prefill / preemption ----------------

    def _start_staging(self, state: RequestState, slot: int) -> None:
        """Begin a chunked prefill: the prompt is longer than the per-step
        prefill budget, so its chunks run one per ``step()`` against a
        private batch-1 cache view; the finished view is inserted into the
        pool in one scatter.  At most one request stages at a time
        (head-of-line admission bounds staging memory to one view)."""
        state.admit_time = self._clock()
        self._staging = {"state": state, "slot": slot,
                         "cache": self.pool.request_cache(),
                         "pos": 0, "first": True,
                         "logits": None, "ready": False}
        obs.event("engine.prefill_chunk_start", request_id=state.request_id,
                  trace=state.trace, prompt_len=len(state.request.prompt),
                  chunk=self.pool_cfg.prefill_chunk)

    def _staging_step(self):
        """Advance the in-flight chunked prefill by one chunk (or retry a
        page-starved pool insert).  Returns ``(prefill tokens consumed,
        event or None)`` — the event fires on the chunk that completes the
        prompt *and* lands in the pool."""
        st = self._staging
        state, slot = st["state"], st["slot"]
        prompt = state.request.prompt
        consumed = 0
        if not st["ready"]:
            pos = st["pos"]
            width = min(self.pool_cfg.prefill_chunk, len(prompt) - pos)
            batch = {"tokens": jnp.asarray(
                np.asarray(prompt[pos:pos + width], np.int32)[None])}
            if api.is_encdec(self.cfg) and st["first"]:
                src = _as_batch1(state.request.src_embeds, "src_embeds")
                if src.shape[1] != self.pool_cfg.src_len:
                    raise ValueError(
                        f"src_embeds length {src.shape[1]} != pool "
                        f"src_len {self.pool_cfg.src_len}")
                batch["src_embeds"] = src
            chunk_fn = self._chunk_first if st["first"] else self._chunk_rest
            tr = obs.current_tracer()
            span = (tr.span("prefill.chunk", request_id=state.request_id,
                            trace=state.trace, pos=pos, width=width,
                            slot=slot)
                    if tr is not None
                    and state.request_id in self._trace_ids
                    else obs.NULL_SPAN)
            with span:
                logits, st["cache"] = chunk_fn(self.params, batch,
                                               st["cache"], jnp.int32(pos))
            st["first"] = False
            st["pos"] = pos + width
            self.metrics.prefill_chunks += 1
            consumed = width
            if st["pos"] < len(prompt):
                return consumed, None
            st["ready"] = True
            st["logits"] = logits
        # prompt fully prefilled: move the view into the pool (page-
        # starved inserts return False and are retried next step)
        n_valid = self._pos_off + len(prompt)
        if self.paged:
            if not self.pool.insert(slot, st["cache"], n_valid):
                return consumed, None
        else:
            self.pool.insert(slot, st["cache"])
        logits = st["logits"]
        self._staging = None
        return consumed, self._first_token(state, slot, logits)

    def _preempt(self, state: RequestState) -> None:
        """Evict a running request to reclaim its pages: its generated
        tokens fold into the prompt and it requeues first-in-line, so a
        greedy re-admission prefill recomputes the same KV and continues
        with the correct next token — nothing is emitted twice."""
        slot = state.slot
        obs.event("engine.preempt", request_id=state.request_id,
                  trace=state.trace, generated=len(state.generated))
        self.scheduler.preempt(state)
        self._release_slot(slot)
        self.metrics.preemptions += 1

    def _ensure_pages(self) -> None:
        """Paged pools only: guarantee every running slot owns the page
        its next decode write lands in, preempting the newest admissions
        while the free list is dry (newest-first keeps FCFS fairness and
        minimizes recompute)."""
        for slot in sorted(self.scheduler.running):
            state = self.scheduler.running.get(slot)
            if state is None:
                continue   # preempted earlier in this pass
            while not self.pool.ensure(slot, int(self.pool.positions[slot])):
                victim = max(self.scheduler.running.values(),
                             key=lambda s: (s.admit_step, s.request_id))
                self._preempt(victim)
                if victim is state:
                    break

    def gauges(self) -> dict[str, float]:
        """Point-in-time pool gauges (slot occupancy; page stats when
        paged) for metrics exporters."""
        g = {"kv_occupancy": self.pool.occupancy}
        if self.paged:
            g["kv_page_occupancy"] = self.pool.page_occupancy
            g["kv_page_fragmentation"] = self.pool.fragmentation
            g["kv_free_pages"] = float(self.pool.n_free_pages)
        return g

    def has_work(self) -> bool:
        """Whether any request is waiting, staging, or running."""
        return self._staging is not None or self.scheduler.has_work()

    def cancel(self, request_id: int) -> bool:
        """Cancel a waiting or running request mid-flight.

        A running request's KV slot is freed the same step (available to
        the next admission sweep), so a stuck or departed client no longer
        holds its slot until ``max_tokens``.  Its streaming callback is
        dropped without a ``finished=True`` call — cancellation is not a
        generated token.  Returns False when the id is unknown or already
        finished.
        """
        if (self._staging is not None
                and self._staging["state"].request_id == request_id):
            st, self._staging = self._staging, None
            self.scheduler._finish(st["state"], "cancelled",
                                   self.metrics.steps)
            self._release_slot(st["slot"])
            self._on_token.pop(request_id, None)
            self._trace_ids.discard(request_id)
            self.metrics.requests_cancelled += 1
            return True
        state = self.scheduler.cancel(request_id, step=self.metrics.steps)
        if state is None:
            return False
        if state.slot is not None:
            self._release_slot(state.slot)
        self._on_token.pop(request_id, None)
        self._trace_ids.discard(request_id)
        self.metrics.requests_cancelled += 1
        return True

    # ---------------- the serving loop ----------------

    def step(self):
        """One scheduler step: admit, batched decode, evict finished.

        Returns a list of ``(request_id, token, finished)`` events.
        """
        t0 = self._clock()
        self.metrics.steps += 1
        step = self.metrics.steps
        depth = self.scheduler.queue_depth
        self.metrics.queue_depth_sum += depth
        self.metrics.max_queue_depth = max(self.metrics.max_queue_depth,
                                           depth)

        events = []
        # per-step prefill token budget (prefill_chunk): the in-flight
        # chunked prefill advances first, then one-shot admissions share
        # whatever is left — decodes never stall more than one chunk
        budget = self.pool_cfg.prefill_chunk
        spent = 0
        if self._staging is not None:
            consumed, event = self._staging_step()
            spent += consumed
            if event is not None:
                events.append(self._emit(*event))
        while self.pool.n_free and self.scheduler.waiting:
            if budget is not None and spent >= budget:
                break
            state = self.scheduler.next_waiting()
            n_prompt = len(state.request.prompt)
            if budget is not None and n_prompt > budget:
                # prompt longer than a whole step's budget: chunk it.
                # Staging starts only on a step with no prefill work yet,
                # so every chunk gets the full (page-aligned) budget.
                if self._staging is not None or spent:
                    self.scheduler.requeue(state)
                    break
                slot = self.pool.alloc()
                self._start_staging(state, slot)
                consumed, event = self._staging_step()
                spent += consumed
                if event is not None:
                    events.append(self._emit(*event))
                break
            if budget is not None and spent + n_prompt > budget:
                self.scheduler.requeue(state)
                break
            if (self.paged and -(-(self._pos_off + n_prompt)
                                 // self.pool.page_size)
                    > self.pool.n_free_pages):
                # not enough pages for the prompt: hold admission (decode
                # progress frees pages as running requests finish)
                self.scheduler.requeue(state)
                break
            slot = self.pool.alloc()
            try:
                event = self._admit(state, slot)
            except Exception:
                # retry-safe admission: a failed prefill frees the slot
                # and puts the request back first-in-line, so a router
                # retrying this step neither loses nor duplicates it
                self.scheduler.running.pop(slot, None)
                self._release_slot(slot)
                self.scheduler.requeue(state)
                raise
            events.append(self._emit(*event))
            spent += n_prompt

        if self.paged:
            self._ensure_pages()
        active = sorted(self.scheduler.running.items())
        if active:
            tr = obs.current_tracer()
            dspan = (tr.span("decode", step=step, n_active=len(active))
                     if tr is not None else obs.NULL_SPAN)
            td0 = self._clock()
            with dspan:
                if self.paged:
                    logits, self.pool.data, self.pool.scales = self._decode(
                        self.params, jnp.asarray(self._tokens)[:, None],
                        self.pool.data, self.pool.scales,
                        jnp.asarray(self.pool.page_tables),
                        jnp.asarray(self.pool.positions))
                else:
                    logits, self.pool.cache = self._decode(
                        self.params, jnp.asarray(self._tokens)[:, None],
                        self.pool.cache, jnp.asarray(self.pool.positions))
                if not np.any(self._temps > 0):
                    toks = np.asarray(self._greedy(logits))
                else:
                    self._key, sub = jax.random.split(self._key)
                    toks = np.asarray(self._sample(
                        logits, jnp.asarray(self._temps),
                        jnp.asarray(self._topk), sub))
            # np.asarray above syncs, so td1 - td0 is the real decode
            # latency every active slot's token paid this step
            td1 = self._clock()
            self.metrics.token_latency_hist.observe(td1 - td0,
                                                    n=len(active))
            self.metrics.decode_steps += 1
            self.metrics.slot_steps += len(active)
            self.metrics.slot_capacity_steps += self.pool.n_slots
            for slot, state in active:
                self.pool.positions[slot] += 1
                self.pool.lengths[slot] += 1
                tok = int(toks[slot])
                self.metrics.tokens_generated += 1
                finished = self.scheduler.record_token(state, tok, step,
                                                       now=td1)
                events.append(self._emit(state.request_id, tok, finished))
                if finished:
                    self._evict(state)
                else:
                    self._tokens[slot] = tok
        self.metrics.wall_time_s += self._clock() - t0
        return events

    def serve(self, requests, *, key=None) -> dict[int, list[int]]:
        """Run ``requests`` to completion; returns {request_id: token ids}.

        Requests beyond the slot capacity queue and join mid-stream as
        earlier ones finish.  More can be ``submit()``-ed between ``step()``
        calls when driving the loop manually.
        """
        if key is not None:
            self._key = key
        ids = [self.submit(r) for r in requests]
        while self.has_work():
            self.step()
        return {rid: list(self.scheduler.finished[rid].generated)
                for rid in ids}
