"""Multi-replica serving: request router, admission control, deadlines,
and replica fault handling.

``EngineRouter`` spreads traffic across N ``ContinuousEngine`` replicas,
each its own serving tier (own ``PoolConfig``, backend, block policy,
accumulation dtype — e.g. a bf16 high-throughput tier next to an fp32
quality tier, each capturing its own warm tuning-cache context when its
jit entries trace).  The router stays pure host-side orchestration: it
never touches device state, it only drives each replica's
``submit()/step()/cancel()``.

Routing.  ``policy(replicas, request) -> replica`` picks among the
healthy candidates; the default is least queue depth (queued + running,
stable over replica order for ties).  A request may name a ``tier``:
replicas with that tier label are preferred, and the policy falls back to
all healthy replicas when none match (tier affinity is a preference, not
a partition).

Admission control.  ``max_waiting`` bounds the cluster-wide *backlog* —
requests queued beyond the slots currently free.  At the bound, the
router either rejects the newcomer (``admission="reject"``, terminal
status ``"rejected"``) or sheds the lowest-priority waiting request to
make room (``admission="shed"``; the newcomer itself is shed when nothing
waiting has lower priority).  Either way the queue never grows without
bound.

Deadlines.  ``submit(deadline_s=...)`` arms a per-request wall-clock
deadline (router clock, injectable for tests).  ``step()`` sweeps expired
requests first: a timed-out request is cancelled *mid-flight* — its KV
slot frees the same step (``ContinuousEngine.cancel``) — and resolves
with status ``"timeout"``.

Fault handling.  A replica step failure is first *classified*
(``serve.health.classify_failure``): transient failures are retried in
place — bounded attempts with exponential backoff + jitter
(``retry=RetryPolicy(...)``) — before the replica is condemned; a fatal
failure (or exhausted retries) quarantines the replica
(``healthy=False``, not stepped again) and every request it held —
waiting or mid-generation — is requeued onto the survivors.  Tokens the
request already streamed are not re-emitted: the requeued run skips that
prefix (greedy decoding regenerates it identically; sampled requests may
legitimately diverge from the dropped prefix).

Self-healing.  With ``health=HealthConfig(...)`` quarantine is no longer
forever: a per-step watchdog (``watchdog_s``, heartbeat check-ins on the
router clock) turns hangs into quarantines instead of a stuck cluster;
quarantined replicas with a ``factory`` get periodic health probes — a
canary generate through a warm-restarted engine — and are re-admitted
with that fresh engine after ``probes_to_readmit`` consecutive passes
(traffic drains back via the ordinary least-depth policy); ``max_probes``
consecutive failures retire a replica permanently.  When a tier loses
all matching replicas, tier-affinity requests *degrade* to any healthy
replica (counted in ``requests_degraded``, flagged on the ticket) rather
than silently; when the whole cluster is down, requests park awaiting a
re-admission if one is still possible, else resolve with status
``"failed"``.  Without ``health``, the last replica's death keeps the
legacy contract: stranded requests resolve ``"failed"`` and the fault
propagates.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

from repro import obs
from repro.serve.engine import ContinuousEngine
from repro.serve.health import (
    ClusterHealth,
    HealthConfig,
    ReplicaHungError,
    ReplicaStragglerError,
    RetryPolicy,
    TRANSIENT,
    classify_failure,
)
from repro.serve.metrics import ClusterMetrics
from repro.serve.scheduler import Request

# terminal statuses a routed request can resolve with
COMPLETED = "completed"
CANCELLED = "cancelled"
TIMEOUT = "timeout"
REJECTED = "rejected"
SHED = "shed"
FAILED = "failed"


@dataclasses.dataclass
class EngineReplica:
    """One engine behind the router: a name, a tier label, health state.

    ``factory`` is the warm-restart hook — a zero-arg callable building a
    fresh engine of this replica's tier.  With router ``health`` enabled
    it is what makes re-admission possible: probes canary a fresh
    ``factory()`` engine, and on re-admission it replaces ``engine``.
    Replicas without a factory stay quarantined for good (and are retired
    immediately so drivers don't probe them forever).  ``restarts``
    counts successful re-admissions; ``retired`` marks a replica that
    exhausted its probe budget and will never rejoin.
    """
    name: str
    engine: ContinuousEngine
    tier: Optional[str] = None
    healthy: bool = True
    fault: Optional[BaseException] = None
    factory: Optional[Callable[[], ContinuousEngine]] = None
    restarts: int = 0
    retired: bool = False

    @property
    def load(self) -> int:
        """Queued + running requests (the routing signal)."""
        s = self.engine.scheduler
        return s.queue_depth + s.n_running

    @property
    def backlog(self) -> int:
        """Waiting requests beyond the slots currently free."""
        return max(0, self.engine.scheduler.queue_depth
                   - self.engine.pool.n_free)


def least_depth(replicas: Sequence[EngineReplica],
                request: Request) -> EngineReplica:
    """Default routing policy: the replica with the fewest queued+running
    requests; replica order breaks ties (min() is stable)."""
    return min(replicas, key=lambda r: r.load)


@dataclasses.dataclass
class ClusterRequest:
    """Router-side lifecycle of one request (its "ticket")."""
    ticket_id: int
    request: Request
    tier: Optional[str]
    deadline: Optional[float]            # absolute, router clock
    on_token: Optional[Callable]
    on_finish: Optional[Callable]
    replica: Optional[EngineReplica] = None
    local_id: Optional[int] = None       # request id inside the replica
    tokens: list = dataclasses.field(default_factory=list)
    status: Optional[str] = None         # terminal status, None while live
    finish_reason: Optional[str] = None  # "stop"/"length" or the status
    attempts: int = 0
    degraded: bool = False               # served off-tier (tier had no
                                         # healthy replica at dispatch)
    submit_time: float = 0.0
    first_token_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.status is not None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time


class EngineRouter:
    """Route requests across engine replicas; see the module docstring.

    Drive it like one engine: ``submit()`` then ``step()`` until
    ``has_work()`` is False, or ``serve()`` for a whole batch.  ``step()``
    returns merged ``(ticket_id, token, finished)`` events across every
    replica stepped.
    """

    def __init__(self, replicas: Sequence[EngineReplica], *,
                 policy: Callable[..., EngineReplica] | None = None,
                 max_waiting: int | None = None,
                 admission: str = "reject",
                 priority_fn: Callable[[Request], float] | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 retry: RetryPolicy | None = None,
                 health: HealthConfig | None = None,
                 trace_sample_rate: int | None = None,
                 sleep: Callable[[float], None] = time.sleep):
        replicas = list(replicas)
        if not replicas:
            raise ValueError("EngineRouter needs at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names: {names}")
        if admission not in ("reject", "shed"):
            raise ValueError(f"admission must be 'reject' or 'shed', "
                             f"got {admission!r}")
        self.replicas = replicas
        self.policy = policy or least_depth
        self.max_waiting = max_waiting
        self.admission = admission
        self.priority_fn = priority_fn or (lambda r: r.priority)
        self.clock = clock
        self.retry = retry
        self.health_cfg = health
        self.health = ClusterHealth(names, health) if health else None
        # sampled tracing: every Nth ticket gets the full span tree on its
        # replica (None => all); counters/events stay always-on.  Keyed on
        # the ticket id, so a requeued ticket keeps its sampling decision.
        self.trace_sample_rate = trace_sample_rate
        self.sleep = sleep
        self._by_name = {r.name: r for r in replicas}
        self.tickets: dict[int, ClusterRequest] = {}
        self._next_ticket = 0
        self._events: list = []
        self._pending: list[ClusterRequest] = []   # parked: cluster down,
                                                   # a re-admission pending
        self.counters = {"requests_rejected": 0, "requests_shed": 0,
                         "requests_timeout": 0, "requests_requeued": 0,
                         "requests_degraded": 0, "retries": 0,
                         "replicas_quarantined": 0,
                         "replicas_readmitted": 0,
                         "probes": 0, "probe_failures": 0}

    # ---------------- routing ----------------

    def healthy_replicas(self, tier: str | None = None
                         ) -> list[EngineReplica]:
        live = [r for r in self.replicas if r.healthy]
        if tier is not None:
            tiered = [r for r in live if r.tier == tier]
            if tiered:
                return tiered
        return live

    @property
    def total_backlog(self) -> int:
        return sum(r.backlog for r in self.replicas if r.healthy)

    # ---------------- submission / admission ----------------

    def submit(self, request: Request, *, tier: str | None = None,
               deadline_s: float | None = None,
               on_token: Callable | None = None,
               on_finish: Callable | None = None) -> int:
        """Route a request; returns its cluster-wide ticket id.

        ``on_token(ticket_id, token, finished)`` streams tokens exactly as
        ``ContinuousEngine.submit(on_token=)`` does, but survives a
        replica requeue (the re-run's duplicate prefix is suppressed).
        ``on_finish(ticket)`` fires once, on any terminal status —
        including a synchronous rejection inside this call.  Check
        ``router.tickets[tid].status`` after submitting: a rejected
        request is already terminal.
        """
        now = self.clock()
        ticket = ClusterRequest(
            ticket_id=self._next_ticket, request=request, tier=tier,
            deadline=None if deadline_s is None else now + deadline_s,
            on_token=on_token, on_finish=on_finish, submit_time=now)
        self._next_ticket += 1
        self.tickets[ticket.ticket_id] = ticket
        obs.event("router.submit", trace=f"t{ticket.ticket_id}",
                  tier=tier, deadline_s=deadline_s)
        if (self.max_waiting is not None
                and self.total_backlog >= self.max_waiting
                and not self._make_room(ticket)):
            return ticket.ticket_id
        self._dispatch(ticket)
        return ticket.ticket_id

    def _make_room(self, ticket: ClusterRequest) -> bool:
        """Admission control at a full backlog: reject the newcomer, or
        shed the lowest-priority waiting request to admit it."""
        if self.admission == "reject":
            self.counters["requests_rejected"] += 1
            self._finalize(ticket, REJECTED)
            return False
        waiting = [t for t in self.tickets.values()
                   if not t.done and t.replica is not None
                   and self._is_waiting(t)]
        # lowest priority loses; among equals, the newest submission
        # (shedding old FCFS work for an equal newcomer would churn)
        victim = min(waiting,
                     key=lambda t: (self.priority_fn(t.request),
                                    -t.ticket_id),
                     default=None)
        self.counters["requests_shed"] += 1
        if (victim is None
                or self.priority_fn(victim.request)
                >= self.priority_fn(ticket.request)):
            self._finalize(ticket, SHED)
            return False
        self._cancel_ticket(victim, SHED)
        return True

    def _is_waiting(self, ticket: ClusterRequest) -> bool:
        return any(s.request_id == ticket.local_id
                   for s in ticket.replica.engine.scheduler.waiting)

    def _may_recover(self) -> bool:
        """True while a quarantined replica could still be re-admitted."""
        return (self.health is not None
                and any(not r.healthy and not r.retired
                        and r.factory is not None for r in self.replicas))

    def _dispatch(self, ticket: ClusterRequest) -> None:
        live = [r for r in self.replicas if r.healthy]
        if not live:
            if self._may_recover():
                # cluster momentarily down: park until a probe re-admits
                # a replica (deadline sweeps still cover parked tickets)
                obs.event("router.park", trace=f"t{ticket.ticket_id}")
                self._pending.append(ticket)
                return
            self._finalize(ticket, FAILED)
            return
        if ticket.tier is not None:
            tiered = [r for r in live if r.tier == ticket.tier]
            if not tiered and not ticket.degraded:
                # tier affinity is a preference: record the degradation
                # instead of failing (or silently crossing tiers)
                ticket.degraded = True
                self.counters["requests_degraded"] += 1
                obs.event("router.degrade", trace=f"t{ticket.ticket_id}",
                          tier=ticket.tier)
            live = tiered or live
        replica = self.policy(live, ticket.request)
        ticket.attempts += 1
        ticket.replica = replica
        # the ticket id is the cluster-wide trace id: the same request
        # keeps it across requeues, so one trace follows it between
        # replicas (each dispatch is a fresh local request id).  An
        # unsampled ticket passes trace="" — the engine skips its spans
        # but keeps every counter.
        ticket.local_id = replica.engine.submit(
            ticket.request, on_token=self._bridge(ticket),
            trace=self._trace_arg(ticket))
        obs.event("router.dispatch", trace=f"t{ticket.ticket_id}",
                  replica=replica.name, attempt=ticket.attempts)

    def _trace_arg(self, ticket: ClusterRequest) -> str:
        rate = self.trace_sample_rate
        if rate is None or rate <= 1 or ticket.ticket_id % rate == 0:
            return f"t{ticket.ticket_id}"
        return ""

    def _bridge(self, ticket: ClusterRequest) -> Callable:
        """Per-dispatch engine callback: forwards the replica's token
        stream onto the ticket, skipping the prefix a previous dispatch
        already emitted (requeue after a replica fault)."""
        skip = len(ticket.tokens)
        seen = 0

        def cb(local_id: int, token: int, finished: bool) -> None:
            nonlocal seen
            seen += 1
            if seen > skip:
                if ticket.first_token_time is None:
                    ticket.first_token_time = self.clock()
                ticket.tokens.append(int(token))
                self._events.append((ticket.ticket_id, int(token),
                                     finished))
                if ticket.on_token is not None:
                    ticket.on_token(ticket.ticket_id, int(token), finished)
            if finished:
                state = ticket.replica.engine.scheduler.finished.get(
                    ticket.local_id)
                if state is not None:
                    ticket.finish_reason = state.finish_reason
                self._finalize(ticket, COMPLETED)
        return cb

    # ---------------- cancellation / resolution ----------------

    def cancel(self, ticket_id: int, *, status: str = CANCELLED) -> bool:
        """Cancel a live request (frees its KV slot the same step).
        Returns False when the id is unknown or already terminal."""
        ticket = self.tickets.get(ticket_id)
        if ticket is None or ticket.done:
            return False
        self._cancel_ticket(ticket, status)
        return True

    def _cancel_ticket(self, ticket: ClusterRequest, status: str) -> None:
        if ticket.replica is not None and ticket.local_id is not None:
            ticket.replica.engine.cancel(ticket.local_id)
        self._finalize(ticket, status)

    def _finalize(self, ticket: ClusterRequest, status: str) -> None:
        if ticket.done:
            return
        ticket.status = status
        if ticket.finish_reason is None:
            ticket.finish_reason = status
        obs.event("request.finish", trace=f"t{ticket.ticket_id}",
                  status=status, reason=ticket.finish_reason,
                  tokens=len(ticket.tokens), attempts=ticket.attempts,
                  ttft_s=ticket.ttft_s)
        if ticket.on_finish is not None:
            ticket.on_finish(ticket)

    # ---------------- the serving loop ----------------

    def step(self) -> list:
        """One cluster step: expire deadlines, run due health probes
        (re-admitting or retiring quarantined replicas), dispatch parked
        requests onto whatever is healthy, step every healthy replica
        with work (transient failures retried in place with backoff;
        fatal failures, watchdog hangs, and flagged stragglers
        quarantined, their in-flight requests requeued), and return the
        merged ``(ticket_id, token, finished)`` events."""
        self._events = []
        now = self.clock()
        for ticket in list(self.tickets.values()):
            if (not ticket.done and ticket.deadline is not None
                    and now >= ticket.deadline):
                self.counters["requests_timeout"] += 1
                obs.event("router.timeout", trace=f"t{ticket.ticket_id}")
                self._cancel_ticket(ticket, TIMEOUT)
        if self.health is not None:
            self._probe_sweep(now)
        if self._pending and any(r.healthy for r in self.replicas):
            pending, self._pending = self._pending, []
            for ticket in pending:
                if not ticket.done:
                    self._dispatch(ticket)
        durations: dict[str, float] = {}
        for replica in self.replicas:
            if not replica.healthy:
                continue
            if not replica.engine.has_work():
                if self.health is not None:   # idle check-in: not hung
                    self.health.beat(replica.name, self.clock())
                continue
            self._step_replica(replica, durations)
        if self.health is not None:
            for name in self.health.observe_durations(durations):
                replica = self._by_name[name]
                if replica.healthy:
                    self._quarantine(replica, ReplicaStragglerError(
                        f"replica {name!r} flagged as a straggler "
                        f"({self.health_cfg.straggler_factor}x median for "
                        f"{self.health_cfg.straggler_patience} steps)"))
        if (self.health is not None and self.health.probes
                and not any(r.healthy for r in self.replicas)):
            # hard-down but recoverable: advance to the next probe time
            # instead of busy-spinning serve() (with an injected
            # sleep=clock.advance this is what makes the loop progress)
            wait = (min(st.next_at for st in self.health.probes.values())
                    - self.clock())
            if wait > 0:
                self.sleep(wait)
        return self._events

    def _step_replica(self, replica: EngineReplica,
                      durations: dict[str, float]) -> None:
        """Step one replica: transient failures get bounded in-place
        retries with backoff before quarantine; each attempt checks in
        with the heartbeat monitor first, and the watchdog verdict is
        taken right after the attempt returns (beat at start, dead-host
        check at end = this step's duration against ``watchdog_s``) —
        per-replica, so one replica's stall cannot stale-out the beats
        of replicas stepped earlier in the same sweep."""
        attempts = 0
        while True:
            t0 = self.clock()
            if self.health is not None:
                self.health.beat(replica.name, t0,
                                 step=replica.engine.metrics.steps)
            try:
                replica.engine.step()
            except Exception as exc:
                if (classify_failure(exc) == TRANSIENT
                        and self.retry is not None
                        and attempts < self.retry.max_retries):
                    attempts += 1
                    self.counters["retries"] += 1
                    obs.event("router.retry", replica=replica.name,
                              attempt=attempts, error=type(exc).__name__)
                    self.sleep(self.retry.backoff(attempts))
                    continue
                self._quarantine(replica, exc)
                return
            now = self.clock()
            if (self.health is not None
                    and replica.name in self.health.hung(now)):
                self._quarantine(replica, ReplicaHungError(
                    f"replica {replica.name!r} step took {now - t0:.3f}s, "
                    f"over the {self.health_cfg.watchdog_s}s watchdog "
                    f"deadline"))
                return
            if self.health is not None:
                self.health.beat(replica.name, now,
                                 step=replica.engine.metrics.steps)
            durations[replica.name] = now - t0
            return

    def _quarantine(self, replica: EngineReplica,
                    exc: BaseException) -> None:
        replica.healthy = False
        replica.fault = exc
        self.counters["replicas_quarantined"] += 1
        obs.event("replica.quarantine", replica=replica.name,
                  error=type(exc).__name__)
        if (self.health is not None and replica.factory is not None
                and not replica.retired):
            self.health.on_quarantine(replica.name, self.clock())
        elif self.health is not None:
            replica.retired = True    # nothing to restart: never probed
        stranded = [t for t in self.tickets.values()
                    if not t.done and t.replica is replica]
        survivors = any(r.healthy for r in self.replicas)
        if not survivors and not self._may_recover():
            for ticket in stranded:
                self._finalize(ticket, FAILED)
            raise RuntimeError(
                f"replica {replica.name!r} failed with no survivors"
            ) from exc
        for ticket in stranded:
            self.counters["requests_requeued"] += 1
            obs.event("router.requeue", trace=f"t{ticket.ticket_id}",
                      replica=replica.name)
            if survivors:
                self._dispatch(ticket)
            else:
                ticket.replica = None
                ticket.local_id = None
                self._pending.append(ticket)

    # ---------------- health probes / re-admission ----------------

    def _probe_sweep(self, now: float) -> None:
        """Run due health probes: canary a warm-restarted engine; N
        consecutive passes re-admit the replica with it, ``max_probes``
        consecutive failures retire the replica.  When retirement kills
        the last possible recovery, parked requests resolve ``failed``."""
        for name in self.health.due_probes(now):
            replica = self._by_name[name]
            if replica.healthy or replica.retired:
                self.health.probes.pop(name, None)
                continue
            state = self.health.probes[name]
            self.counters["probes"] += 1
            if state.candidate is None:
                try:
                    state.candidate = replica.factory()
                except Exception:
                    state.candidate = None
            ok = (state.candidate is not None
                  and self._run_canary(state.candidate))
            candidate = state.candidate
            obs.event("router.probe", replica=name, ok=ok)
            if not ok:
                self.counters["probe_failures"] += 1
            verdict = self.health.record_probe(name, ok, self.clock())
            if verdict == "readmit":
                self._readmit(replica, candidate)
            elif verdict == "retired":
                replica.retired = True
        if (self._pending and not self._may_recover()
                and not any(r.healthy for r in self.replicas)):
            pending, self._pending = self._pending, []
            for ticket in pending:
                self._finalize(ticket, FAILED)

    def _run_canary(self, engine: ContinuousEngine) -> bool:
        """One greedy canary generate on the candidate engine (a single
        request, occupying one slot of its pool)."""
        cfg = self.health_cfg
        try:
            out = engine.serve([Request(prompt=list(cfg.canary_prompt),
                                        max_tokens=cfg.canary_tokens,
                                        stop_tokens=())])
        except Exception:
            return False
        return all(len(toks) >= 1 for toks in out.values())

    def _readmit(self, replica: EngineReplica,
                 engine: ContinuousEngine) -> None:
        replica.engine = engine        # the warm restart becomes live
        replica.healthy = True
        replica.fault = None
        replica.restarts += 1
        self.counters["replicas_readmitted"] += 1
        obs.event("replica.readmit", replica=replica.name,
                  restarts=replica.restarts)
        self.health.on_readmit(replica.name, self.clock())

    def has_work(self) -> bool:
        return (any(r.healthy and r.engine.has_work()
                    for r in self.replicas)
                or any(not t.done for t in self._pending))

    def serve(self, requests: Sequence[Request], *,
              tiers: Sequence[str | None] | None = None,
              deadline_s: float | None = None) -> dict[int, list[int]]:
        """Route ``requests`` and run the cluster to completion; returns
        ``{ticket_id: tokens}`` (empty list for rejected/shed/expired
        requests — check ``tickets[tid].status``)."""
        tiers = tiers if tiers is not None else [None] * len(requests)
        ids = [self.submit(r, tier=t, deadline_s=deadline_s)
               for r, t in zip(requests, tiers)]
        while self.has_work():
            self.step()
        return {tid: list(self.tickets[tid].tokens) for tid in ids}

    # ---------------- metrics ----------------

    def metrics(self) -> ClusterMetrics:
        """Live cluster metrics: per-replica ``ServeMetrics`` (aggregate
        with ``ClusterMetrics.merge``), instantaneous gauges, and the
        router's admission/fault counters."""
        return ClusterMetrics(
            replicas={r.name: r.engine.metrics for r in self.replicas},
            gauges={r.name: {
                "queue_depth": float(r.engine.scheduler.queue_depth),
                "running": float(r.engine.scheduler.n_running),
                "slots_free": float(r.engine.pool.n_free),
                "healthy": 1.0 if r.healthy else 0.0,
                "probing": 1.0 if (self.health is not None
                                   and self.health.is_probing(r.name))
                else 0.0,
                # pool gauges: slot occupancy always; page occupancy /
                # fragmentation / free pages when the replica is paged
                **r.engine.gauges(),
            } for r in self.replicas},
            counters=dict(self.counters))
