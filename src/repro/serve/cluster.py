"""Multi-replica serving: request router, admission control, deadlines,
and replica fault handling.

``EngineRouter`` spreads traffic across N ``ContinuousEngine`` replicas,
each its own serving tier (own ``PoolConfig``, backend, block policy,
accumulation dtype — e.g. a bf16 high-throughput tier next to an fp32
quality tier, each capturing its own warm tuning-cache context when its
jit entries trace).  The router stays pure host-side orchestration: it
never touches device state, it only drives each replica's
``submit()/step()/cancel()``.

Routing.  ``policy(replicas, request) -> replica`` picks among the
healthy candidates; the default is least queue depth (queued + running,
stable over replica order for ties).  A request may name a ``tier``:
replicas with that tier label are preferred, and the policy falls back to
all healthy replicas when none match (tier affinity is a preference, not
a partition).

Admission control.  ``max_waiting`` bounds the cluster-wide *backlog* —
requests queued beyond the slots currently free.  At the bound, the
router either rejects the newcomer (``admission="reject"``, terminal
status ``"rejected"``) or sheds the lowest-priority waiting request to
make room (``admission="shed"``; the newcomer itself is shed when nothing
waiting has lower priority).  Either way the queue never grows without
bound.

Deadlines.  ``submit(deadline_s=...)`` arms a per-request wall-clock
deadline (router clock, injectable for tests).  ``step()`` sweeps expired
requests first: a timed-out request is cancelled *mid-flight* — its KV
slot frees the same step (``ContinuousEngine.cancel``) — and resolves
with status ``"timeout"``.

Fault handling.  A replica whose ``step()`` raises is quarantined
(``healthy=False``, never stepped again) and every request it held —
waiting or mid-generation — is requeued onto the survivors.  Tokens the
request already streamed are not re-emitted: the requeued run skips that
prefix (greedy decoding regenerates it identically; sampled requests may
legitimately diverge from the dropped prefix).  When the last replica
fails, stranded requests resolve with status ``"failed"`` and the fault
propagates.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

from repro.serve.engine import ContinuousEngine
from repro.serve.metrics import ClusterMetrics
from repro.serve.scheduler import Request

# terminal statuses a routed request can resolve with
COMPLETED = "completed"
CANCELLED = "cancelled"
TIMEOUT = "timeout"
REJECTED = "rejected"
SHED = "shed"
FAILED = "failed"


@dataclasses.dataclass
class EngineReplica:
    """One engine behind the router: a name, a tier label, health state."""
    name: str
    engine: ContinuousEngine
    tier: Optional[str] = None
    healthy: bool = True
    fault: Optional[BaseException] = None

    @property
    def load(self) -> int:
        """Queued + running requests (the routing signal)."""
        s = self.engine.scheduler
        return s.queue_depth + s.n_running

    @property
    def backlog(self) -> int:
        """Waiting requests beyond the slots currently free."""
        return max(0, self.engine.scheduler.queue_depth
                   - self.engine.pool.n_free)


def least_depth(replicas: Sequence[EngineReplica],
                request: Request) -> EngineReplica:
    """Default routing policy: the replica with the fewest queued+running
    requests; replica order breaks ties (min() is stable)."""
    return min(replicas, key=lambda r: r.load)


@dataclasses.dataclass
class ClusterRequest:
    """Router-side lifecycle of one request (its "ticket")."""
    ticket_id: int
    request: Request
    tier: Optional[str]
    deadline: Optional[float]            # absolute, router clock
    on_token: Optional[Callable]
    on_finish: Optional[Callable]
    replica: Optional[EngineReplica] = None
    local_id: Optional[int] = None       # request id inside the replica
    tokens: list = dataclasses.field(default_factory=list)
    status: Optional[str] = None         # terminal status, None while live
    finish_reason: Optional[str] = None  # "stop"/"length" or the status
    attempts: int = 0
    submit_time: float = 0.0
    first_token_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.status is not None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time


class EngineRouter:
    """Route requests across engine replicas; see the module docstring.

    Drive it like one engine: ``submit()`` then ``step()`` until
    ``has_work()`` is False, or ``serve()`` for a whole batch.  ``step()``
    returns merged ``(ticket_id, token, finished)`` events across every
    replica stepped.
    """

    def __init__(self, replicas: Sequence[EngineReplica], *,
                 policy: Callable[..., EngineReplica] | None = None,
                 max_waiting: int | None = None,
                 admission: str = "reject",
                 priority_fn: Callable[[Request], float] | None = None,
                 clock: Callable[[], float] = time.monotonic):
        replicas = list(replicas)
        if not replicas:
            raise ValueError("EngineRouter needs at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names: {names}")
        if admission not in ("reject", "shed"):
            raise ValueError(f"admission must be 'reject' or 'shed', "
                             f"got {admission!r}")
        self.replicas = replicas
        self.policy = policy or least_depth
        self.max_waiting = max_waiting
        self.admission = admission
        self.priority_fn = priority_fn or (lambda r: r.priority)
        self.clock = clock
        self.tickets: dict[int, ClusterRequest] = {}
        self._next_ticket = 0
        self._events: list = []
        self.counters = {"requests_rejected": 0, "requests_shed": 0,
                         "requests_timeout": 0, "requests_requeued": 0,
                         "replicas_quarantined": 0}

    # ---------------- routing ----------------

    def healthy_replicas(self, tier: str | None = None
                         ) -> list[EngineReplica]:
        live = [r for r in self.replicas if r.healthy]
        if tier is not None:
            tiered = [r for r in live if r.tier == tier]
            if tiered:
                return tiered
        return live

    @property
    def total_backlog(self) -> int:
        return sum(r.backlog for r in self.replicas if r.healthy)

    # ---------------- submission / admission ----------------

    def submit(self, request: Request, *, tier: str | None = None,
               deadline_s: float | None = None,
               on_token: Callable | None = None,
               on_finish: Callable | None = None) -> int:
        """Route a request; returns its cluster-wide ticket id.

        ``on_token(ticket_id, token, finished)`` streams tokens exactly as
        ``ContinuousEngine.submit(on_token=)`` does, but survives a
        replica requeue (the re-run's duplicate prefix is suppressed).
        ``on_finish(ticket)`` fires once, on any terminal status —
        including a synchronous rejection inside this call.  Check
        ``router.tickets[tid].status`` after submitting: a rejected
        request is already terminal.
        """
        now = self.clock()
        ticket = ClusterRequest(
            ticket_id=self._next_ticket, request=request, tier=tier,
            deadline=None if deadline_s is None else now + deadline_s,
            on_token=on_token, on_finish=on_finish, submit_time=now)
        self._next_ticket += 1
        self.tickets[ticket.ticket_id] = ticket
        if (self.max_waiting is not None
                and self.total_backlog >= self.max_waiting
                and not self._make_room(ticket)):
            return ticket.ticket_id
        self._dispatch(ticket)
        return ticket.ticket_id

    def _make_room(self, ticket: ClusterRequest) -> bool:
        """Admission control at a full backlog: reject the newcomer, or
        shed the lowest-priority waiting request to admit it."""
        if self.admission == "reject":
            self.counters["requests_rejected"] += 1
            self._finalize(ticket, REJECTED)
            return False
        waiting = [t for t in self.tickets.values()
                   if not t.done and t.replica is not None
                   and self._is_waiting(t)]
        # lowest priority loses; among equals, the newest submission
        # (shedding old FCFS work for an equal newcomer would churn)
        victim = min(waiting,
                     key=lambda t: (self.priority_fn(t.request),
                                    -t.ticket_id),
                     default=None)
        self.counters["requests_shed"] += 1
        if (victim is None
                or self.priority_fn(victim.request)
                >= self.priority_fn(ticket.request)):
            self._finalize(ticket, SHED)
            return False
        self._cancel_ticket(victim, SHED)
        return True

    def _is_waiting(self, ticket: ClusterRequest) -> bool:
        return any(s.request_id == ticket.local_id
                   for s in ticket.replica.engine.scheduler.waiting)

    def _dispatch(self, ticket: ClusterRequest) -> None:
        live = self.healthy_replicas(ticket.tier)
        if not live:
            raise RuntimeError("no healthy replicas left")
        replica = self.policy(live, ticket.request)
        ticket.attempts += 1
        ticket.replica = replica
        ticket.local_id = replica.engine.submit(
            ticket.request, on_token=self._bridge(ticket))

    def _bridge(self, ticket: ClusterRequest) -> Callable:
        """Per-dispatch engine callback: forwards the replica's token
        stream onto the ticket, skipping the prefix a previous dispatch
        already emitted (requeue after a replica fault)."""
        skip = len(ticket.tokens)
        seen = 0

        def cb(local_id: int, token: int, finished: bool) -> None:
            nonlocal seen
            seen += 1
            if seen > skip:
                if ticket.first_token_time is None:
                    ticket.first_token_time = self.clock()
                ticket.tokens.append(int(token))
                self._events.append((ticket.ticket_id, int(token),
                                     finished))
                if ticket.on_token is not None:
                    ticket.on_token(ticket.ticket_id, int(token), finished)
            if finished:
                state = ticket.replica.engine.scheduler.finished.get(
                    ticket.local_id)
                if state is not None:
                    ticket.finish_reason = state.finish_reason
                self._finalize(ticket, COMPLETED)
        return cb

    # ---------------- cancellation / resolution ----------------

    def cancel(self, ticket_id: int, *, status: str = CANCELLED) -> bool:
        """Cancel a live request (frees its KV slot the same step).
        Returns False when the id is unknown or already terminal."""
        ticket = self.tickets.get(ticket_id)
        if ticket is None or ticket.done:
            return False
        self._cancel_ticket(ticket, status)
        return True

    def _cancel_ticket(self, ticket: ClusterRequest, status: str) -> None:
        if ticket.replica is not None and ticket.local_id is not None:
            ticket.replica.engine.cancel(ticket.local_id)
        self._finalize(ticket, status)

    def _finalize(self, ticket: ClusterRequest, status: str) -> None:
        if ticket.done:
            return
        ticket.status = status
        if ticket.finish_reason is None:
            ticket.finish_reason = status
        if ticket.on_finish is not None:
            ticket.on_finish(ticket)

    # ---------------- the serving loop ----------------

    def step(self) -> list:
        """One cluster step: expire deadlines, step every healthy replica
        with work (quarantining any whose ``step()`` raises and requeuing
        its in-flight requests onto survivors), and return the merged
        ``(ticket_id, token, finished)`` events."""
        self._events = []
        now = self.clock()
        for ticket in list(self.tickets.values()):
            if (not ticket.done and ticket.deadline is not None
                    and now >= ticket.deadline):
                self.counters["requests_timeout"] += 1
                self._cancel_ticket(ticket, TIMEOUT)
        for replica in self.replicas:
            if not replica.healthy or not replica.engine.scheduler.has_work():
                continue
            try:
                replica.engine.step()
            except Exception as exc:
                self._quarantine(replica, exc)
        return self._events

    def _quarantine(self, replica: EngineReplica,
                    exc: BaseException) -> None:
        replica.healthy = False
        replica.fault = exc
        self.counters["replicas_quarantined"] += 1
        stranded = [t for t in self.tickets.values()
                    if not t.done and t.replica is replica]
        if not any(r.healthy for r in self.replicas):
            for ticket in stranded:
                self._finalize(ticket, FAILED)
            raise RuntimeError(
                f"replica {replica.name!r} failed with no survivors"
            ) from exc
        for ticket in stranded:
            self.counters["requests_requeued"] += 1
            self._dispatch(ticket)

    def has_work(self) -> bool:
        return any(r.healthy and r.engine.scheduler.has_work()
                   for r in self.replicas)

    def serve(self, requests: Sequence[Request], *,
              tiers: Sequence[str | None] | None = None,
              deadline_s: float | None = None) -> dict[int, list[int]]:
        """Route ``requests`` and run the cluster to completion; returns
        ``{ticket_id: tokens}`` (empty list for rejected/shed/expired
        requests — check ``tickets[tid].status``)."""
        tiers = tiers if tiers is not None else [None] * len(requests)
        ids = [self.submit(r, tier=t, deadline_s=deadline_s)
               for r, t in zip(requests, tiers)]
        while self.has_work():
            self.step()
        return {tid: list(self.tickets[tid].tokens) for tid in ids}

    # ---------------- metrics ----------------

    def metrics(self) -> ClusterMetrics:
        """Live cluster metrics: per-replica ``ServeMetrics`` (aggregate
        with ``ClusterMetrics.merge``), instantaneous gauges, and the
        router's admission/fault counters."""
        return ClusterMetrics(
            replicas={r.name: r.engine.metrics for r in self.replicas},
            gauges={r.name: {
                "queue_depth": float(r.engine.scheduler.queue_depth),
                "running": float(r.engine.scheduler.n_running),
                "slots_free": float(r.engine.pool.n_free),
                "healthy": 1.0 if r.healthy else 0.0,
            } for r in self.replicas},
            counters=dict(self.counters))
