"""Asyncio serving front-end over the engine router.

``AsyncFrontend`` turns the synchronous ``EngineRouter.submit()/step()``
host loop into a service: requests arrive on an asyncio queue from any
number of concurrent client coroutines, while the (GIL-releasing,
jit-dispatching) ``router.step()`` runs in an executor thread so the
event loop stays responsive between steps.

One background task owns the router.  It alternates between applying
queued commands (submissions, cancellations) and awaiting the next
cluster step in the executor — router state is therefore only ever
touched from one logical thread at a time, with no locking.  Token
callbacks fire inside ``router.step()`` on the executor thread and are
bridged back onto the loop with ``call_soon_threadsafe``, preserving
generation order.

``await frontend.submit(request)`` resolves immediately to a
``RequestHandle``:

    handle = await frontend.submit(Request(prompt=..., max_tokens=8))
    async for token in handle:          # streams as steps complete
        ...
    result = await handle               # RequestResult(status, tokens, ...)

The handle's future resolves with a terminal status for every fate a
routed request can meet: ``"completed"``, ``"cancelled"``
(``handle.cancel()``), ``"timeout"`` (``deadline_s=``), ``"rejected"`` /
``"shed"`` (admission control at a bounded queue), or ``"failed"`` (the
cluster lost its last replica).  Token iteration always terminates:
the terminal status ends the stream.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import Optional

from repro import obs
from repro.serve import cluster as _cluster
from repro.serve.cluster import EngineRouter
from repro.serve.scheduler import Request

_DONE = object()   # sentinel ending a handle's token stream


@dataclasses.dataclass
class RequestResult:
    """Terminal outcome of one routed request."""
    status: str                    # cluster status: completed/cancelled/...
    tokens: list                   # every token streamed to the client
    finish_reason: Optional[str]   # "stop"/"length", or the status


class RequestHandle:
    """Awaitable, async-iterable handle for one submitted request.

    ``async for token in handle`` yields tokens in generation order as the
    cluster produces them; ``await handle`` resolves to the
    ``RequestResult``.  Both may be used together (iteration first, then
    the await returns instantly) or independently.
    """

    def __init__(self, frontend: "AsyncFrontend"):
        self._frontend = frontend
        self._queue: asyncio.Queue = asyncio.Queue()
        self._result: asyncio.Future = (
            frontend._loop.create_future())
        self.request_id: Optional[int] = None   # ticket id, set on routing

    def __await__(self):
        return asyncio.shield(self._result).__await__()

    async def result(self) -> RequestResult:
        return await asyncio.shield(self._result)

    def done(self) -> bool:
        return self._result.done()

    async def tokens(self):
        while True:
            item = await self._queue.get()
            if item is _DONE:
                return
            yield item

    def __aiter__(self):
        return self.tokens()

    async def cancel(self) -> None:
        """Request cancellation; the result future resolves with status
        ``"cancelled"`` once the router frees the request's slot."""
        await self._frontend._enqueue(("cancel", self))

    # -- called on the event loop (via call_soon_threadsafe) --

    def _push_token(self, token: int) -> None:
        self._queue.put_nowait(token)

    def _finish(self, result: RequestResult) -> None:
        if not self._result.done():
            self._result.set_result(result)
        self._queue.put_nowait(_DONE)


class AsyncFrontend:
    """The async service layer; see the module docstring.

    Use as an async context manager (``async with AsyncFrontend(router)``)
    or call ``start()``/``stop()`` explicitly.  ``stop()`` drains by
    default — the loop keeps stepping until every routed request reaches
    a terminal status; ``stop(drain=False)`` cancels live requests
    instead.  ``frontend.error`` carries the exception if the cluster
    lost its last replica (every pending handle resolves ``"failed"``
    first, so awaiting clients never hang).
    """

    def __init__(self, router: EngineRouter, *, executor=None):
        self.router = router
        self.error: Optional[BaseException] = None
        self._executor = executor
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._inbox: Optional[asyncio.Queue] = None
        self._task: Optional[asyncio.Task] = None
        self._stopping: Optional[str] = None      # None | "drain" | "abort"
        self._handles: dict[int, RequestHandle] = {}

    async def __aenter__(self) -> "AsyncFrontend":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop(drain=exc == (None, None, None))

    async def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("frontend already started")
        self._loop = asyncio.get_running_loop()
        self._inbox = asyncio.Queue()
        self._stopping = None
        self.error = None
        self._task = asyncio.create_task(self._run(), name="serve-frontend")

    async def stop(self, *, drain: bool = True) -> None:
        """Stop the background loop.  ``drain=True`` finishes all live
        requests first; ``drain=False`` cancels them (their handles
        resolve with status ``"cancelled"``)."""
        if self._task is None:
            return
        self._stopping = "drain" if drain else "abort"
        await self._enqueue(("wake",))
        try:
            await self._task
        finally:
            self._task = None

    async def submit(self, request: Request, *, tier: str | None = None,
                     deadline_s: float | None = None) -> RequestHandle:
        """Queue a request for routing; returns its handle immediately.

        Admission control happens on the loop: a rejected or shed request
        resolves its handle with that status rather than raising here.
        """
        if self._task is None:
            raise RuntimeError("frontend is not started")
        handle = RequestHandle(self)
        if self._stopping == "abort" or self._task.done():
            # stop already landed: this submit will never be routed, so
            # resolve its handle terminally instead of leaving the
            # awaiter hanging on a command nobody will drain
            self._resolve_unrouted(handle)
            return handle
        await self._enqueue(("submit", handle, request, tier, deadline_s))
        if self._task.done() and not handle.done():
            # the loop exited between the check and the enqueue: the
            # command is in a dead inbox — resolve the handle here
            self._resolve_unrouted(handle)
        return handle

    @staticmethod
    def _resolve_unrouted(handle: RequestHandle) -> None:
        handle._finish(RequestResult(status=_cluster.CANCELLED, tokens=[],
                                     finish_reason=_cluster.CANCELLED))

    async def _enqueue(self, command: tuple) -> None:
        if self._inbox is None:
            raise RuntimeError("frontend is not started")
        await self._inbox.put(command)

    # ---------------- the background loop ----------------

    async def _run(self) -> None:
        loop = self._loop
        try:
            while True:
                while not self._inbox.empty():
                    self._apply(self._inbox.get_nowait())
                if self._stopping == "abort":
                    return
                if not self.router.has_work():
                    if self._stopping:
                        return
                    # idle: block until a client says something
                    self._apply(await self._inbox.get())
                    continue
                # executor threads don't inherit the loop's contextvars,
                # so a tracer scoped around the frontend (repro.use
                # tracer=...) is re-activated around each step explicitly
                tr = obs.current_tracer()
                if tr is None:
                    await loop.run_in_executor(self._executor,
                                               self.router.step)
                else:
                    await loop.run_in_executor(self._executor,
                                               self._traced_step, tr)
        except Exception as exc:
            # total cluster failure: resolve every pending handle so no
            # client awaits forever, then surface the fault on .error
            self.error = exc
            for tid, handle in list(self._handles.items()):
                ticket = self.router.tickets.get(tid)
                handle._finish(RequestResult(
                    status=(ticket.status if ticket and ticket.done
                            else _cluster.FAILED),
                    tokens=list(ticket.tokens) if ticket else [],
                    finish_reason=(ticket.finish_reason
                                   if ticket and ticket.finish_reason
                                   else _cluster.FAILED)))
                self._handles.pop(tid, None)
        finally:
            # abort path: cancel whatever is still live (resolves handles
            # through the normal on_finish bridge)
            for tid in list(self._handles):
                self.router.cancel(tid)
            # commands still in the inbox were never applied (stop or a
            # cluster fault beat them): resolve their handles terminally
            # so no submitter awaits a dead loop
            while self._inbox is not None and not self._inbox.empty():
                command = self._inbox.get_nowait()
                if command[0] == "submit":
                    self._resolve_unrouted(command[1])

    def _traced_step(self, tracer) -> None:
        with obs.activate(tracer):
            self.router.step()

    def _apply(self, command: tuple) -> None:
        op = command[0]
        if op == "submit":
            _, handle, request, tier, deadline_s = command

            def on_token(tid, token, finished, handle=handle):
                self._loop.call_soon_threadsafe(handle._push_token, token)

            def on_finish(ticket, handle=handle):
                self._handles.pop(ticket.ticket_id, None)
                self._loop.call_soon_threadsafe(
                    handle._finish,
                    RequestResult(status=ticket.status,
                                  tokens=list(ticket.tokens),
                                  finish_reason=ticket.finish_reason))

            try:
                tid = self.router.submit(request, tier=tier,
                                         deadline_s=deadline_s,
                                         on_token=on_token,
                                         on_finish=on_finish)
            except ValueError as exc:
                # invalid request (e.g. prompt + max_tokens exceeds the
                # pool): resolve this handle, don't kill the service loop
                handle._finish(RequestResult(
                    status=_cluster.FAILED, tokens=[],
                    finish_reason=f"invalid request: {exc}"))
                return
            handle.request_id = tid
            if not self.router.tickets[tid].done:   # rejected => resolved
                self._handles[tid] = handle
        elif op == "cancel":
            handle = command[1]
            if handle.request_id is not None:
                self.router.cancel(handle.request_id)
        # "wake" carries no action: it just unblocks the idle await
