"""Serving runtime: static reference engine + continuous batching."""
from repro.serve.engine import (  # noqa: F401
    ContinuousEngine,
    Engine,
    PoolConfig,
    ServeConfig,
    completed_lengths,
)
from repro.serve.kv_cache import SlotKVCache  # noqa: F401
from repro.serve.metrics import ServeMetrics  # noqa: F401
from repro.serve.scheduler import Request, RequestState, Scheduler  # noqa: F401
