"""Serving runtime: static reference engine, continuous batching, the
multi-replica router, and the asyncio front-end."""
from repro.serve.cluster import (  # noqa: F401
    ClusterRequest,
    EngineReplica,
    EngineRouter,
    least_depth,
)
from repro.serve.engine import (  # noqa: F401
    ContinuousEngine,
    Engine,
    PoolConfig,
    ServeConfig,
    completed_lengths,
)
from repro.serve.frontend import (  # noqa: F401
    AsyncFrontend,
    RequestHandle,
    RequestResult,
)
from repro.serve.kv_cache import SlotKVCache  # noqa: F401
from repro.serve.metrics import (  # noqa: F401
    ClusterMetrics,
    ServeMetrics,
    render_prometheus,
)
from repro.serve.scheduler import Request, RequestState, Scheduler  # noqa: F401
