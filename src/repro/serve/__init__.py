"""Serving runtime: static reference engine, continuous batching, the
multi-replica router, self-healing (fault classification, retry/backoff,
health probes, re-admission, fault injection), the asyncio front-end,
and a stdlib HTTP shim over it."""
from repro.serve.cluster import (  # noqa: F401
    ClusterRequest,
    EngineReplica,
    EngineRouter,
    least_depth,
)
from repro.serve.faults import (  # noqa: F401
    FaultClock,
    FaultInjector,
    FaultSpec,
)
from repro.serve.health import (  # noqa: F401
    ClusterHealth,
    FatalError,
    HealthConfig,
    ReplicaHungError,
    ReplicaStragglerError,
    RetryPolicy,
    TransientError,
    classify_failure,
)
from repro.serve.engine import (  # noqa: F401
    ContinuousEngine,
    Engine,
    PoolConfig,
    ServeConfig,
    completed_lengths,
)
from repro.serve.frontend import (  # noqa: F401
    AsyncFrontend,
    RequestHandle,
    RequestResult,
)
from repro.serve.http import HttpFrontend, request_from_payload  # noqa: F401
from repro.serve.kv_cache import PagedKVCache, SlotKVCache  # noqa: F401
from repro.serve.metrics import (  # noqa: F401
    ClusterMetrics,
    LatencyHistogram,
    ServeMetrics,
    render_prometheus,
)
from repro.serve.scheduler import Request, RequestState, Scheduler  # noqa: F401
