"""Deterministic fault injection for the serving stack.

Every recovery path in the self-healing layer (retry, quarantine, probe,
re-admission, watchdog, corrupt-cache fallback) is driven end-to-end by
this harness, so it is testable on CPU and reproducible in CI:

  * :class:`FaultSpec` — one scheduled fault: a *site* (``"step"``,
    ``"prefill"``, ``"decode"``, or any caller-chosen label), the 1-based
    call index ``at`` at which it fires for a given target, and a kind —
    ``"transient"`` / ``"fatal"`` (raise the matching
    ``serve.health`` error) or ``"hang"`` (advance the injectable clock
    by ``hang_s`` so the step appears to have stalled past the watchdog
    deadline, then let the call proceed).  ``repeat=True`` makes the
    fault permanent from ``at`` on (``until`` bounds it — a fault that
    "clears" after call ``until``).
  * :class:`FaultInjector` — matches specs against per-``(site, target)``
    call counters.  ``instrument(engine, name)`` wraps a
    ``ContinuousEngine``'s ``step`` / ``_prefill`` / ``_decode`` entry
    points so faults fire inside the real serving loop; engines built
    later (e.g. a warm restart from a replica factory) are *not*
    instrumented unless the factory instruments them — restarting really
    does clear instance-bound faults, which is exactly the semantics
    re-admission relies on.  Optional seeded ``rates`` add random
    transient chaos per site, deterministic for a fixed seed and call
    order.  Everything that fires is recorded in ``injector.fired``.
  * :meth:`FaultInjector.corrupt_cache` — deterministic tuning-cache IO
    faults: truncate, overwrite with garbage, or rewrite with an
    unknown schema, for exercising the hardened loader's
    warn-and-fall-back path.
  * :class:`FaultClock` — a controllable monotonic clock shared by the
    injector and the router, so hangs, backoff, probe intervals, and
    deadlines all advance deterministically in tests.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.serve.health import FatalError, TransientError


class FaultClock:
    """Injectable monotonic clock: ``now()`` / ``advance(s)``.

    Callable, so an instance drops in anywhere a ``clock=`` callable is
    expected (``EngineRouter(clock=clk)``), and its ``advance`` method
    drops in as a deterministic ``sleep=`` (backoff consumes simulated
    time instead of wall time).
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, seconds: float) -> None:
        self._t += float(seconds)

    def __call__(self) -> float:
        return self.now()


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault; see the module docstring."""
    site: str
    at: int = 1
    kind: str = "transient"          # "transient" | "fatal" | "hang"
    target: Optional[str] = None     # None matches any target at the site
    hang_s: float = 0.0
    repeat: bool = False
    until: Optional[int] = None      # with repeat: last call that faults

    def __post_init__(self):
        if self.kind not in ("transient", "fatal", "hang"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "hang" and self.hang_s <= 0:
            raise ValueError("hang faults need hang_s > 0")

    def matches(self, site: str, target: Optional[str], count: int) -> bool:
        if site != self.site:
            return False
        if self.target is not None and target != self.target:
            return False
        if self.repeat:
            return count >= self.at and (self.until is None
                                         or count <= self.until)
        return count == self.at


class FaultInjector:
    """Seedable, schedule-driven fault source; see the module docstring."""

    def __init__(self, specs: Sequence[FaultSpec] = (), *,
                 clock: FaultClock | None = None, seed: int = 0,
                 rates: dict[str, float] | None = None):
        self.specs = list(specs)
        self.clock = clock
        self.rates = dict(rates or {})
        self._rng = np.random.default_rng(seed)
        self.calls: collections.Counter = collections.Counter()
        self.fired: list[tuple] = []    # (site, target, call#, kind)

    # ---------------- the fault source ----------------

    def fire(self, site: str, target: str | None = None) -> None:
        """Account one call at ``site`` for ``target``; raise / hang when
        a spec (or the site's random rate) says so."""
        self.calls[(site, target)] += 1
        count = self.calls[(site, target)]
        for spec in self.specs:
            if spec.matches(site, target, count):
                self._trigger(spec.kind, site, target, count,
                              hang_s=spec.hang_s)
                return
        rate = self.rates.get(site, 0.0)
        if rate and float(self._rng.random()) < rate:
            self._trigger("transient", site, target, count)

    def _trigger(self, kind: str, site: str, target: str | None,
                 count: int, hang_s: float = 0.0) -> None:
        self.fired.append((site, target, count, kind))
        where = f"{site}[{target}] call {count}"
        if kind == "hang":
            if self.clock is None:
                raise ValueError(
                    "hang faults need FaultInjector(clock=FaultClock())")
            self.clock.advance(hang_s)   # the call "took" hang_s
            return
        if kind == "transient":
            raise TransientError(f"injected transient fault at {where}")
        raise FatalError(f"injected fatal fault at {where}")

    # ---------------- instrumentation ----------------

    def instrument(self, engine, name: str):
        """Wrap ``engine``'s step / prefill / decode entry points so this
        injector fires inside them (sites ``"step"`` / ``"prefill"`` /
        ``"decode"``, target ``name``).  Returns the engine.  The wrap is
        instance-bound: a fresh engine (warm restart) is clean.
        """
        orig_step = engine.step
        orig_prefill = engine._prefill
        orig_decode = engine._decode

        def step(*a, **kw):
            self.fire("step", name)
            return orig_step(*a, **kw)

        def prefill(*a, **kw):
            self.fire("prefill", name)
            return orig_prefill(*a, **kw)

        def decode(*a, **kw):
            self.fire("decode", name)
            return orig_decode(*a, **kw)

        engine.step = step
        engine._prefill = prefill
        engine._decode = decode
        return engine

    # ---------------- tuning-cache IO faults ----------------

    @staticmethod
    def corrupt_cache(path: str, mode: str = "garbage") -> None:
        """Deterministically corrupt a tuning-cache file.

        ``"garbage"`` overwrites with non-JSON bytes; ``"truncate"``
        keeps the first half of the existing file (a partially-written
        save), simulating a crash mid-write on a non-atomic writer;
        ``"unknown"`` writes valid JSON with an unrecognized schema.
        The hardened loader must warn and fall back to heuristic blocks
        for all three.
        """
        if mode == "garbage":
            payload = "{this is not json\x00"
        elif mode == "truncate":
            try:
                with open(path) as f:
                    text = f.read()
            except OSError:
                text = '{"version": 1, "entries": [{"op": "matmul", '
            payload = text[:max(1, len(text) // 2)]
        elif mode == "unknown":
            payload = ('{"version": 999, "schema": "from-the-future", '
                       '"entries": {"not": "a list"}}')
        else:
            raise ValueError(f"unknown corruption mode {mode!r}")
        with open(path, "w") as f:
            f.write(payload)
