"""Admission + step scheduler for continuous batching.

Pure host-side bookkeeping (no jax): requests queue on submission, are
admitted into KV-cache slots as capacity frees up (FCFS by default, with a
priority hook), and are evicted the step they finish (stop token,
``max_tokens``, or ``cancel()``).  The engine drives it:

    state = scheduler.next_waiting()     # admission order
    scheduler.start(state, slot, step)   # after prefill
    scheduler.record_token(state, tok, step)  # True => finished + evicted
    scheduler.cancel(request_id, step=step)   # waiting or running

The scheduler never touches device state; slot recycling is the engine's
job (``SlotKVCache.free``).  Wall-clock stamps (``submit_time``,
``first_token_time``) are recorded on each state so time-to-first-token
can be reported in seconds, not just scheduler steps.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Optional, Sequence

WAITING, RUNNING, FINISHED = "waiting", "running", "finished"


@dataclasses.dataclass
class Request:
    """One generation request.

    ``stop_tokens=None`` defers to the engine default (``cfg.eos_token``
    when set); pass ``()`` to disable early stop.  ``temperature=0`` is
    greedy; ``top_k=0`` disables top-k filtering.  ``src_embeds`` (enc-dec
    encoder memory) and ``patch_embeds`` (VLM prefix) are per-request
    modality inputs, shaped with or without the leading batch-1 axis.
    """
    prompt: Sequence[int]
    max_tokens: int
    temperature: float = 0.0
    top_k: int = 0
    stop_tokens: Optional[Sequence[int]] = None
    priority: float = 0.0
    src_embeds: Any = None
    patch_embeds: Any = None


@dataclasses.dataclass
class RequestState:
    """Scheduler-tracked lifecycle of one request."""
    request: Request
    request_id: int
    stop_tokens: tuple
    status: str = WAITING
    slot: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)
    submit_step: int = 0
    admit_step: Optional[int] = None
    first_token_step: Optional[int] = None
    finish_step: Optional[int] = None
    finish_reason: Optional[str] = None   # "stop" | "length" | "cancelled"
    submit_time: float = 0.0              # wall clock (time.perf_counter)
    first_token_time: Optional[float] = None
    # TTFT breakdown stamps (engine clock, same domain as submit_time):
    # admission start and prefill completion split TTFT into queue wait /
    # prefill / first-decode segments that telescope exactly
    admit_time: Optional[float] = None
    prefill_end_time: Optional[float] = None
    finish_time: Optional[float] = None
    trace: Optional[str] = None           # trace id (obs), None untraced

    @property
    def ttft_s(self) -> Optional[float]:
        """Wall-clock time-to-first-token in seconds (None before it)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def ttft_breakdown(self) -> Optional[dict]:
        """Where TTFT went: ``{"queue_s", "prefill_s", "first_decode_s"}``.

        The three segments are cut from contiguous stamps on one clock
        (submit -> admit -> prefill end -> first token), so they sum to
        ``ttft_s`` exactly.  None until the first token (or when the
        engine never stamped the admission, e.g. states finished by
        ``cancel`` while waiting).
        """
        if (self.first_token_time is None or self.admit_time is None
                or self.prefill_end_time is None):
            return None
        return {
            "queue_s": self.admit_time - self.submit_time,
            "prefill_s": self.prefill_end_time - self.admit_time,
            "first_decode_s": self.first_token_time
            - self.prefill_end_time,
        }


class Scheduler:
    """FCFS admission with a priority hook.

    ``priority_fn(request) -> float`` overrides the admission order:
    higher priority first, FCFS (submission order) among ties.  Without it,
    ``Request.priority`` is used the same way (all-zero priorities degrade
    to pure FCFS).
    """

    def __init__(self, *, priority_fn: Callable[[Request], float] | None
                 = None):
        self.priority_fn = priority_fn
        self.waiting: collections.deque[RequestState] = collections.deque()
        self.running: dict[int, RequestState] = {}    # slot -> state
        self.finished: dict[int, RequestState] = {}   # request_id -> state
        self._next_id = 0

    # ---------------- submission / admission ----------------

    def submit(self, request: Request, *, stop_tokens: tuple = (),
               step: int = 0, now: float | None = None,
               trace: str | None = None) -> int:
        """Queue a request; returns its id.  ``stop_tokens`` is the
        engine-resolved stop set (request override already applied);
        ``trace`` is an opaque trace id threaded onto the request's
        spans (router ticket ids propagate here)."""
        state = RequestState(request=request, request_id=self._next_id,
                             stop_tokens=tuple(stop_tokens),
                             submit_step=step, trace=trace,
                             submit_time=(time.perf_counter()
                                          if now is None else now))
        self._next_id += 1
        self.waiting.append(state)
        return state.request_id

    def next_waiting(self) -> RequestState | None:
        """Pop the next request to admit (priority, then FCFS)."""
        if not self.waiting:
            return None
        key = self.priority_fn or (lambda req: req.priority)
        # max() is stable over first occurrence: FCFS among equal priority.
        best = max(self.waiting, key=lambda s: key(s.request))
        self.waiting.remove(best)
        return best

    def requeue(self, state: RequestState) -> None:
        """Put an un-admitted state back at the head of the queue.

        The engine's prefill-failure path: admission popped the state and
        allocated a slot, prefill raised, the slot was freed — the state
        goes back first-in-line so a retried step picks it up again
        (retry-safe admission: no work is lost, none duplicated)."""
        state.status = WAITING
        state.slot = None
        state.admit_step = None
        state.admit_time = None
        state.prefill_end_time = None
        self.waiting.appendleft(state)

    def preempt(self, state: RequestState) -> None:
        """Kick a *running* state back to the head of the queue (the
        engine reclaims its KV pages).  Generated tokens are folded into
        the prompt, so the re-admission prefill recomputes the same KV and
        the next sampled token continues the sequence; ``state.generated``
        keeps the emitted tokens, so ``max_tokens`` still counts the total
        and nothing is emitted twice.  TTFT stamps survive — preemption
        does not reset a request's first token."""
        state.request = dataclasses.replace(
            state.request,
            prompt=tuple(state.request.prompt) + tuple(state.generated))
        if state.slot is not None:
            self.running.pop(state.slot, None)
        state.status = WAITING
        state.slot = None
        state.admit_step = None
        state.admit_time = None
        state.prefill_end_time = None
        self.waiting.appendleft(state)

    def start(self, state: RequestState, slot: int, step: int) -> None:
        state.status = RUNNING
        state.slot = slot
        state.admit_step = step
        self.running[slot] = state

    # ---------------- token accounting / eviction ----------------

    def record_token(self, state: RequestState, token: int,
                     step: int, now: float | None = None) -> bool:
        """Append a generated token; returns True when the request is
        finished (and has been moved out of ``running``)."""
        state.generated.append(int(token))
        if state.first_token_step is None:
            state.first_token_step = step
            state.first_token_time = (time.perf_counter()
                                      if now is None else now)
        reason = None
        if int(token) in state.stop_tokens:
            reason = "stop"
        elif len(state.generated) >= state.request.max_tokens:
            reason = "length"
        if reason is None:
            return False
        self._finish(state, reason, step, now=now)
        return True

    def _finish(self, state: RequestState, reason: str, step: int,
                now: float | None = None) -> None:
        state.status = FINISHED
        state.finish_reason = reason
        state.finish_step = step
        state.finish_time = time.perf_counter() if now is None else now
        if state.slot is not None:
            self.running.pop(state.slot, None)
        self.finished[state.request_id] = state

    def cancel(self, request_id: int, *, step: int = 0
               ) -> RequestState | None:
        """Cancel a waiting *or* running request (same-step eviction).

        Returns the cancelled state (``finish_reason="cancelled"``) so the
        caller can free its KV slot (``state.slot``, set only if it was
        running), or None when the id is unknown or already finished —
        a cancelled request never leaks its slot until ``max_tokens``.
        """
        for state in self.waiting:
            if state.request_id == request_id:
                self.waiting.remove(state)
                self._finish(state, "cancelled", step)
                return state
        for state in list(self.running.values()):
            if state.request_id == request_id:
                self._finish(state, "cancelled", step)
                return state
        return None

    # ---------------- introspection ----------------

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)

    @property
    def n_running(self) -> int:
        return len(self.running)
