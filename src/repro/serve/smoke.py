"""Serving smoke for CI: continuous batching at the autotuned pallas tier.

``python -m repro.serve.smoke`` serves a handful of mixed-length requests
through ``ContinuousEngine`` with ``backend="pallas"`` in interpret mode and
``blocks_policy="autotune"``, asserts every request completes, and reports
how many block candidates were actually measured — zero on a warm persisted
``REPRO_TUNING_CACHE`` (``measured=0 cache=hit``, what CI asserts on the
second run).
"""
from __future__ import annotations

import argparse
import os
from typing import Sequence

import jax
import numpy as np


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=32)
    ap.add_argument("--candidates", type=int, default=None,
                    help="cap the measured candidate count per search")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)

    from repro import configs
    from repro.core import autotune
    from repro.models import api
    from repro.serve import ContinuousEngine, PoolConfig, Request

    if args.candidates is not None:
        os.environ[autotune.ENV_MAX_CANDIDATES] = str(args.candidates)
    if args.repeats is not None:
        os.environ[autotune.ENV_REPEATS] = str(args.repeats)

    cfg = configs.get(args.arch).reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    before = autotune.STATS.snapshot()
    engine = ContinuousEngine(
        cfg, params, PoolConfig(n_slots=args.n_slots, max_len=args.max_len),
        backend="pallas", blocks_policy="autotune", interpret=True)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, 3 + i % 7).tolist(),
                max_tokens=2 + i % 3, stop_tokens=())
        for i in range(args.requests)
    ]
    out = engine.serve(requests)
    completed = sum(1 for toks in out.values() if toks)
    measured = autotune.STATS.measured - before["measured"]
    hit = autotune.STATS.searches == before["searches"]
    print(f"serve-smoke arch={args.arch} "
          f"completed={completed}/{len(requests)} "
          f"tokens={engine.metrics.tokens_generated} "
          f"occupancy={engine.metrics.occupancy():.2f} "
          f"measured={measured} cache={'hit' if hit else 'miss'}")
    if completed != len(requests):
        raise SystemExit(f"only {completed}/{len(requests)} completed")


if __name__ == "__main__":
    main()
