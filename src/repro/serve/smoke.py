"""Serving smokes for CI.

``python -m repro.serve.smoke`` serves a handful of mixed-length requests
through ``ContinuousEngine`` with ``backend="pallas"`` in interpret mode and
``blocks_policy="autotune"``, asserts every request completes, and reports
how many block candidates were actually measured — zero on a warm persisted
``REPRO_TUNING_CACHE`` (``measured=0 cache=hit``, what CI asserts on the
second run).

``python -m repro.serve.smoke --frontend`` exercises the async serving
front-end instead: two engine replicas on different tiers behind an
``EngineRouter``, one replica hit by an injected ``step()`` fault
mid-service.  The smoke asserts the replica is quarantined, its in-flight
requests requeue onto the survivor, and *every* submitted request still
resolves ``completed`` through its awaitable handle — then prints the
Prometheus exposition line count as a sanity check on metrics export.

``python -m repro.serve.smoke --chaos`` drives the full self-healing
loop under a seeded ``FaultInjector``: three replicas, transient faults
(survived by in-place retry), one permanent fault and one hang (each
quarantining its replica, which is then health-probed, warm-restarted,
and re-admitted), all on an injectable clock.  The smoke asserts every
request reaches a terminal status, at least one retry / two quarantines
/ two re-admissions happened, and the greedy token streams are
token-for-token identical to a fault-free reference run.

``python -m repro.serve.smoke --trace`` serves under an installed
``repro.obs.Tracer``: asserts prefill/decode/request spans were
recorded, that every request's TTFT breakdown (queue/prefill/first
decode) sums exactly to its wall-clock TTFT, and that the exported
Chrome trace JSON round-trips ``obs.chrome.validate``.

``python -m repro.serve.smoke --paged`` serves a mixed-length workload
through the paged KV pool (2x-overcommitted page budget + chunked
prefill) and through the slotted pool, asserting token-for-token greedy
parity, full completion, and a drained page allocator (no leaks).

``python -m repro.serve.smoke --chaos-soak`` is the long-haul variant of
``--chaos``: a seeded random transient-fault *rate* on every injector
site of two of three replicas, a 3x-length mixed workload on paged
pools, and SLO asserts — every request terminal, availability >= 95%,
and every completed stream token-identical to a fault-free reference.
"""
from __future__ import annotations

import argparse
import os
from typing import Sequence


def _continuous_smoke(args) -> None:
    import jax
    import numpy as np

    from repro import configs
    from repro.core import autotune
    from repro.models import api
    from repro.serve import ContinuousEngine, PoolConfig, Request

    cfg = configs.get(args.arch).reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    before = autotune.STATS.snapshot()
    # --quant: int8 decode tier next to the full-precision prefill tier —
    # the per-phase context mix production decode runs (decode streams
    # weights, so int8 halves its bytes; prefill stays compute-bound).
    decode_quant = "int8" if args.quant else None
    engine = ContinuousEngine(
        cfg, params, PoolConfig(n_slots=args.n_slots, max_len=args.max_len),
        backend="pallas", blocks_policy="autotune", interpret=True,
        decode_quant=decode_quant)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, 3 + i % 7).tolist(),
                max_tokens=2 + i % 3, stop_tokens=())
        for i in range(args.requests)
    ]
    out = engine.serve(requests)
    completed = sum(1 for toks in out.values() if toks)
    measured = autotune.STATS.measured - before["measured"]
    hit = autotune.STATS.searches == before["searches"]
    qfield = " quant=int8-decode" if args.quant else ""
    print(f"serve-smoke arch={args.arch}{qfield} "
          f"completed={completed}/{len(requests)} "
          f"tokens={engine.metrics.tokens_generated} "
          f"occupancy={engine.metrics.occupancy():.2f} "
          f"measured={measured} cache={'hit' if hit else 'miss'}")
    if completed != len(requests):
        raise SystemExit(f"only {completed}/{len(requests)} completed")


def _frontend_smoke(args) -> None:
    import asyncio

    import jax
    import numpy as np

    from repro import configs
    from repro.models import api
    from repro.serve import (AsyncFrontend, ContinuousEngine, EngineReplica,
                             EngineRouter, PoolConfig, Request)

    cfg = configs.get(args.arch).reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pool = lambda: PoolConfig(n_slots=args.n_slots,  # noqa: E731
                              max_len=args.max_len)
    # two tiers: default accumulation next to an explicit bf16-accum tier
    flaky = ContinuousEngine(cfg, params, pool(), accum_dtype="bfloat16")
    calls = [0]
    orig_step = flaky.step

    def injected_fault():
        calls[0] += 1
        if calls[0] == args.fail_at_step:
            raise RuntimeError("injected replica fault")
        return orig_step()
    flaky.step = injected_fault

    router = EngineRouter(
        [EngineReplica("stable", ContinuousEngine(cfg, params, pool()),
                       tier="fp32"),
         EngineReplica("flaky", flaky, tier="bf16")],
        max_waiting=4 * args.requests)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, 3 + i % 7).tolist(),
                max_tokens=3 + i % 3, stop_tokens=())
        for i in range(args.requests)
    ]

    async def main():
        async with AsyncFrontend(router) as frontend:
            handles = [await frontend.submit(r) for r in requests]
            return [await h for h in handles]

    results = asyncio.run(main())
    completed = sum(1 for r in results if r.status == "completed")
    tokens = sum(len(r.tokens) for r in results)
    prom_lines = len(router.metrics().to_prometheus().splitlines())
    print(f"frontend-smoke arch={args.arch} replicas=2 "
          f"completed={completed}/{len(requests)} tokens={tokens} "
          f"quarantined={router.counters['replicas_quarantined']} "
          f"requeued={router.counters['requests_requeued']} "
          f"prometheus_lines={prom_lines}")
    if completed != len(requests):
        bad = [(r.status, r.finish_reason) for r in results
               if r.status != "completed"]
        raise SystemExit(f"only {completed}/{len(requests)} completed: {bad}")
    if router.counters["replicas_quarantined"] != 1:
        raise SystemExit("the injected fault did not quarantine a replica")
    if router.counters["requests_requeued"] < 1:
        raise SystemExit("no requests were requeued off the failed replica")


def _chaos_smoke(args) -> None:
    import jax
    import numpy as np

    from repro import configs
    from repro.models import api
    from repro.serve import (ContinuousEngine, EngineReplica, EngineRouter,
                             FaultClock, FaultInjector, FaultSpec,
                             HealthConfig, PoolConfig, Request, RetryPolicy)

    cfg = configs.get(args.arch).reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pool = lambda: PoolConfig(n_slots=args.n_slots,  # noqa: E731
                              max_len=args.max_len)
    make_engine = lambda: ContinuousEngine(cfg, params, pool())  # noqa: E731

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, 3 + i % 7).tolist(),
                max_tokens=3 + i % 3, stop_tokens=())
        for i in range(args.requests)
    ]
    # greedy fault-free reference: with temperature=0 every token is a
    # pure function of the prompt, so chaos-run streams must match it
    reference = make_engine().serve(requests)
    ref_tokens = [reference[i] for i in sorted(reference)]

    clk = FaultClock()
    injector = FaultInjector([
        # transient blips on "flaky": survived by in-place retry
        FaultSpec(site="step", target="flaky", at=2, kind="transient"),
        FaultSpec(site="step", target="flaky", at=3, kind="transient"),
        # permanent fault on "doomed": quarantine -> probe -> re-admit
        FaultSpec(site="step", target="doomed", at=2, kind="fatal"),
        # one hang on "flaky" right after the retries, past the
        # watchdog deadline: quarantined too
        FaultSpec(site="step", target="flaky", at=4, kind="hang",
                  hang_s=10.0),
    ], clock=clk)
    replicas = [
        EngineReplica("stable", make_engine(), factory=make_engine),
        EngineReplica("flaky", injector.instrument(make_engine(), "flaky"),
                      factory=make_engine),
        EngineReplica("doomed", injector.instrument(make_engine(), "doomed"),
                      factory=make_engine),
    ]
    router = EngineRouter(
        replicas, clock=clk, sleep=clk.advance,
        retry=RetryPolicy(max_retries=3, backoff_s=0.01, seed=0),
        health=HealthConfig(probe_interval_s=1.0, probes_to_readmit=2,
                            max_probes=8, watchdog_s=5.0))

    out = router.serve(requests)
    statuses = [router.tickets[tid].status for tid in sorted(out)]
    # drive the probe loop until both quarantined replicas rejoin
    for _ in range(64):
        if all(r.healthy for r in replicas):
            break
        clk.advance(1.0)
        router.step()
    readmitted = router.counters["replicas_readmitted"]
    # second wave lands on the healed cluster (including the rejoins)
    out2 = router.serve(requests[:3])
    statuses += [router.tickets[tid].status for tid in sorted(out2)]

    chaos_tokens = [out[tid] for tid in sorted(out)]
    parity = sum(1 for got, ref in zip(chaos_tokens, ref_tokens)
                 if got == ref)
    terminal = sum(1 for s in statuses if s is not None)
    c = router.counters
    print(f"chaos-smoke arch={args.arch} replicas=3 "
          f"terminal={terminal}/{len(statuses)} "
          f"parity={parity}/{len(requests)} "
          f"retries={c['retries']} quarantined={c['replicas_quarantined']} "
          f"readmitted={readmitted} probes={c['probes']} "
          f"requeued={c['requests_requeued']} "
          f"faults={len(injector.fired)}")
    if terminal != len(statuses):
        raise SystemExit("a request never reached a terminal status")
    if parity != len(requests):
        bad = [i for i, (g, r) in enumerate(zip(chaos_tokens, ref_tokens))
               if g != r]
        raise SystemExit(f"chaos streams diverged from the fault-free "
                         f"reference at requests {bad}")
    if c["retries"] < 1:
        raise SystemExit("no transient fault was retried")
    if c["replicas_quarantined"] < 2:
        raise SystemExit("expected the fatal fault and the hang to "
                         "quarantine a replica each")
    if readmitted < 2:
        raise SystemExit("quarantined replicas were not re-admitted")
    if not all(r.healthy for r in replicas):
        raise SystemExit("a replica is still unhealthy after the probe "
                         "loop")


def _paged_smoke(args) -> None:
    import jax
    import numpy as np

    from repro import configs
    from repro.models import api
    from repro.serve import ContinuousEngine, PoolConfig, Request

    cfg = configs.get(args.arch).reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    # mixed prompt lengths, several past the chunk size so chunked
    # prefill runs, plus a 2x-overcommitted page budget so the allocator
    # churns (and may preempt) while parity must still hold
    lens = [3 + (7 * i) % (args.max_len - 12) for i in range(args.requests)]
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in lens]
    reqs = lambda: [Request(prompt=p, max_tokens=2 + i % 4,  # noqa: E731
                            stop_tokens=())
                    for i, p in enumerate(prompts)]

    slotted = ContinuousEngine(
        cfg, params, PoolConfig(n_slots=args.n_slots, max_len=args.max_len),
        interpret=True)
    reference = slotted.serve(reqs())

    page_size = 8
    pages_per_slot = -(-args.max_len // page_size)
    n_pages = max(pages_per_slot, args.n_slots * pages_per_slot // 2)
    engine = ContinuousEngine(
        cfg, params, PoolConfig(n_slots=args.n_slots, max_len=args.max_len,
                                page_size=page_size, n_pages=n_pages,
                                prefill_chunk=2 * page_size),
        interpret=True)
    if not engine.paged:
        raise SystemExit(f"arch {args.arch} did not take the paged pool")
    out = engine.serve(reqs())

    completed = sum(1 for toks in out.values() if toks)
    parity = sum(1 for a, b in zip(sorted(out), sorted(reference))
                 if out[a] == reference[b])
    pool = engine.pool
    leak_ok = (pool.page_alloc_count == pool.page_free_count
               and pool.n_free_pages == pool.n_pages
               and pool.n_free == pool.n_slots)
    print(f"paged-smoke arch={args.arch} "
          f"completed={completed}/{len(prompts)} "
          f"parity={parity}/{len(prompts)} "
          f"page_size={page_size} pages={n_pages} "
          f"chunks={engine.metrics.prefill_chunks} "
          f"preemptions={engine.metrics.preemptions} "
          f"page_occupancy={pool.page_occupancy:.2f} "
          f"fragmentation={pool.fragmentation:.2f} "
          f"leak={'ok' if leak_ok else 'LEAK'}")
    if completed != len(prompts):
        raise SystemExit(f"only {completed}/{len(prompts)} completed")
    if parity != len(prompts):
        bad = [int(a) for a, b in zip(sorted(out), sorted(reference))
               if out[a] != reference[b]]
        raise SystemExit(f"paged tokens diverged from slotted at {bad}")
    if not leak_ok:
        raise SystemExit(
            f"page leak after drain: alloc={pool.page_alloc_count} "
            f"free={pool.page_free_count} "
            f"free_pages={pool.n_free_pages}/{pool.n_pages}")


def _chaos_soak_smoke(args) -> None:
    import jax
    import numpy as np

    from repro import configs
    from repro.models import api
    from repro.serve import (ContinuousEngine, EngineReplica, EngineRouter,
                             FaultClock, FaultInjector, HealthConfig,
                             PoolConfig, Request, RetryPolicy)

    cfg = configs.get(args.arch).reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    page_size = 8
    pool = lambda: PoolConfig(n_slots=args.n_slots,  # noqa: E731
                              max_len=args.max_len, page_size=page_size,
                              prefill_chunk=2 * page_size)
    make_engine = lambda: ContinuousEngine(cfg, params, pool())  # noqa: E731

    rng = np.random.default_rng(0)
    n = 3 * args.requests   # a longer mixed soak, not a quick smoke
    lens = [3 + (7 * i) % (args.max_len - 12) for i in range(n)]
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, lens[i]).tolist(),
                max_tokens=2 + i % 4, stop_tokens=())
        for i in range(n)
    ]
    # greedy fault-free reference: the soaked cluster must stream the
    # exact same tokens for every request that completes
    reference = make_engine().serve(requests)
    ref_tokens = [reference[i] for i in sorted(reference)]

    clk = FaultClock()
    # no scripted faults: a seeded random transient *rate* per site, the
    # sustained low-grade failure weather a soak is about
    injector = FaultInjector([], clock=clk, seed=0,
                             rates={"step": 0.06, "prefill": 0.06,
                                    "decode": 0.06})
    replicas = [
        EngineReplica("stable", make_engine(), factory=make_engine),
        EngineReplica("soak-a", injector.instrument(make_engine(), "soak-a"),
                      factory=make_engine),
        EngineReplica("soak-b", injector.instrument(make_engine(), "soak-b"),
                      factory=make_engine),
    ]
    router = EngineRouter(
        replicas, clock=clk, sleep=clk.advance,
        retry=RetryPolicy(max_retries=4, backoff_s=0.01, seed=0),
        health=HealthConfig(probe_interval_s=1.0, probes_to_readmit=2,
                            max_probes=32, watchdog_s=600.0))

    out = router.serve(requests)
    statuses = [router.tickets[tid].status for tid in sorted(out)]
    terminal = sum(1 for s in statuses if s is not None)
    completed = sum(1 for s in statuses if s == "completed")
    chaos_tokens = [out[tid] for tid in sorted(out)]
    parity = sum(1 for got, ref in zip(chaos_tokens, ref_tokens)
                 if got == ref)
    availability = completed / n
    c = router.counters
    print(f"chaos-soak arch={args.arch} replicas=3 requests={n} "
          f"terminal={terminal}/{n} completed={completed}/{n} "
          f"parity={parity}/{completed} "
          f"availability={availability:.2f} "
          f"faults={len(injector.fired)} retries={c['retries']} "
          f"quarantined={c['replicas_quarantined']} "
          f"readmitted={c['replicas_readmitted']} "
          f"requeued={c['requests_requeued']}")
    # SLOs: every request reaches a terminal status; availability (the
    # completed fraction) holds 95% under the sustained fault rate; every
    # completed stream is token-for-token the fault-free reference
    if terminal != n:
        raise SystemExit("SLO violation: a request never reached a "
                         "terminal status")
    if availability < 0.95:
        raise SystemExit(f"SLO violation: availability "
                         f"{availability:.2f} < 0.95")
    if parity != completed:
        bad = [i for i, (g, r) in enumerate(zip(chaos_tokens, ref_tokens))
               if g != r and statuses[i] == "completed"]
        raise SystemExit(f"soak streams diverged from the fault-free "
                         f"reference at requests {bad}")
    if len(injector.fired) < 3:
        raise SystemExit(f"the soak barely soaked: only "
                         f"{len(injector.fired)} faults fired")


def _trace_smoke(args) -> None:
    import jax
    import numpy as np

    from repro import configs, obs
    from repro.models import api
    from repro.serve import ContinuousEngine, PoolConfig, Request

    cfg = configs.get(args.arch).reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    engine = ContinuousEngine(
        cfg, params, PoolConfig(n_slots=args.n_slots, max_len=args.max_len))

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, 3 + i % 7).tolist(),
                max_tokens=2 + i % 3, stop_tokens=())
        for i in range(args.requests)
    ]
    tracer = obs.Tracer()
    prev = obs.install(tracer)
    try:
        out = engine.serve(requests)
    finally:
        obs.install(prev)

    completed = sum(1 for toks in out.values() if toks)
    names = {r.name for r in tracer.spans()}
    for needed in ("prefill", "decode", "request", "request.queue",
                   "request.prefill", "request.first_decode"):
        if needed not in names:
            raise SystemExit(f"no {needed!r} span was recorded "
                             f"(got {sorted(names)})")
    # the TTFT breakdown must telescope: its segments are cut from
    # contiguous stamps on one clock, so they sum to ttft_s exactly
    checked = 0
    for state in engine.scheduler.finished.values():
        bd = state.ttft_breakdown
        if bd is None or state.ttft_s is None:
            raise SystemExit(
                f"request {state.request_id} has no TTFT breakdown")
        if abs(sum(bd.values()) - state.ttft_s) > 1e-6:
            raise SystemExit(
                f"request {state.request_id} breakdown {bd} does not sum "
                f"to ttft_s={state.ttft_s}")
        checked += 1

    n_events = obs.export_chrome(tracer, args.trace_out)
    trace = obs.chrome.load(args.trace_out)
    obs.chrome.validate(trace)
    chrome_names = {ev["name"] for ev in trace["traceEvents"]}
    if "request" not in chrome_names or "decode" not in chrome_names:
        raise SystemExit(f"chrome export lost spans: {sorted(chrome_names)}")

    print(f"trace-smoke arch={args.arch} "
          f"completed={completed}/{len(requests)} "
          f"spans={len(tracer.spans())} chrome_events={n_events} "
          f"breakdown=ok({checked}) trace={args.trace_out}")
    if completed != len(requests):
        raise SystemExit(f"only {completed}/{len(requests)} completed")


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=32)
    ap.add_argument("--quant", action="store_true",
                    help="serve with an int8 decode tier "
                         "(decode_quant='int8') next to full-precision "
                         "prefill")
    ap.add_argument("--frontend", action="store_true",
                    help="async front-end smoke: two replicas behind the "
                         "router, one injected fault, all must complete")
    ap.add_argument("--chaos", action="store_true",
                    help="self-healing smoke: seeded fault injector "
                         "(transient, fatal, hang) against three replicas "
                         "with retry + health probes; asserts retries, "
                         "quarantine, re-admission, and token parity with "
                         "a fault-free run")
    ap.add_argument("--paged", action="store_true",
                    help="paged-pool smoke: mixed-length workload on an "
                         "overcommitted page budget with chunked prefill, "
                         "token parity vs the slotted pool, allocator "
                         "leak check")
    ap.add_argument("--chaos-soak", action="store_true",
                    help="long mixed workload under a sustained seeded "
                         "transient-fault rate; asserts terminal-status "
                         "and availability SLOs plus greedy parity")
    ap.add_argument("--trace", action="store_true",
                    help="tracing smoke: serve under an installed tracer, "
                         "assert prefill/decode/request spans and an "
                         "exactly-telescoping TTFT breakdown, export + "
                         "validate a Chrome trace JSON")
    ap.add_argument("--trace-out", default="trace_smoke.json",
                    help="with --trace: Chrome trace output path")
    ap.add_argument("--fail-at-step", type=int, default=2,
                    help="with --frontend: replica step() call that raises")
    ap.add_argument("--candidates", type=int, default=None,
                    help="cap the measured candidate count per search")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)

    from repro.core import autotune

    if args.candidates is not None:
        os.environ[autotune.ENV_MAX_CANDIDATES] = str(args.candidates)
    if args.repeats is not None:
        os.environ[autotune.ENV_REPEATS] = str(args.repeats)

    if args.chaos_soak:
        _chaos_soak_smoke(args)
    elif args.chaos:
        _chaos_smoke(args)
    elif args.frontend:
        _frontend_smoke(args)
    elif args.trace:
        _trace_smoke(args)
    elif args.paged:
        _paged_smoke(args)
    else:
        _continuous_smoke(args)


if __name__ == "__main__":
    main()
