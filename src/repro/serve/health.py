"""Failure taxonomy, retry/backoff policy, and replica health tracking.

This is the policy half of the self-healing serving layer (the mechanism
lives in ``serve.cluster.EngineRouter``).  Three failure classes:

  * **transient** — a step failed but the replica is presumed fine
    (spurious dispatch error, recoverable backend hiccup).  Classified by
    :func:`classify_failure`; retried in place with exponential backoff +
    jitter (:class:`RetryPolicy`) before escalating to quarantine.
  * **fatal** — the replica itself is suspect (anything not transient).
    Quarantined immediately; in-flight requests requeue onto survivors.
  * **hang** — a step that never (or too slowly) returns.  Detected by a
    per-step watchdog deadline built on ``HeartbeatMonitor`` from
    ``repro.runtime.fault_tolerance``: a replica checks in immediately
    before each step attempt, and the dead-host verdict is taken right
    after the attempt returns — so a step that consumed more than
    ``watchdog_s`` of router-clock time is declared hung and quarantined
    (:class:`ReplicaHungError`), per replica, without one stall staling
    out the beats of replicas stepped earlier in the same sweep.  With
    an injectable clock this is deterministic on CPU.

Quarantined replicas are not dead forever: :class:`ClusterHealth`
schedules periodic health probes (a canary generate through a fresh
engine from the replica's ``factory`` — a warm restart).  ``N``
consecutive probe passes re-admit the replica with that fresh engine;
``max_probes`` consecutive failures retire it permanently so drivers
terminate instead of probing a corpse forever.

``StragglerDetector`` (same module) optionally quarantines replicas that
are consistently ``straggler_factor``x slower than the per-step median —
at scale a straggling replica drags p99 TTFT for every request routed to
it, so it takes the same quarantine -> probe -> re-admit path as a fault.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import numpy as np

from repro.runtime.fault_tolerance import HeartbeatMonitor, StragglerDetector

TRANSIENT, FATAL = "transient", "fatal"


class TransientError(RuntimeError):
    """A step failure presumed not to implicate the replica itself.

    Any exception type carrying a truthy ``transient`` attribute is
    classified the same way, so backends can tag their own recoverable
    errors without importing the serving layer.
    """
    transient = True


class FatalError(RuntimeError):
    """A step failure that condemns the replica (quarantine, no retry)."""
    transient = False


class ReplicaHungError(FatalError):
    """A replica step exceeded the watchdog deadline."""


class ReplicaStragglerError(FatalError):
    """A replica was consistently slower than factor x the step median."""


def classify_failure(exc: BaseException) -> str:
    """``"transient"`` or ``"fatal"`` for a replica step failure.

    Transient iff the exception (or any in its ``__cause__`` chain)
    carries a truthy ``transient`` attribute; everything else — including
    garden-variety ``RuntimeError`` from a genuinely broken replica — is
    fatal.  Unknown failures defaulting to fatal is deliberate: wrongly
    retrying a corrupt replica duplicates work, wrongly quarantining a
    healthy one only costs a probe round-trip.
    """
    seen = 0
    while exc is not None and seen < 8:
        if getattr(exc, "transient", False):
            return TRANSIENT
        exc = exc.__cause__
        seen += 1
    return FATAL


@dataclasses.dataclass
class RetryPolicy:
    """Bounded in-place retry with exponential backoff + seeded jitter.

    ``backoff(attempt)`` (attempt is 1-based) returns
    ``min(backoff_s * mult**(attempt-1), max_backoff_s)`` scaled by a
    uniform jitter in ``[1-jitter, 1+jitter]`` — jitter decorrelates
    replica retries so a cluster-wide transient doesn't produce a
    synchronized retry stampede.  The jitter stream is seeded, so a fixed
    seed gives a reproducible backoff schedule in tests and CI.
    """
    max_retries: int = 2
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    max_backoff_s: float = 2.0
    jitter: float = 0.1
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def backoff(self, attempt: int) -> float:
        base = min(self.backoff_s * self.backoff_mult ** max(0, attempt - 1),
                   self.max_backoff_s)
        if not self.jitter:
            return base
        return base * (1.0 + self.jitter * float(self._rng.uniform(-1, 1)))


@dataclasses.dataclass
class HealthConfig:
    """Knobs for the quarantine -> probe -> re-admission lifecycle.

    ``watchdog_s`` arms the per-step hang watchdog (None disables).
    ``probe_interval_s`` spaces health probes on the router clock;
    ``probes_to_readmit`` consecutive canary passes re-admit a replica
    with the freshly-restarted engine; ``max_probes`` consecutive
    failures retire it permanently (None probes forever — only safe with
    real traffic deadlines).  The canary is a single greedy generate
    (``canary_prompt`` -> ``canary_tokens`` tokens) occupying one slot of
    the restarted engine's pool.  ``straggler_factor``/``patience``
    enable the straggler detector (None disables).
    """
    probe_interval_s: float = 1.0
    probes_to_readmit: int = 2
    max_probes: Optional[int] = 8
    canary_prompt: Sequence[int] = (1, 2, 3)
    canary_tokens: int = 2
    watchdog_s: Optional[float] = None
    straggler_factor: Optional[float] = None
    straggler_patience: int = 3


@dataclasses.dataclass
class ProbeState:
    """Per-quarantine probe bookkeeping for one replica."""
    next_at: float
    passes: int = 0
    probes_run: int = 0
    candidate: Any = None     # the warm-restarted engine under evaluation


class ClusterHealth:
    """Replica health tracker for one router.

    Wraps the seed-era fault-tolerance primitives for serving: a
    ``HeartbeatMonitor`` (one host per replica; a beat = "starting a step
    attempt now", so ``hung()`` after the sweep is exactly the per-step
    watchdog) and an optional ``StragglerDetector`` over per-step
    durations.  Probe scheduling is pure bookkeeping — the router owns
    the engines and runs the canaries.
    """

    def __init__(self, names: Sequence[str], cfg: HealthConfig):
        self.cfg = cfg
        self.names = list(names)
        self.index = {n: i for i, n in enumerate(self.names)}
        timeout = cfg.watchdog_s if cfg.watchdog_s is not None \
            else float("inf")
        self.monitor = HeartbeatMonitor(len(self.names), timeout_s=timeout)
        self.straggler = (
            StragglerDetector(len(self.names), factor=cfg.straggler_factor,
                              patience=cfg.straggler_patience)
            if cfg.straggler_factor is not None else None)
        self.probes: dict[str, ProbeState] = {}

    # ---------------- heartbeats / watchdog ----------------

    def beat(self, name: str, now: float, step: int = 0) -> None:
        """Check a replica in: it is alive and starting (or idling past)
        a step at ``now``."""
        self.monitor.beat(self.index[name], step, now=now)

    def hung(self, now: float) -> list[str]:
        """Replicas whose last check-in is older than the watchdog
        deadline — i.e. whose step attempt consumed more than
        ``watchdog_s`` of router-clock time.  (Quarantined replicas stop
        beating, so they linger here until ``on_readmit`` revives them —
        callers filter on replica health.)"""
        return [self.names[i] for i in self.monitor.dead_hosts(now=now)]

    def observe_durations(self, durations: dict[str, float]) -> list[str]:
        """Feed per-replica step durations; returns replicas flagged as
        stragglers (``patience`` consecutive over-threshold steps)."""
        if self.straggler is None or not durations:
            return []
        flagged = self.straggler.observe(
            {self.index[n]: d for n, d in durations.items()})
        return [self.names[i] for i in flagged]

    # ---------------- probe lifecycle ----------------

    def on_quarantine(self, name: str, now: float) -> None:
        self.probes[name] = ProbeState(
            next_at=now + self.cfg.probe_interval_s)

    def due_probes(self, now: float) -> list[str]:
        return [n for n, st in self.probes.items() if now >= st.next_at]

    def record_probe(self, name: str, ok: bool, now: float
                     ) -> Optional[str]:
        """Account one probe result.  Returns ``"readmit"`` when the
        replica has passed ``probes_to_readmit`` consecutive canaries,
        ``"retired"`` when it exhausted ``max_probes``, else None (probe
        again at ``next_at``)."""
        st = self.probes[name]
        st.probes_run += 1
        if ok:
            st.passes += 1
            if st.passes >= self.cfg.probes_to_readmit:
                return "readmit"
        else:
            st.passes = 0
            st.candidate = None   # a failed candidate is discarded
            if (self.cfg.max_probes is not None
                    and st.probes_run >= self.cfg.max_probes):
                self.probes.pop(name, None)
                return "retired"
        st.next_at = now + self.cfg.probe_interval_s
        return None

    def on_readmit(self, name: str, now: float) -> None:
        self.probes.pop(name, None)
        self.beat(name, now)      # revives the heartbeat host

    def is_probing(self, name: str) -> bool:
        return name in self.probes
