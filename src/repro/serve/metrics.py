"""Step-level serving metrics: throughput, slot occupancy, queue depth,
and a time-to-first-token proxy measured in scheduler steps.

All counters are plain host-side ints accumulated by ``ContinuousEngine``;
``snapshot()`` renders the derived rates.  "Steps" are engine steps (one
admission sweep + one batched decode), the natural clock of a
continuous-batching loop — wall time is tracked separately so tokens/s
reflects real cost, including prefill work.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class ServeMetrics:
    steps: int = 0
    prefills: int = 0
    decode_steps: int = 0
    requests_submitted: int = 0
    requests_completed: int = 0
    tokens_generated: int = 0
    # occupancy: occupied-slot decode steps / (n_slots * decode steps)
    slot_steps: int = 0
    slot_capacity_steps: int = 0
    # queue pressure, sampled at the start of each step
    queue_depth_sum: int = 0
    max_queue_depth: int = 0
    # time-to-first-token proxy: steps from submit to first sampled token
    ttft_steps_sum: int = 0
    ttft_count: int = 0
    wall_time_s: float = 0.0

    # ---------------- derived ----------------

    def occupancy(self) -> float:
        if not self.slot_capacity_steps:
            return 0.0
        return self.slot_steps / self.slot_capacity_steps

    def tokens_per_s(self) -> float:
        if self.wall_time_s <= 0:
            return 0.0
        return self.tokens_generated / self.wall_time_s

    def mean_queue_depth(self) -> float:
        return self.queue_depth_sum / self.steps if self.steps else 0.0

    def mean_ttft_steps(self) -> float:
        return (self.ttft_steps_sum / self.ttft_count
                if self.ttft_count else 0.0)

    def snapshot(self) -> dict:
        out = dataclasses.asdict(self)
        out["occupancy"] = self.occupancy()
        out["tokens_per_s"] = self.tokens_per_s()
        out["mean_queue_depth"] = self.mean_queue_depth()
        out["mean_ttft_steps"] = self.mean_ttft_steps()
        return out
