"""Step-level serving metrics: throughput, slot occupancy, queue depth,
time-to-first-token (both a scheduler-step proxy and wall-clock seconds),
and a Prometheus text exposition for scraping.

All counters are plain host-side ints accumulated by ``ContinuousEngine``;
``snapshot()`` renders the derived rates.  "Steps" are engine steps (one
admission sweep + one batched decode), the natural clock of a
continuous-batching loop — wall time is tracked separately so tokens/s
reflects real cost, including prefill work.

For multi-replica serving, ``ClusterMetrics`` carries one ``ServeMetrics``
per replica plus live router gauges (queue depth, free slots, health) and
router-level counters (rejected / shed / timeout / requeued);
``ClusterMetrics.merge`` folds any set of per-replica metrics into one
cluster-wide ``ServeMetrics``, and ``to_prometheus()`` renders everything
as one exposition with a ``replica`` label per sample.

Latency distributions (TTFT, per-token decode latency) accumulate in
bounded-bucket ``LatencyHistogram``s on the engine itself, so percentile
estimates (p50/p99) come from the serving loop's own observations —
``bench_serving`` and the Prometheus exposition read them instead of
recomputing percentiles downstream.  The exposition also appends the
process-wide dispatch telemetry families (``repro_op_dispatch_total``,
``repro_backend_fallbacks_total``, ``repro_tuning_cache_*_total``,
``repro_autotune_*_total``) from :mod:`repro.obs.telemetry`.
"""
from __future__ import annotations

import bisect
import dataclasses

from repro.obs import telemetry as _telemetry

# log-spaced ~0.5ms .. 60s: TTFT and per-token latencies on anything from
# an interpret-mode CPU test to a loaded production replica land inside
DEFAULT_LATENCY_BOUNDS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


@dataclasses.dataclass
class LatencyHistogram:
    """Bounded-bucket latency histogram with quantile estimates.

    ``counts[i]`` holds observations ``<= bounds[i]`` (exclusive of the
    previous bound); the final slot is the +Inf overflow.  ``__add__``
    merges two histograms of the same bounds — which is what lets
    ``ClusterMetrics.merge`` fold per-replica histograms with the same
    generic field-summing loop it uses for plain counters.
    """
    bounds: tuple = DEFAULT_LATENCY_BOUNDS
    counts: list = None
    total_s: float = 0.0
    count: int = 0

    def __post_init__(self):
        if self.counts is None:
            self.counts = [0] * (len(self.bounds) + 1)

    def observe(self, value_s: float, n: int = 1) -> None:
        self.counts[bisect.bisect_left(self.bounds, value_s)] += n
        self.total_s += value_s * n
        self.count += n

    def mean(self) -> float:
        return self.total_s / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Estimated ``q``-quantile (0..1): linear interpolation inside
        the bucket holding the target rank; the overflow bucket reports
        the last bound (a floor, not an estimate)."""
        if not self.count:
            return 0.0
        target = q * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if seen + c >= target:
                if i >= len(self.bounds):
                    return self.bounds[-1]
                lo = self.bounds[i - 1] if i else 0.0
                frac = (target - seen) / c
                return lo + (self.bounds[i] - lo) * min(1.0, max(0.0, frac))
            seen += c
        return self.bounds[-1]

    def __add__(self, other: "LatencyHistogram") -> "LatencyHistogram":
        if self.bounds != other.bounds:
            raise ValueError("cannot merge histograms with different "
                             "bucket bounds")
        return LatencyHistogram(
            bounds=self.bounds,
            counts=[a + b for a, b in zip(self.counts, other.counts)],
            total_s=self.total_s + other.total_s,
            count=self.count + other.count)

    def prometheus_lines(self, name: str, labels: str) -> list[str]:
        """The cumulative ``_bucket``/``_sum``/``_count`` samples of one
        histogram (headers are the caller's job)."""
        lines, cum = [], 0
        for bound, c in zip(self.bounds, self.counts):
            cum += c
            sep = "," if labels else ""
            inner = labels[1:-1] if labels else ""
            lines.append(f'{name}_bucket{{{inner}{sep}le="{bound}"}} {cum}')
        inner = labels[1:-1] if labels else ""
        sep = "," if labels else ""
        lines.append(f'{name}_bucket{{{inner}{sep}le="+Inf"}} {self.count}')
        lines.append(f"{name}_sum{labels} {_prom_value(self.total_s)}")
        lines.append(f"{name}_count{labels} {self.count}")
        return lines


@dataclasses.dataclass
class ServeMetrics:
    steps: int = 0
    prefills: int = 0
    # chunked prefill: individual prompt chunks processed, and running
    # requests preempted to reclaim KV pages (paged pool under pressure)
    prefill_chunks: int = 0
    preemptions: int = 0
    decode_steps: int = 0
    requests_submitted: int = 0
    requests_completed: int = 0
    requests_cancelled: int = 0
    tokens_generated: int = 0
    # occupancy: occupied-slot decode steps / (n_slots * decode steps)
    slot_steps: int = 0
    slot_capacity_steps: int = 0
    # queue pressure, sampled at the start of each step
    queue_depth_sum: int = 0
    max_queue_depth: int = 0
    # time-to-first-token: steps from submit to first sampled token, and
    # the same interval in wall-clock seconds
    ttft_steps_sum: int = 0
    ttft_s_sum: float = 0.0
    ttft_count: int = 0
    wall_time_s: float = 0.0
    # latency distributions, engine-observed: wall-clock TTFT per request
    # and per-token decode-step latency (the batched decode's duration,
    # one observation per active slot)
    ttft_hist: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)
    token_latency_hist: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)

    # ---------------- derived ----------------

    def occupancy(self) -> float:
        if not self.slot_capacity_steps:
            return 0.0
        return self.slot_steps / self.slot_capacity_steps

    def tokens_per_s(self) -> float:
        if self.wall_time_s <= 0:
            return 0.0
        return self.tokens_generated / self.wall_time_s

    def mean_queue_depth(self) -> float:
        return self.queue_depth_sum / self.steps if self.steps else 0.0

    def mean_ttft_steps(self) -> float:
        return (self.ttft_steps_sum / self.ttft_count
                if self.ttft_count else 0.0)

    def mean_ttft_s(self) -> float:
        return (self.ttft_s_sum / self.ttft_count
                if self.ttft_count else 0.0)

    def snapshot(self) -> dict:
        out = dataclasses.asdict(self)
        out["occupancy"] = self.occupancy()
        out["tokens_per_s"] = self.tokens_per_s()
        out["mean_queue_depth"] = self.mean_queue_depth()
        out["mean_ttft_steps"] = self.mean_ttft_steps()
        out["mean_ttft_s"] = self.mean_ttft_s()
        out["ttft_p50_s"] = self.ttft_hist.quantile(0.5)
        out["ttft_p99_s"] = self.ttft_hist.quantile(0.99)
        out["token_latency_p50_s"] = self.token_latency_hist.quantile(0.5)
        out["token_latency_p99_s"] = self.token_latency_hist.quantile(0.99)
        return out

    def to_prometheus(self, labels: dict | None = None) -> str:
        """Prometheus text exposition of this metrics set (one sample per
        family, optionally labelled)."""
        return render_prometheus([(labels or {}, self)])


# ==========================================================================
# Prometheus text exposition
# ==========================================================================

PROM_PREFIX = "repro_serve_"

# (family suffix, prometheus type, help text, extractor)
_PROM_SPEC = (
    ("steps_total", "counter", "Engine steps run.",
     lambda m: m.steps),
    ("prefills_total", "counter", "Per-request prefills run.",
     lambda m: m.prefills),
    ("prefill_chunks_total", "counter",
     "Chunked-prefill prompt chunks processed.",
     lambda m: m.prefill_chunks),
    ("preemptions_total", "counter",
     "Running requests preempted to reclaim KV pages.",
     lambda m: m.preemptions),
    ("decode_steps_total", "counter", "Batched decode steps run.",
     lambda m: m.decode_steps),
    ("requests_submitted_total", "counter", "Requests submitted.",
     lambda m: m.requests_submitted),
    ("requests_completed_total", "counter", "Requests completed.",
     lambda m: m.requests_completed),
    ("requests_cancelled_total", "counter",
     "Requests cancelled mid-flight (slot freed early).",
     lambda m: m.requests_cancelled),
    ("tokens_generated_total", "counter", "Tokens generated.",
     lambda m: m.tokens_generated),
    ("wall_time_seconds_total", "counter",
     "Wall-clock seconds spent inside step().",
     lambda m: m.wall_time_s),
    ("occupancy", "gauge",
     "Occupied-slot fraction of decode capacity.",
     lambda m: m.occupancy()),
    ("tokens_per_second", "gauge", "Generated tokens per wall second.",
     lambda m: m.tokens_per_s()),
    ("queue_depth_mean", "gauge", "Mean waiting-queue depth per step.",
     lambda m: m.mean_queue_depth()),
    ("queue_depth_max", "gauge", "Max waiting-queue depth observed.",
     lambda m: m.max_queue_depth),
    ("ttft_steps_mean", "gauge",
     "Mean time-to-first-token in engine steps.",
     lambda m: m.mean_ttft_steps()),
    ("ttft_seconds_mean", "gauge",
     "Mean wall-clock time-to-first-token in seconds.",
     lambda m: m.mean_ttft_s()),
    ("ttft_seconds_p50", "gauge",
     "Engine-observed wall-clock TTFT p50 estimate (seconds).",
     lambda m: m.ttft_hist.quantile(0.5)),
    ("ttft_seconds_p99", "gauge",
     "Engine-observed wall-clock TTFT p99 estimate (seconds).",
     lambda m: m.ttft_hist.quantile(0.99)),
    ("token_latency_seconds_p50", "gauge",
     "Engine-observed per-token decode latency p50 estimate (seconds).",
     lambda m: m.token_latency_hist.quantile(0.5)),
    ("token_latency_seconds_p99", "gauge",
     "Engine-observed per-token decode latency p99 estimate (seconds).",
     lambda m: m.token_latency_hist.quantile(0.99)),
)

# (family suffix, help, histogram accessor): rendered as native
# Prometheus histograms (_bucket{le=}/_sum/_count) per row
_PROM_HISTOGRAMS = (
    ("ttft_seconds", "Wall-clock time-to-first-token distribution.",
     lambda m: m.ttft_hist),
    ("token_latency_seconds",
     "Per-token decode-step latency distribution.",
     lambda m: m.token_latency_hist),
)


# HELP text for the router-level families rendered via the generic
# ``gauges=`` / ``counters=`` hooks of render_prometheus (families not
# listed fall back to a generic line, so adding a counter in the router
# never breaks the exposition).
_GAUGE_HELP = {
    "queue_depth": "Requests waiting on this replica.",
    "running": "Requests currently decoding on this replica.",
    "slots_free": "Free KV-cache slots on this replica.",
    "healthy": "1 when the replica is serving traffic, 0 quarantined.",
    "probing": "1 while the quarantined replica is under health probes.",
    "kv_occupancy": "Occupied fraction of the replica's KV slots.",
    "kv_page_occupancy":
        "Allocated fraction of the replica's KV page pool.",
    "kv_page_fragmentation":
        "Allocated-but-dead KV fraction (partially filled trailing "
        "pages).",
    "kv_free_pages": "Free KV pages on this replica.",
}
_COUNTER_HELP = {
    "requests_rejected": "Requests rejected at a full backlog.",
    "requests_shed": "Requests shed by priority at a full backlog.",
    "requests_timeout": "Requests expired by their deadline.",
    "requests_requeued": "Requests requeued off a quarantined replica.",
    "requests_degraded":
        "Tier-affinity requests served off-tier (tier had no healthy "
        "replica).",
    "retries": "Transient step failures retried in place with backoff.",
    "replicas_quarantined": "Replica quarantine events.",
    "replicas_readmitted":
        "Replicas re-admitted after passing health probes.",
    "probes": "Health probes run against quarantined replicas.",
    "probe_failures": "Health probes that failed.",
}


def _prom_value(v) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) else repr(f)


def _prom_labels(labels: dict) -> str:
    if not labels:
        return ""
    esc = {k: str(v).replace("\\", r"\\").replace('"', r'\"')
           .replace("\n", r"\n") for k, v in labels.items()}
    return "{" + ",".join(f'{k}="{v}"' for k, v in esc.items()) + "}"


def render_prometheus(rows, *, gauges=None, counters=None,
                      dispatch_telemetry: bool = True) -> str:
    """Render ``rows`` of ``(labels, ServeMetrics)`` as one exposition.

    Each family gets its HELP/TYPE header once, then one sample per row.
    ``gauges`` adds extra per-row gauge families as
    ``{family: [(labels, value), ...]}``; ``counters`` adds unlabelled
    top-level counters as ``{family: value}`` (router-level totals).
    ``dispatch_telemetry`` appends the process-wide dispatch/autotune
    counter families from :mod:`repro.obs.telemetry` (they are
    per-process, not per-replica, so they render once, unlabelled).
    """
    lines = []
    for suffix, ptype, help_, extract in _PROM_SPEC:
        name = PROM_PREFIX + suffix
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} {ptype}")
        for labels, m in rows:
            lines.append(
                f"{name}{_prom_labels(labels)} {_prom_value(extract(m))}")
    for suffix, help_, extract in _PROM_HISTOGRAMS:
        name = PROM_PREFIX + suffix
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} histogram")
        for labels, m in rows:
            lines.extend(extract(m).prometheus_lines(
                name, _prom_labels(labels)))
    for family in sorted(gauges or ()):
        name = PROM_PREFIX + family
        help_ = _GAUGE_HELP.get(family, "Live gauge exported by the router.")
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} gauge")
        for labels, value in gauges[family]:
            lines.append(f"{name}{_prom_labels(labels)} "
                         f"{_prom_value(value)}")
    for family in sorted(counters or ()):
        name = PROM_PREFIX + family + "_total"
        help_ = _COUNTER_HELP.get(family, "Router-level counter.")
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} counter")
        lines.append(f"{name} {_prom_value(counters[family])}")
    if dispatch_telemetry:
        lines.extend(_telemetry.prometheus_lines())
    return "\n".join(lines) + "\n"


# ==========================================================================
# cluster aggregation
# ==========================================================================

@dataclasses.dataclass
class ClusterMetrics:
    """Per-replica metrics plus router-level state, as one exposition.

    ``replicas`` maps replica name -> its live ``ServeMetrics``;
    ``gauges`` maps replica name -> instantaneous router-side gauges
    (``queue_depth``, ``running``, ``slots_free``, ``healthy``);
    ``counters`` holds router-level admission/fault totals.
    """

    replicas: dict
    gauges: dict = dataclasses.field(default_factory=dict)
    counters: dict = dataclasses.field(default_factory=dict)

    @staticmethod
    def merge(metrics) -> ServeMetrics:
        """Fold an iterable of ``ServeMetrics`` into one cluster-wide set:
        counters sum; ``max_queue_depth`` takes the max (a max over
        replicas is still a max); derived rates then fall out of the sums
        (cluster occupancy weights each replica by its decode capacity)."""
        out = ServeMetrics()
        for m in metrics:
            for f in dataclasses.fields(ServeMetrics):
                if f.name == "max_queue_depth":
                    out.max_queue_depth = max(out.max_queue_depth,
                                              m.max_queue_depth)
                else:
                    setattr(out, f.name,
                            getattr(out, f.name) + getattr(m, f.name))
        return out

    def aggregate(self) -> ServeMetrics:
        return self.merge(self.replicas.values())

    def to_prometheus(self) -> str:
        """One exposition: every ``ServeMetrics`` family sampled per
        replica (``replica="<name>"``), the live router gauges per
        replica, and the router-level totals."""
        rows = [({"replica": name}, m)
                for name, m in sorted(self.replicas.items())]
        gauges: dict = {}
        for name in sorted(self.gauges):
            for family, value in self.gauges[name].items():
                gauges.setdefault(family, []).append(
                    ({"replica": name}, value))
        return render_prometheus(rows, gauges=gauges,
                                 counters=self.counters)
