"""Minimal stdlib HTTP shim over the async serving front-end.

No web framework: ``http.server.ThreadingHTTPServer`` +
``json`` over the existing ``AsyncFrontend``.  Two endpoints:

  * ``POST /generate`` — JSON body ``{"prompt": [ids...], "max_tokens":
    N, ...}`` (see :func:`request_from_payload` for the accepted
    fields); blocks until the request reaches a terminal status and
    returns ``{"status", "tokens", "finish_reason", "ttft_s",
    "request_id"}``.
  * ``GET /metrics`` — the cluster's Prometheus exposition (content
    type ``text/plain; version=0.0.4``), including the dispatch
    telemetry and latency-histogram families from ``repro.obs``.

``HttpFrontend`` owns the plumbing: a daemon thread runs an asyncio
loop hosting the ``AsyncFrontend``; HTTP handler threads hop onto that
loop with ``asyncio.run_coroutine_threadsafe``.  The router is still
only ever touched by the frontend's single background task, so the
no-locking invariant holds no matter how many HTTP clients connect.

Run a toy server::

    PYTHONPATH=src python -m repro.serve.http --arch smollm-135m \
        --reduced --interpret --port 8080
"""
from __future__ import annotations

import asyncio
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.serve.cluster import EngineRouter
from repro.serve.frontend import AsyncFrontend, RequestResult
from repro.serve.scheduler import Request

_REQUEST_FIELDS = ("prompt", "max_tokens", "temperature", "top_k",
                   "stop_tokens", "priority", "tier", "deadline_s")


def request_from_payload(payload: dict) -> tuple[Request, Optional[str],
                                                 Optional[float]]:
    """Validate a ``/generate`` JSON body into ``(Request, tier,
    deadline_s)``; raises ``ValueError`` with a client-safe message."""
    if not isinstance(payload, dict):
        raise ValueError("body must be a JSON object")
    unknown = set(payload) - set(_REQUEST_FIELDS)
    if unknown:
        raise ValueError(f"unknown fields: {sorted(unknown)}")
    prompt = payload.get("prompt")
    if (not isinstance(prompt, list) or not prompt
            or not all(isinstance(t, int) for t in prompt)):
        raise ValueError("prompt must be a non-empty list of token ids")
    max_tokens = payload.get("max_tokens", 16)
    if not isinstance(max_tokens, int) or max_tokens < 1:
        raise ValueError("max_tokens must be a positive integer")
    stop = payload.get("stop_tokens")
    if stop is not None and (not isinstance(stop, list) or
                             not all(isinstance(t, int) for t in stop)):
        raise ValueError("stop_tokens must be a list of token ids")
    tier = payload.get("tier")
    if tier is not None and not isinstance(tier, str):
        raise ValueError("tier must be a string")
    deadline_s = payload.get("deadline_s")
    if deadline_s is not None and not isinstance(deadline_s, (int, float)):
        raise ValueError("deadline_s must be a number")
    req = Request(prompt=list(prompt), max_tokens=max_tokens,
                  temperature=float(payload.get("temperature", 0.0)),
                  top_k=int(payload.get("top_k", 0)),
                  stop_tokens=None if stop is None else tuple(stop),
                  priority=float(payload.get("priority", 0.0)))
    return req, tier, deadline_s


class _Handler(BaseHTTPRequestHandler):
    # the server instance carries the HttpFrontend (see _Server below)
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):   # quiet by default
        if self.server.hf.verbose:
            super().log_message(fmt, *args)

    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, code: int, obj: dict) -> None:
        self._send(code, json.dumps(obj).encode(), "application/json")

    def do_GET(self):
        if self.path.split("?")[0] != "/metrics":
            self._send_json(404, {"error": f"no route {self.path!r}"})
            return
        text = self.server.hf.router.metrics().to_prometheus()
        self._send(200, text.encode(),
                   "text/plain; version=0.0.4; charset=utf-8")

    def do_POST(self):
        if self.path.split("?")[0] != "/generate":
            self._send_json(404, {"error": f"no route {self.path!r}"})
            return
        try:
            n = int(self.headers.get("Content-Length", 0))
            payload = json.loads(self.rfile.read(n) or b"{}")
            req, tier, deadline_s = request_from_payload(payload)
        except (ValueError, json.JSONDecodeError) as exc:
            self._send_json(400, {"error": str(exc)})
            return
        try:
            rid, result = self.server.hf.generate(req, tier=tier,
                                                  deadline_s=deadline_s)
        except RuntimeError as exc:
            self._send_json(503, {"error": str(exc)})
            return
        ticket = self.server.hf.router.tickets.get(rid)
        self._send_json(200, {
            "status": result.status,
            "tokens": result.tokens,
            "finish_reason": result.finish_reason,
            "ttft_s": ticket.ttft_s if ticket is not None else None,
            "request_id": rid,
        })


class _Server(ThreadingHTTPServer):
    daemon_threads = True

    def __init__(self, addr, hf: "HttpFrontend"):
        self.hf = hf
        super().__init__(addr, _Handler)


class HttpFrontend:
    """Serve an ``EngineRouter`` over HTTP; see the module docstring.

    ``start()`` spins up (1) a daemon thread running an asyncio loop
    that hosts the ``AsyncFrontend`` and (2) the threading HTTP server;
    ``stop()`` drains and tears both down.  ``port=0`` binds an
    ephemeral port (read it back from ``.port`` — handy for tests).
    """

    def __init__(self, router: EngineRouter, *, host: str = "127.0.0.1",
                 port: int = 0, verbose: bool = False):
        self.router = router
        self.host = host
        self.port = port
        self.verbose = verbose
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._frontend: Optional[AsyncFrontend] = None
        self._loop_thread: Optional[threading.Thread] = None
        self._httpd: Optional[_Server] = None
        self._http_thread: Optional[threading.Thread] = None

    # ---------------- lifecycle ----------------

    def start(self) -> "HttpFrontend":
        if self._loop is not None:
            raise RuntimeError("already started")
        started = threading.Event()

        def run_loop():
            loop = asyncio.new_event_loop()
            asyncio.set_event_loop(loop)
            self._loop = loop
            started.set()
            loop.run_forever()
            loop.close()

        self._loop_thread = threading.Thread(target=run_loop,
                                             name="http-frontend-loop",
                                             daemon=True)
        self._loop_thread.start()
        started.wait()

        async def boot():
            fe = AsyncFrontend(self.router)
            await fe.start()
            return fe

        self._frontend = asyncio.run_coroutine_threadsafe(
            boot(), self._loop).result()
        self._httpd = _Server((self.host, self.port), self)
        self.port = self._httpd.server_address[1]
        self._http_thread = threading.Thread(
            target=self._httpd.serve_forever, name="http-frontend-server",
            daemon=True)
        self._http_thread.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._frontend is not None and self._loop is not None:
            asyncio.run_coroutine_threadsafe(
                self._frontend.stop(drain=drain), self._loop).result()
            self._frontend = None
        if self._loop is not None:
            self._loop.call_soon_threadsafe(self._loop.stop)
            self._loop_thread.join()
            self._loop = None
            self._loop_thread = None

    def __enter__(self) -> "HttpFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # ---------------- request bridge ----------------

    def generate(self, request: Request, *, tier: str | None = None,
                 deadline_s: float | None = None
                 ) -> tuple[int, RequestResult]:
        """Submit and block until terminal (handler-thread entry point)."""
        if self._loop is None or self._frontend is None:
            raise RuntimeError("frontend is not running")

        async def run():
            handle = await self._frontend.submit(request, tier=tier,
                                                 deadline_s=deadline_s)
            result = await handle
            return handle.request_id, result

        return asyncio.run_coroutine_threadsafe(run(), self._loop).result()


def main(argv=None) -> None:
    import argparse

    import jax

    from repro import configs
    from repro.models import api as model_api
    from repro.serve.cluster import EngineReplica
    from repro.serve.engine import ContinuousEngine, PoolConfig

    p = argparse.ArgumentParser(
        description="toy HTTP serving front-end (stdlib only)")
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--reduced", action="store_true",
                   help="shrink the config (toy weights)")
    p.add_argument("--interpret", action="store_true",
                   help="pallas interpret mode (no accelerator needed)")
    p.add_argument("--replicas", type=int, default=1)
    p.add_argument("--n-slots", type=int, default=4)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8080)
    p.add_argument("--verbose", action="store_true")
    args = p.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model_api.init_params(jax.random.PRNGKey(0), cfg)
    pool = PoolConfig(n_slots=args.n_slots, max_len=args.max_len)
    replicas = [
        EngineReplica(name=f"r{i}", engine=ContinuousEngine(
            cfg, params, pool, interpret=args.interpret or None))
        for i in range(args.replicas)
    ]
    router = EngineRouter(replicas)
    hf = HttpFrontend(router, host=args.host, port=args.port,
                      verbose=args.verbose)
    hf.start()
    print(f"serving {args.arch} on {hf.url}  "
          f"(POST /generate, GET /metrics; ctrl-c to stop)")
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        pass
    finally:
        hf.stop()


if __name__ == "__main__":
    main()
