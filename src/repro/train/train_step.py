"""The jit-able train step: loss -> grads -> (optional compression /
accumulation) -> AdamW update.

Mixed precision: the fp32 master copy lives in the optimizer state; the
compute-dtype (usually bf16) working params are re-cast from it every step
(cheap, sharded).  Microbatch gradient accumulation loops with ``lax.scan``
so compute overlaps the reduce-scatter XLA schedules across microbatches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import ArchCfg
from repro.core import dispatch
from repro.models import api
from repro.sharding import annotate
from repro.train import optimizer as opt
from repro.train.schedule import warmup_cosine
from repro.distributed.collectives import compress_grads, decompress_grads


def make_train_step(cfg: ArchCfg, ocfg: opt.AdamWCfg, *,
                    microbatches: int = 1, grad_compression: str = "none",
                    backend: str | None = None, blocks_policy=None,
                    accum_dtype=None, mesh=None, axis_specs=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``blocks_policy``/``accum_dtype`` scope the whole step's kernels —
    forward *and* backward: the context wraps the full value_and_grad, so
    the conv dgrad/wgrad duals and the fused flash-attention backward
    (its ``flash_attention_bwd`` tile, resolved at backward trace time)
    tune under the same policy (e.g. ``blocks_policy="autotune"``
    measures every GEMM/conv/attention fwd+bwd tile at first trace;
    ``accum_dtype=jnp.bfloat16`` trades accumulator precision for VMEM
    headroom).

    ``mesh`` makes every block resolution per-shard (tiles are tuned for
    the local problem each device runs, not the global shape — see
    ``repro.sharding.local``); when not given, the mesh the launcher
    installed via ``sharding.annotate.use_rules`` is captured at trace
    time, so the dry-run/production path is mesh-aware without extra
    plumbing.  ``axis_specs`` overrides per-op triple sharding."""

    def loss_of(params, batch):
        return api.loss_fn(params, batch, cfg)

    def train_step(state, batch):
        # Execution configuration scopes through the context (captured
        # when the surrounding jit traces).  It wraps the whole step — not
        # just the loss — so the custom-VJP backward rules (dgrad/wgrad
        # kernels, traced when value_and_grad pulls back cotangents)
        # resolve their block geometry under the same tuned context.
        step_mesh = mesh if mesh is not None else annotate.current_mesh()
        # The span brackets the python-side step: per-call when run
        # eagerly, the (expensive, once) trace when the caller jits —
        # either way the dispatch/autotune events it contains show which
        # kernels this step resolved and how.
        with obs.span("train_step", microbatches=microbatches,
                      compression=grad_compression):
            with dispatch.use(backend=backend, blocks_policy=blocks_policy,
                              accum_dtype=accum_dtype, mesh=step_mesh,
                              axis_specs=axis_specs):
                return _train_step(state, batch)

    def _train_step(state, batch):
        params = opt.cast_params(state["opt"], cfg.dtype)

        if microbatches > 1:
            def micro(acc, mb):
                (_, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return acc, metrics

            split = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics = jax.lax.scan(micro, zeros, split)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)

        if grad_compression != "none":
            grads, scales = compress_grads(grads, kind=grad_compression)
            grads = decompress_grads(grads, scales, kind=grad_compression)

        lr_scale = warmup_cosine(state["opt"]["step"])
        new_opt, opt_metrics = opt.adamw_update(grads, state["opt"], ocfg,
                                                lr_scale)
        metrics = {**metrics, **opt_metrics}
        return {"opt": new_opt}, metrics

    return train_step


def init_state(key, cfg: ArchCfg, ocfg: opt.AdamWCfg):
    params = api.init_params(key, cfg)
    return {"opt": opt.adamw_init(params, ocfg)}


def abstract_state(cfg: ArchCfg, ocfg: opt.AdamWCfg):
    """ShapeDtypeStruct state tree (dry-run: no allocation)."""
    return jax.eval_shape(
        functools.partial(init_state, jax.random.PRNGKey(0), cfg, ocfg))
