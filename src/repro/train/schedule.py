"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 1000, total: int = 100_000,
                  min_ratio: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return warm * cos


def constant(step, *, value: float = 1.0):
    del step
    return value
