"""Optimizers: AdamW (fp32 master + configurable-moment dtype) and SGDM.

Optimizer state is a pytree mirroring params, so the FSDP param shardings
apply verbatim (ZeRO-3: params, grads, and both moments all sharded).
``moment_dtype=bfloat16`` halves optimizer HBM for the 671B-class models
(see DESIGN.md memory budget).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWCfg:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def adamw_init(params, cfg: AdamWCfg):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)  # noqa: E731
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        # fp32 master copy when params train in bf16
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, cfg: AdamWCfg, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    mdt = jnp.dtype(cfg.moment_dtype)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        new_master = master - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master)
        return new_master, m32.astype(mdt), v32.astype(mdt)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_ma = jax.tree.leaves(state["master"])
    out = [upd(g, m, v, ma)
           for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
    new_master = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v, "master": new_master}
    metrics = {"grad_norm": gnorm, "lr": jnp.float32(lr)}
    return new_state, metrics


def cast_params(state, param_dtype) -> Any:
    """Working (compute-dtype) params from the fp32 master copy."""
    dt = jnp.dtype(param_dtype)
    return jax.tree.map(lambda p: p.astype(dt), state["master"])


@dataclasses.dataclass(frozen=True)
class SGDMCfg:
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 0.0
    grad_clip: float = 0.0


def sgdm_init(params, cfg: SGDMCfg):
    return {"step": jnp.zeros((), jnp.int32),
            "mom": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                params)}


def sgdm_update(params, grads, state, cfg: SGDMCfg, lr_scale=1.0):
    gnorm = global_norm(grads)
    scale = 1.0
    if cfg.grad_clip:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(p, g, m):
        g32 = g.astype(jnp.float32) * scale + cfg.weight_decay * p.astype(
            jnp.float32)
        m_new = cfg.momentum * m + g32
        return (p.astype(jnp.float32)
                - cfg.lr * lr_scale * m_new).astype(p.dtype), m_new

    flat_p, treedef = jax.tree.flatten(params)
    out = [upd(p, g, m) for p, g, m in zip(
        flat_p, jax.tree.leaves(grads), jax.tree.leaves(state["mom"]))]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_p, {"step": state["step"] + 1, "mom": new_m}, {
        "grad_norm": gnorm}
