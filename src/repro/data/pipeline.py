"""Deterministic synthetic data pipeline: sharded, resumable, prefetched.

Production shape: each host generates only its shard of the global batch
(`host_slice`), the stream is a pure function of (seed, step) so restarts
resume exactly, and a background thread prefetches ahead of the training
loop.  Swap `_synthesize` for a real tokenizer+storage reader to go to
production — the sharding/resume/prefetch contract stays identical.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.configs.base import ArchCfg
from repro.configs.shapes import ShapeCfg


class TokenPipeline:
    def __init__(self, cfg: ArchCfg, shape: ShapeCfg, *, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1, start_step: int = 0,
                 prefetch: int = 2):
        assert shape.global_batch % n_hosts == 0
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = shape.global_batch // n_hosts
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # --- deterministic generation ------------------------------------
    def _batch_at(self, step: int) -> dict:
        from repro.models.api import token_len, is_encdec, encdec_src_len
        tl = token_len(self.cfg, self.shape)
        rng = np.random.default_rng(
            (self.seed, step, self.host_id))
        # zipf-ish token distribution; labels = next token
        toks = rng.zipf(1.3, size=(self.local_batch, tl + 1))
        toks = np.minimum(toks - 1, self.cfg.vocab - 1).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.n_patches:
            batch["patch_embeds"] = rng.standard_normal(
                (self.local_batch, self.cfg.n_patches, self.cfg.d_model),
                dtype=np.float32)
        if is_encdec(self.cfg):
            batch["src_embeds"] = rng.standard_normal(
                (self.local_batch, encdec_src_len(self.cfg, self.shape),
                 self.cfg.d_model), dtype=np.float32)
        return batch

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self._q.get()
        self._step = step + 1
        return batch

    def __iter__(self):
        return self

    @property
    def step(self) -> int:
        return self._step

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
