"""Thread-safe ring-buffer tracer: nested spans + structured events.

The tracer is the storage and recording half of ``repro.obs``; activation
(global install, ``repro.use(tracer=...)``) lives in the package
``__init__``.  Design constraints, in order:

  * **Near-zero disabled cost.**  Hot paths guard with
    ``tr = obs.current_tracer()`` — one module-level bool check when no
    tracer is active — and the public ``obs.span()`` helper returns a
    shared no-op singleton, so tracing off means no allocation and no
    lock traffic on the serving/dispatch fast paths.
  * **Thread safety without a hot lock.**  Completed records land in a
    ``collections.deque(maxlen=capacity)`` (appends are atomic under the
    GIL), and the *open*-span stack is ``threading.local`` — each thread
    nests independently, so the frontend's executor thread and the event
    loop never contend or cross-parent.
  * **Injectable clock.**  ``Tracer(clock=...)`` defaults to
    ``time.perf_counter`` — the same clock the serve scheduler stamps
    ``submit_time``/``first_token_time`` with, so per-request span trees
    telescope exactly against the engine's own TTFT accounting; tests
    inject a fake clock for deterministic durations.

Spans record on *completion* (children before parents in the buffer);
synthetic spans for intervals that outlive any ``with`` block — e.g. a
request's life across many engine steps — are added after the fact with
:meth:`Tracer.add_span` from already-captured timestamps.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Optional

DEFAULT_CAPACITY = 65536


@dataclasses.dataclass
class SpanRecord:
    """One completed (or synthetic) span."""
    name: str
    t0: float
    t1: float
    span_id: int
    parent_id: Optional[int]
    thread: int
    attrs: dict

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass
class EventRecord:
    """One instant event, optionally parented to the span it fired in."""
    name: str
    t: float
    span_id: Optional[int]
    thread: int
    attrs: dict


class Span:
    """A live span; use as a context manager.  ``set(**attrs)`` attaches
    attributes (inside or after the ``with`` block — the record holds a
    reference to the same dict), ``event()`` fires an instant event
    parented here."""

    __slots__ = ("_tracer", "name", "attrs", "span_id", "parent_id",
                 "t0", "t1")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id: Optional[int] = None
        self.parent_id: Optional[int] = None
        self.t0: Optional[float] = None
        self.t1: Optional[float] = None

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def event(self, name: str, **attrs) -> None:
        self._tracer.event(name, **attrs)

    def __enter__(self) -> "Span":
        tr = self._tracer
        stack = tr._stack()
        self.span_id = next(tr._ids)
        self.parent_id = stack[-1].span_id if stack else None
        stack.append(self)
        self.t0 = tr.clock()
        return self

    def __exit__(self, *exc) -> None:
        tr = self._tracer
        self.t1 = tr.clock()
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:          # mis-nested exit: drop through to us
            while stack and stack[-1] is not self:
                stack.pop()
            stack.pop()
        tr._records.append(SpanRecord(
            name=self.name, t0=self.t0, t1=self.t1, span_id=self.span_id,
            parent_id=self.parent_id, thread=threading.get_ident(),
            attrs=self.attrs))


class _NullSpan:
    """Shared no-op span: the disabled fast path allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def set(self, **attrs) -> "_NullSpan":
        return self

    def event(self, name: str, **attrs) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Ring-buffer span/event recorder; see the module docstring.

    ``capacity`` bounds memory: the oldest completed records fall off.
    ``clock`` is any zero-arg monotonic-seconds callable.
    """

    def __init__(self, *, capacity: int = DEFAULT_CAPACITY,
                 clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.capacity = capacity
        self._records: collections.deque = collections.deque(
            maxlen=capacity)
        self._ids = itertools.count(1)
        self._local = threading.local()

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    # ---------------- recording ----------------

    def span(self, name: str, **attrs) -> Span:
        """A new span; enter it (``with tracer.span("prefill"): ...``)."""
        return Span(self, name, attrs)

    def event(self, name: str, **attrs) -> EventRecord:
        """Record an instant event, parented to the open span (if any)."""
        stack = self._stack()
        rec = EventRecord(
            name=name, t=self.clock(),
            span_id=stack[-1].span_id if stack else None,
            thread=threading.get_ident(), attrs=attrs)
        self._records.append(rec)
        return rec

    def annotate(self, **attrs) -> None:
        """Attach attributes to the innermost open span (no-op outside)."""
        stack = self._stack()
        if stack:
            stack[-1].attrs.update(attrs)

    def add_span(self, name: str, t0: float, t1: float, *,
                 parent_id: Optional[int] = None, **attrs) -> SpanRecord:
        """Record a synthetic span from captured timestamps — for
        intervals no ``with`` block can cover (a request's life across
        many engine steps).  Timestamps must come from this tracer's
        ``clock`` domain."""
        rec = SpanRecord(
            name=name, t0=float(t0), t1=float(t1), span_id=next(self._ids),
            parent_id=parent_id, thread=threading.get_ident(), attrs=attrs)
        self._records.append(rec)
        return rec

    # ---------------- introspection ----------------

    def records(self) -> list:
        """All records (spans + events) in completion order."""
        return list(self._records)

    def spans(self, name: str | None = None) -> list:
        out = [r for r in self._records if isinstance(r, SpanRecord)]
        if name is not None:
            out = [r for r in out if r.name == name]
        return out

    def events(self, name: str | None = None) -> list:
        out = [r for r in self._records if isinstance(r, EventRecord)]
        if name is not None:
            out = [r for r in out if r.name == name]
        return out

    def clear(self) -> None:
        self._records.clear()

    def summary(self) -> dict:
        """Per-span-name aggregates: ``{name: {count, total_s, mean_s,
        max_s}}``, sorted by total time descending."""
        agg: dict[str, list] = {}
        for r in self.spans():
            agg.setdefault(r.name, []).append(r.duration_s)
        out = {}
        for name, ds in sorted(agg.items(),
                               key=lambda kv: -sum(kv[1])):
            out[name] = {"count": len(ds), "total_s": sum(ds),
                         "mean_s": sum(ds) / len(ds), "max_s": max(ds)}
        return out
