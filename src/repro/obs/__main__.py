"""``python -m repro.obs summarize trace.json``: terminal summary of an
exported Chrome trace (per-span totals, instant-event counts)."""
from __future__ import annotations

import argparse
from typing import Sequence

from repro.obs import chrome


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("summarize",
                       help="per-name aggregate table of a Chrome trace")
    p.add_argument("path", help="Chrome trace-event JSON file "
                                "(obs.export_chrome output)")
    args = ap.parse_args(argv)
    trace = chrome.load(args.path)
    n = chrome.validate(trace)
    print(f"{args.path}: {n} events")
    print(chrome.summarize(trace))


if __name__ == "__main__":
    main()
