"""Chrome trace-event JSON export: any tracer buffer, Perfetto-loadable.

The JSON Array/Object format ``chrome://tracing`` and Perfetto ingest —
complete events (``"ph": "X"``) for spans, instant events (``"ph": "i"``)
for events, timestamps/durations in microseconds relative to the earliest
record, one ``tid`` per recording thread.  ``validate()`` round-trips the
schema (what the CI trace smoke asserts); ``summarize()`` renders the
per-name terminal table behind ``python -m repro.obs summarize``.
"""
from __future__ import annotations

import json

from repro.obs.tracer import EventRecord, SpanRecord, Tracer


def to_chrome(records, *, pid: int = 1) -> dict:
    """Render an iterable of Span/Event records as a Chrome trace dict."""
    records = list(records)
    t_base = min((r.t0 if isinstance(r, SpanRecord) else r.t
                  for r in records), default=0.0)
    # compact per-thread tids (0, 1, ...) in order of first appearance
    tids: dict[int, int] = {}
    events = []
    for r in records:
        tid = tids.setdefault(r.thread, len(tids))
        if isinstance(r, SpanRecord):
            events.append({
                "name": r.name, "ph": "X", "pid": pid, "tid": tid,
                "ts": (r.t0 - t_base) * 1e6,
                "dur": max(0.0, (r.t1 - r.t0) * 1e6),
                "args": dict(r.attrs, span_id=r.span_id,
                             parent_id=r.parent_id),
            })
        elif isinstance(r, EventRecord):
            events.append({
                "name": r.name, "ph": "i", "s": "t", "pid": pid,
                "tid": tid, "ts": (r.t - t_base) * 1e6,
                "args": dict(r.attrs, span_id=r.span_id),
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_chrome(tracer: Tracer, path: str) -> int:
    """Write the tracer's buffer as Chrome trace JSON; returns the event
    count."""
    trace = to_chrome(tracer.records())
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)
    return len(trace["traceEvents"])


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def validate(trace: dict) -> int:
    """Schema-check a Chrome trace dict; returns the event count, raises
    ``ValueError`` with the first offense otherwise."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("not a Chrome trace: missing 'traceEvents'")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' is not a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"traceEvents[{i}] missing {field!r}")
        if ev["ph"] not in ("X", "i", "B", "E", "M"):
            raise ValueError(
                f"traceEvents[{i}] has unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"traceEvents[{i}].ts is not a number")
        if ev["ph"] == "X" and not isinstance(ev.get("dur"),
                                              (int, float)):
            raise ValueError(f"traceEvents[{i}] ('X') missing numeric dur")
    return len(events)


def summarize(trace: dict) -> str:
    """Per-name aggregate table of a loaded Chrome trace (complete events
    by total time descending, then instant-event counts)."""
    validate(trace)
    spans: dict[str, list] = {}
    instants: dict[str, int] = {}
    for ev in trace["traceEvents"]:
        if ev["ph"] == "X":
            spans.setdefault(ev["name"], []).append(ev["dur"])
        elif ev["ph"] == "i":
            instants[ev["name"]] = instants.get(ev["name"], 0) + 1
    lines = [f"{'span':<28}{'count':>7}{'total_ms':>12}"
             f"{'mean_us':>12}{'max_us':>12}"]
    for name, ds in sorted(spans.items(), key=lambda kv: -sum(kv[1])):
        lines.append(f"{name:<28}{len(ds):>7}{sum(ds) / 1e3:>12.3f}"
                     f"{sum(ds) / len(ds):>12.1f}{max(ds):>12.1f}")
    if instants:
        lines.append("")
        lines.append(f"{'event':<28}{'count':>7}")
        for name, n in sorted(instants.items(), key=lambda kv: -kv[1]):
            lines.append(f"{name:<28}{n:>7}")
    return "\n".join(lines)
