"""Unified dispatch/autotune telemetry: one process-wide counter store.

Every dispatch resolution, tuning-cache lookup, and autotune search
increments counters here — the single source of truth behind
``repro.core.autotune.STATS`` (a property proxy over :data:`TELEMETRY`),
the autotune CLI's cache-hit report, and the Prometheus families the
serving exposition exports (:func:`prometheus_lines`):

    repro_op_dispatch_total{op,backend}    resolutions by chosen backend
    repro_backend_fallbacks_total{reason}  unavailable-backend fallbacks
    repro_tuning_cache_hits_total          resolve_blocks memo hits
    repro_tuning_cache_misses_total        resolve_blocks policy runs
    repro_blocks_source_total{source}      where each blocks pick came from
    repro_autotune_{searches,measured,failed,seeded}_total

Counters are ints behind one lock — cheap relative to any dispatch (a
``resolve`` call inspects context stacks and registry predicates), and
always-on: unlike spans they cost no memory growth, so the Prometheus
exposition is populated whether or not a tracer is installed.
"""
from __future__ import annotations

import threading


class DispatchTelemetry:
    """Process-wide counters; see the module docstring."""

    def __init__(self):
        self._lock = threading.Lock()
        self.op_dispatch: dict[tuple, int] = {}     # (op, backend) -> n
        self.fallbacks: dict[str, int] = {}         # reason -> n
        self.blocks_source: dict[str, int] = {}     # source -> n
        self.cache_hits = 0
        self.cache_misses = 0
        self.autotune = {"searches": 0, "measured": 0, "failed": 0,
                         "seeded": 0}

    # ---------------- recording ----------------

    def record_dispatch(self, op: str, backend: str,
                        fallback_from: str | None = None) -> None:
        with self._lock:
            key = (op, backend)
            self.op_dispatch[key] = self.op_dispatch.get(key, 0) + 1
            if fallback_from is not None:
                reason = f"{fallback_from}_unavailable"
                self.fallbacks[reason] = self.fallbacks.get(reason, 0) + 1

    def record_blocks(self, source: str) -> None:
        """One ``resolve_blocks`` outcome: ``"cache-hit"`` or the policy
        source that produced a fresh entry."""
        with self._lock:
            self.blocks_source[source] = \
                self.blocks_source.get(source, 0) + 1
            if source == "cache-hit":
                self.cache_hits += 1
            else:
                self.cache_misses += 1

    def bump_autotune(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.autotune[name] += n

    def set_autotune(self, name: str, value: int) -> None:
        if name not in self.autotune:
            raise KeyError(name)
        with self._lock:
            self.autotune[name] = int(value)

    # ---------------- introspection ----------------

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "op_dispatch": dict(self.op_dispatch),
                "fallbacks": dict(self.fallbacks),
                "blocks_source": dict(self.blocks_source),
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "autotune": dict(self.autotune),
            }

    def reset(self) -> None:
        with self._lock:
            self.op_dispatch.clear()
            self.fallbacks.clear()
            self.blocks_source.clear()
            self.cache_hits = self.cache_misses = 0
            for key in self.autotune:
                self.autotune[key] = 0


TELEMETRY = DispatchTelemetry()


def prometheus_lines(prefix: str = "repro_") -> list[str]:
    """The telemetry counters as Prometheus exposition lines.

    Family HELP/TYPE headers are always emitted (scrapers see stable
    families from the first scrape); labelled families with no samples
    yet contribute headers only.
    """
    snap = TELEMETRY.snapshot()
    lines = []

    def family(name, help_, samples):
        lines.append(f"# HELP {prefix}{name} {help_}")
        lines.append(f"# TYPE {prefix}{name} counter")
        for labels, value in samples:
            lines.append(f"{prefix}{name}{labels} {value}")

    family("op_dispatch_total",
           "Dispatch resolutions by op and chosen backend.",
           [(f'{{op="{op}",backend="{b}"}}', n)
            for (op, b), n in sorted(snap["op_dispatch"].items())])
    family("backend_fallbacks_total",
           "Backend resolutions that fell back (requested tier "
           "unavailable), by reason.",
           [(f'{{reason="{r}"}}', n)
            for r, n in sorted(snap["fallbacks"].items())])
    family("tuning_cache_hits_total",
           "resolve_blocks lookups served from the tuning cache.",
           [("", snap["cache_hits"])])
    family("tuning_cache_misses_total",
           "resolve_blocks lookups that ran a block policy.",
           [("", snap["cache_misses"])])
    family("blocks_source_total",
           "Block geometry picks by source (cache-hit / heuristic / "
           "autotune-measured / autotune-seeded / custom).",
           [(f'{{source="{s}"}}', n)
            for s, n in sorted(snap["blocks_source"].items())])
    auto_help = {
        "searches": "Autotune searches run (cache misses that measured).",
        "measured": "Autotune candidate tiles measured.",
        "failed": "Autotune candidate measurements that raised.",
        "seeded": "Autotune searches seeded from a tuned neighbor.",
    }
    for key in ("searches", "measured", "failed", "seeded"):
        family(f"autotune_{key}_total", auto_help[key],
               [("", snap["autotune"][key])])
    return lines
