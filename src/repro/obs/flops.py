"""FLOP/byte accounting from problem shapes: the roofline substrate.

Costs derive from each op's canonical tuning triple — the same (m, n, k)
``resolve_blocks`` keys its cache with — so dispatch can stamp every
span/event with the work it represents and benchmarks can report
achieved GFLOP/s against arithmetic intensity without knowing op
internals:

  matmul              2·m·n·k FLOPs over an (m,k)x(k,n) GEMM
  brgemm / batched    2·m·n·k per batch element (``batch=`` scales)
  conv2d              2·q·k·(c·r·s) per output row of q pixels
                      (geometry carries stride/r/s; 1x1 stride-1 without)
  flash_attention     4·tq·tk·d  (QK^T + PV, softmax folded out)
  flash_attention_bwd 10·tq·tk·d (recompute + dQ/dK/dV/dP GEMMs)

Bytes are the minimal stream: inputs once + outputs once at the given
storage dtypes; a ``quant`` spec prices int8/fp8 operand storage (the
whole point of the quantized building block is the byte column).  These
are *arithmetic* costs — cache-resident reuse makes real traffic lower —
so the intensity is an upper bound on bytes, i.e. a lower bound on
attainable intensity, the standard roofline x-axis.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OpCost:
    flops: float
    bytes: float

    @property
    def intensity(self) -> float:
        """Arithmetic intensity, FLOPs per byte."""
        return self.flops / self.bytes if self.bytes else 0.0


def _itemsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def _quant_itemsizes(quant, default: int) -> tuple[int, int]:
    """(weight, activation) storage itemsizes under a quant spec (a
    QuantConfig, tag string, or None)."""
    if quant is None:
        return default, default
    tag = quant if isinstance(quant, str) else quant.tag()
    # int8 and fp8 storage are both one byte; unknown tags keep the
    # full-precision pricing rather than guessing
    w = 1 if ("int8" in tag or "fp8" in tag) else default
    return w, w


def op_cost(op: str, m: int, n: int, k: int, dtype, *, geometry=None,
            batch: int = 1, quant=None) -> OpCost:
    """Arithmetic FLOPs and minimal bytes for one execution of ``op`` at
    its canonical triple; see the module docstring for the formulas."""
    isz = _itemsize(dtype)
    w_isz, a_isz = _quant_itemsizes(quant, isz)
    if op in ("matmul", "brgemm", "batched_matmul"):
        flops = 2.0 * m * n * k * batch
        bytes_ = batch * (m * k * a_isz + k * n * w_isz + m * n * 4)
        return OpCost(flops, float(bytes_))
    if op == "conv2d":
        q, c, kk = m, n, k
        stride, r, s = ((geometry.stride, geometry.r, geometry.s)
                        if geometry is not None else (1, 1, 1))
        flops = 2.0 * q * kk * (c * r * s) * batch
        in_row = r * ((q - 1) * stride + s) * c      # input pixels touched
        bytes_ = batch * (in_row * a_isz + r * s * c * kk * w_isz
                          + q * kk * 4)
        return OpCost(flops, float(bytes_))
    if op == "flash_attention":
        tq, tk, d = m, n, k
        flops = 4.0 * tq * tk * d * batch
        bytes_ = batch * ((tq + 2 * tk) * d * a_isz + tq * d * 4)
        return OpCost(flops, float(bytes_))
    if op == "flash_attention_bwd":
        tq, tk, d = m, n, k
        flops = 10.0 * tq * tk * d * batch
        # q/k/v/y/dy in, dq/dk/dv out (+ lse row)
        bytes_ = batch * ((3 * tq + 2 * tk) * d * a_isz
                          + (tq + 2 * tk) * d * 4 + tq * 4)
        return OpCost(flops, float(bytes_))
    raise ValueError(f"no cost model for op {op!r}")
