"""repro.obs: one tracing surface for the one building block.

A low-overhead tracing/profiling layer threading through every level of
the stack — dispatch resolutions, autotune measurements, serve request
lifecycles — plus always-on dispatch telemetry counters
(:mod:`repro.obs.telemetry`), FLOP/byte accounting
(:mod:`repro.obs.flops`), and Chrome trace-event export
(:mod:`repro.obs.chrome`).

Activation (off by default; the disabled fast path is one bool check):

    tracer = obs.Tracer()
    prev = obs.install(tracer)          # global, all threads
    ...
    obs.install(prev)

    with repro.use(tracer=tracer):      # scoped to the context (and the
        ...                             # asyncio tasks it spawns)

Instrumented code guards its hot sites with::

    tr = obs.current_tracer()
    if tr is not None:
        tr.event("resolve_blocks", op=op, ...)

and ``obs.span("name")`` / ``obs.event(...)`` / ``obs.annotate(...)``
are safe to call unconditionally: with no tracer active they return a
shared no-op singleton / do nothing, allocating nothing.

Export any session with ``obs.export_chrome(tracer, "trace.json")`` and
inspect it in Perfetto / ``chrome://tracing`` or via
``python -m repro.obs summarize trace.json``.
"""
from __future__ import annotations

import contextlib
import contextvars
import threading

from repro.obs import chrome, flops, telemetry  # noqa: F401
from repro.obs.chrome import export_chrome, summarize, to_chrome  # noqa: F401
from repro.obs.flops import OpCost, op_cost  # noqa: F401
from repro.obs.telemetry import TELEMETRY  # noqa: F401
from repro.obs.tracer import (  # noqa: F401
    NULL_SPAN,
    EventRecord,
    Span,
    SpanRecord,
    Tracer,
)

# Activation state.  _ENABLED is the one-check disabled fast path: it is
# True iff a global tracer is installed or any scoped activation is live
# anywhere in the process, so the overwhelmingly common "tracing off"
# case pays a single module-global bool read.  The context var carries
# scoped activations (repro.use(tracer=...), executor propagation) and
# wins over the global install.
_GLOBAL: Tracer | None = None
_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_tracer", default=None)
_SCOPED_DEPTH = 0
_ENABLED = False
_STATE_LOCK = threading.Lock()


def _refresh() -> None:
    global _ENABLED
    _ENABLED = _GLOBAL is not None or _SCOPED_DEPTH > 0


def install(tracer: Tracer | None):
    """Install ``tracer`` globally (all threads); returns the previous
    global tracer so callers can restore it.  ``install(None)``
    uninstalls."""
    global _GLOBAL
    with _STATE_LOCK:
        prev, _GLOBAL = _GLOBAL, tracer
        _refresh()
    return prev


def _activate(tracer: Tracer):
    """Scoped activation (context-var): used by ``repro.use(tracer=...)``
    and executor-thread propagation.  Returns a token for
    :func:`_deactivate`."""
    global _SCOPED_DEPTH
    with _STATE_LOCK:
        _SCOPED_DEPTH += 1
        _refresh()
    return _ACTIVE.set(tracer)


def _deactivate(token) -> None:
    global _SCOPED_DEPTH
    _ACTIVE.reset(token)
    with _STATE_LOCK:
        _SCOPED_DEPTH -= 1
        _refresh()


@contextlib.contextmanager
def activate(tracer: Tracer | None):
    """Scope ``tracer`` as the current-context tracer (a thread-level
    ``repro.use(tracer=...)`` without the dispatch context); passing
    None is a no-op scope.  The serve frontend uses this to carry the
    loop's tracer into its executor thread."""
    if tracer is None:
        yield None
        return
    token = _activate(tracer)
    try:
        yield tracer
    finally:
        _deactivate(token)


def current_tracer() -> Tracer | None:
    """The active tracer: scoped activation > global install > None.
    The disabled path is one bool check."""
    if not _ENABLED:
        return None
    return _ACTIVE.get() or _GLOBAL


def span(name: str, **attrs):
    """A span on the active tracer, or the shared no-op singleton —
    always usable as ``with obs.span("prefill"): ...``."""
    if not _ENABLED:
        return NULL_SPAN
    tr = _ACTIVE.get() or _GLOBAL
    return tr.span(name, **attrs) if tr is not None else NULL_SPAN


def event(name: str, **attrs) -> None:
    """An instant event on the active tracer (no-op when disabled)."""
    if not _ENABLED:
        return
    tr = _ACTIVE.get() or _GLOBAL
    if tr is not None:
        tr.event(name, **attrs)


def annotate(**attrs) -> None:
    """Attach attributes to the active tracer's open span (no-op when
    disabled or outside any span)."""
    if not _ENABLED:
        return
    tr = _ACTIVE.get() or _GLOBAL
    if tr is not None:
        tr.annotate(**attrs)
