"""Activation sharding constraints, decoupled from model code.

Models call ``constrain(x, kind)`` with a *logical* activation kind; the
launcher installs an active rule set (mesh-aware) via ``use_rules``.  With no
rules installed (unit tests, single device) it is a no-op.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax

_ACTIVE = contextvars.ContextVar("repro_sharding_rules", default=None)
_MESH = contextvars.ContextVar("repro_sharding_mesh", default=None)


@contextlib.contextmanager
def use_rules(rules, mesh=None):
    """rules: callable (x, kind) -> PartitionSpec | None."""
    tok = _ACTIVE.set(rules)
    tok_m = _MESH.set(mesh)
    try:
        yield
    finally:
        _ACTIVE.reset(tok)
        _MESH.reset(tok_m)


def current_mesh():
    """Mesh installed by the launcher (None in single-device contexts)."""
    return _MESH.get()


def constrain(x, kind: str):
    rules = _ACTIVE.get()
    if rules is None:
        return x
    spec = rules(x, kind)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
