"""Per-device *local* problem shapes under a mesh.

PolyDL's lesson is that loop/tile choices must track the actual working
set; under a production mesh every device executes a sharded local
problem, so tiles tuned for the global shape are tuned for a problem no
device runs.  This module computes the local view:

  * :func:`shard_count` / :func:`local_shape` apply one PartitionSpec-like
    assignment to a shape with the same divisibility fallback the sharding
    rules use (a dim that does not divide over its axes replicates — it
    stays global, never raises),
  * :func:`default_axis_specs` maps every registered op's canonical
    (m, n, k) tuning triple onto mesh axes the way ``sharding.rules``
    shards the corresponding operands (GEMM rows follow the batch rule
    onto the DP axes, the out dim follows the column-parallel weight rule
    onto the model axis, the contraction dim stays gathered ZeRO-3-style),
  * :func:`local_problem` is what ``dispatch.resolve_blocks`` calls: the
    per-device (m, n, k) for an op under the active mesh, overridable per
    op via ``repro.use(axis_specs={op: (m_axes, n_axes, k_axes)})`` —
    e.g. a row-parallel GEMM shards k on the model axis instead of n,
  * :func:`mesh_signature` is the tuning-cache tag: the mesh *axis names*
    (not sizes), so entries tuned per-shard transfer across mesh sizes
    exactly when the local problems coincide.

Only ``mesh.axis_names`` and ``mesh.shape`` are read, so a real
``jax.sharding.Mesh`` and a device-free ``AbstractMesh`` (see
:func:`abstract_mesh`) are interchangeable everywhere in this module and
in dispatch.
"""
from __future__ import annotations

from repro.launch.mesh import dp_axes

# The ops whose canonical triple is a plain GEMM (m rows, n out, k in).
GEMM_OPS = ("matmul", "brgemm", "batched_matmul")


def shard_count(dim: int, axes, mesh) -> int:
    """How many ways a dim of size ``dim`` shards over mesh ``axes``.

    Returns 1 (replicate) when ``axes`` is empty/None or when the dim does
    not divide over the combined axis size — the same fallback
    ``sharding.rules`` applies to params/activations, so per-dim the local
    problem dispatch tunes for matches what the partitioner would do (see
    the flattened-rows caveat on :func:`default_axis_specs`).
    Axis names absent from the mesh are skipped, so a spec written against
    the full production axis set (e.g. ``("pod", "data")``) degrades
    gracefully on single-pod or host-scale meshes.
    """
    if not axes:
        return 1
    size = 1
    for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
        if a is None or a not in mesh.axis_names:
            continue
        size *= mesh.shape[a]
    if size <= 1 or dim < size or dim % size != 0:
        return 1
    return size


def local_shape(shape, spec, mesh) -> tuple[int, ...]:
    """The per-device shape of a global ``shape`` under ``spec``.

    ``spec`` is PartitionSpec-like: one entry per (leading) dim, each
    ``None`` / axis name / tuple of axis names; missing trailing entries
    replicate.  Non-divisible dims stay global (see :func:`shard_count`).
    """
    spec = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    return tuple(int(d) // shard_count(int(d), ax, mesh)
                 for d, ax in zip(shape, spec))


def mesh_signature(mesh) -> tuple[str, ...]:
    """The tuning-cache tag for ``mesh``: its axis *names*.

    Sizes are deliberately excluded: the local problem already encodes
    them, so a cache tuned on a (4, 4) mesh transfers to a (16, 16) mesh
    whenever the per-device shapes coincide — and never collides with
    entries tuned without a mesh (signature ``None``).
    """
    return tuple(str(a) for a in mesh.axis_names)


def default_axis_specs(mesh) -> dict[str, tuple]:
    """Per-op canonical-triple axis assignments under ``mesh``.

    Derived from the ``sharding.rules`` conventions:

      * GEMM family ``(m, n, k)``: activation rows shard on the DP axes,
        the out dim on the model axis (the column-parallel ``param_spec``
        rule), and the contraction dim is compute-local — FSDP all-gathers
        it before the kernel runs.  Caveat: the canonical ``m`` is the
        *flattened* batch x seq product, so divisibility is checked on the
        product while ``batch_spec`` checks batch and seq separately — a
        product that divides when neither factor does (e.g. B=4, S=6 over
        8 DP ways) over-localizes; pass
        ``axis_specs={"matmul": (None, "model", None)}`` for such shapes.
      * conv2d ``(q, c, k)``: out channels follow the column-parallel rule
        onto the model axis; the per-row pixel walk stays local.
      * attention ``(tq, tk, d)``: the model axis shards *heads*, which are
        outside the triple, so the per-device triple equals the global one
        (sequence parallelism can be expressed via ``axis_specs=``).
    """
    dp = dp_axes(mesh) or None
    model = "model" if "model" in mesh.axis_names else None
    gemm = (dp, model, None)
    return {
        "matmul": gemm,
        "brgemm": gemm,
        "batched_matmul": gemm,
        "conv2d": (None, None, model),
        "flash_attention": (None, None, None),
        "flash_attention_bwd": (None, None, None),
    }


def local_problem(op: str, m: int, n: int, k: int, mesh,
                  axis_specs=None) -> tuple[int, int, int]:
    """The per-device (m, n, k) of ``op`` under ``mesh``.

    ``axis_specs`` (a mapping ``{op: (m_axes, n_axes, k_axes)}``) overrides
    the defaults per op — e.g. a row-parallel projection passes
    ``{"matmul": (dp_axes, None, "model")}`` so the *contraction* dim
    localizes instead of the out dim.  Dict-valued entries (the
    ``{"axes": ..., "backend": ...}`` form dispatch accepts) contribute
    their ``"axes"`` here; a backend-only pin keeps the default axes.
    """
    specs = default_axis_specs(mesh)
    for op_name, entry in (axis_specs or {}).items():
        axes = entry.get("axes") if isinstance(entry, dict) else entry
        if axes is not None or not isinstance(entry, dict):
            specs[op_name] = axes
    spec = specs.get(op)
    if spec is None:
        return int(m), int(n), int(k)
    return local_shape((int(m), int(n), int(k)), spec, mesh)


def abstract_mesh(shape, axes):
    """A device-free mesh for local-shape math (works on 1-device hosts).

    ``jax.sharding.AbstractMesh`` carries only axis names and sizes —
    exactly what this module and the dispatch tuning key read — so tests
    and benchmarks can model a (16, 16) production mesh without 256
    devices.  Handles both AbstractMesh constructor generations.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axes, shape)))     # jax <= 0.4.x
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axes))   # jax >= 0.5
