"""Logical-axis -> mesh-axis sharding rules (MaxText-style, path-based).

Parameter rules (FSDP x TP):
  * column-parallel weights (qkv/up/gate projections): last-2 dims ->
    (fsdp, model): the out dim (heads / mlp hidden) shards on the tensor-
    parallel axis, the in dim (embed) shards ZeRO-3-style on the DP axes,
  * row-parallel weights (wo / w_down / w_out): (model, fsdp),
  * embedding table (vocab, embed) -> (model, fsdp); LM head -> (fsdp, model),
  * MoE expert stacks (E, D, F) -> expert dim on the model axis (EP),
  * any extra leading dims (layer stacks / groups) are unsharded,
  * every assignment checks divisibility and falls back to replication.

Activation/cache rules are shape-kind based; when the global batch cannot
cover the DP axes (long_500k: batch=1) the sequence dim takes the DP axes
instead (sequence parallelism).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes, dp_size, model_size
from repro.sharding.local import shard_count

# leaf names -> column-parallel (in, out) = (fsdp, model)
_COL = {
    "wq", "wk", "wv", "wq_a", "wq_b", "wkv_a", "wkv_b", "w_up", "w_gate",
    "w_gelu", "w_rnn_in", "w_rgate", "w_igate", "wi", "wf", "w", "w1", "w2",
    "wo_gate",
}
# leaf names -> row-parallel (in, out) = (model, fsdp)
_ROW = {"wo", "w_down", "w_out"}
_MOE_LEAVES = {"w_gate", "w_up", "w_down"}


def _div(n: int, axes, mesh) -> bool:
    """Dim shards over ``axes`` iff it divides; else replicate (never
    raise).  The same fallback ``sharding.local`` applies when computing
    per-device problem shapes, so dispatch always tunes for the local
    shape the partitioner actually produces."""
    return shard_count(n, axes, mesh) > 1


def _lead(ndim: int, trailing: tuple) -> P:
    return P(*((None,) * (ndim - len(trailing)) + trailing))


def _path_keys(path) -> list[str]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(f"#{p.idx}")
        else:
            keys.append(str(p))
    return keys


def param_spec(path, shape, mesh, fsdp_enabled: bool = True,
               tp_enabled: bool = True) -> P:
    if len(shape) == 0:
        return P()
    keys = _path_keys(path)
    leaf = keys[-1] if keys else ""
    fsdp = dp_axes(mesh) if fsdp_enabled else ()
    model = "model" if tp_enabled and "model" in mesh.axis_names else None
    fs = fsdp if _div(shape[-2] if len(shape) >= 2 else 0, fsdp, mesh) \
        else None
    mdl_last = model if model and _div(shape[-1], model, mesh) else None

    in_moe = any(k == "moe" for k in keys)
    if in_moe and leaf in _MOE_LEAVES and len(shape) >= 3:
        if model and _div(shape[-3], model, mesh):
            # EP: expert dim on the model axis, D ZeRO-sharded on fsdp
            e_axis = model
            if leaf == "w_down":   # (E, F, D)
                d_fs = fsdp if _div(shape[-1], fsdp, mesh) else None
                return _lead(len(shape), (e_axis, None, d_fs))
            d_fs = fsdp if _div(shape[-2], fsdp, mesh) else None
            return _lead(len(shape), (e_axis, d_fs, None))
        # few-experts fallback (E % model != 0, e.g. grok's 8 experts on a
        # 16-way model axis): TP the per-expert FFN dim instead
        if leaf == "w_down":       # (E, F, D)
            f_m = model if model and _div(shape[-2], model, mesh) else None
            d_fs = fsdp if _div(shape[-1], fsdp, mesh) else None
            return _lead(len(shape), (None, f_m, d_fs))
        d_fs = fsdp if _div(shape[-2], fsdp, mesh) else None
        f_m = model if model and _div(shape[-1], model, mesh) else None
        return _lead(len(shape), (None, d_fs, f_m))

    if leaf == "router" and len(shape) >= 2:
        return _lead(len(shape), (fs, None))

    if leaf == "table" and len(shape) >= 2:
        v_m = model if model and _div(shape[-2], model, mesh) else None
        e_fs = fsdp if _div(shape[-1], fsdp, mesh) else None
        return _lead(len(shape), (v_m, e_fs))

    if len(shape) >= 2 and leaf in _ROW:
        m_in = model if model and _div(shape[-2], model, mesh) else None
        o_fs = fsdp if _div(shape[-1], fsdp, mesh) else None
        return _lead(len(shape), (m_in, o_fs))

    if len(shape) >= 2 and (leaf in _COL or leaf == "r"):
        return _lead(len(shape), (fs, mdl_last))

    # 1-D leaves (biases, norm scales, lam): replicate
    return P()


def param_shardings(param_shapes, mesh, *, fsdp: bool = True,
                    tp: bool = True):
    """Tree of NamedSharding matching a tree of ShapeDtypeStruct/arrays.

    fsdp=False replicates params over the dp axes (ZeRO-0); tp=False
    replicates them over the model axis — both are the small-model calls.
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, x: NamedSharding(
            mesh, param_spec(path, x.shape, mesh, fsdp, tp)),
        param_shapes)


# --------------------------------------------------------------------------
# batch / cache / activation rules
# --------------------------------------------------------------------------

def batch_spec(shape, mesh) -> P:
    """tokens/labels (B, S) or embeds (B, T, D)."""
    fsdp = dp_axes(mesh)
    if _div(shape[0], fsdp, mesh):
        return _lead(len(shape), ()) if len(shape) == 0 else P(
            fsdp, *([None] * (len(shape) - 1)))
    # sequence parallelism fallback (long-context, tiny batch)
    if len(shape) >= 2 and _div(shape[1], fsdp, mesh):
        return P(None, fsdp, *([None] * (len(shape) - 2)))
    return P(*([None] * len(shape)))


def batch_shardings(batch_specs, mesh):
    return jax.tree.map(
        lambda x: NamedSharding(mesh, batch_spec(x.shape, mesh)),
        batch_specs)


def cache_spec(path, shape, mesh) -> P:
    keys = _path_keys(path)
    leaf = keys[-1] if keys else ""
    fsdp = dp_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None

    def bspec(b_dim_idx, rest: list):
        b = fsdp if _div(shape[b_dim_idx], fsdp, mesh) else None
        return _lead(len(shape), tuple([b] + rest))

    if leaf in ("k", "v") and len(shape) >= 4:
        h, s = shape[-3], shape[-2]
        if model and _div(h, model, mesh):
            return bspec(len(shape) - 4, [model, None, None])
        if model and _div(s, model, mesh):
            return bspec(len(shape) - 4, [None, model, None])
        return bspec(len(shape) - 4, [None, None, None])
    if leaf in ("c_kv", "k_rope") and len(shape) >= 3:
        s = shape[-2]
        s_ax = model if model and _div(s, model, mesh) else None
        return bspec(len(shape) - 3, [s_ax, None])
    if any(k == "mlstm" for k in keys) and len(shape) >= 4:
        # (.., B, H, dk, dv): shard dk on model when possible
        dk_ax = model if model and _div(shape[-2], model, mesh) else None
        return bspec(len(shape) - 4, [None, dk_ax, None])
    if leaf in ("h", "conv") or (len(shape) >= 2 and leaf in ("c", "n", "m")):
        d_ax = model if model and _div(shape[-1], model, mesh) else None
        return bspec(len(shape) - 2 if len(shape) >= 2 else 0,
                     [d_ax] if len(shape) >= 2 else [])
    # fallback: try batch on the first trailing-structure dim
    return P(*([None] * len(shape)))


def cache_shardings(cache_specs, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, x: NamedSharding(mesh, cache_spec(path, x.shape, mesh)),
        cache_specs)


def activation_rules(mesh):
    """Callable for repro.sharding.annotate.use_rules."""
    fsdp = dp_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None

    def rules(x, kind: str):
        if x.ndim < 2:
            return None
        if kind == "moe_dispatch" and x.ndim == 4:
            # (G, E, cap, D): groups on DP, experts on model when divisible
            g_ax = fsdp if _div(x.shape[0], fsdp, mesh) else None
            e_ax = model if model and _div(x.shape[1], model, mesh) else None
            return P(g_ax, e_ax, None, None)
        b, s = x.shape[0], x.shape[1]
        if _div(b, fsdp, mesh):
            lead = (fsdp, None)
        elif _div(s, fsdp, mesh):
            lead = (None, fsdp)
        else:
            lead = (None, None)
        if kind == "logits" and model and _div(x.shape[-1], model, mesh):
            return P(*lead, *([None] * (x.ndim - 3)), model)
        return P(*lead, *([None] * (x.ndim - 2)))

    return rules
