"""seamless-m4t-large-v2 [audio] — enc-dec transformer backbone.

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596; hf]

The audio frontend (w2v-BERT conformer stack) is a STUB: ``input_specs()``
supplies precomputed frame embeddings for the encoder.  24L is interpreted
as 24 encoder + 24 decoder layers (the published text-decoder depth).
"""
from repro.configs.base import ArchCfg

CONFIG = ArchCfg(
    name="seamless-m4t-large-v2",
    family="audio",
    block="encdec",
    n_layers=24,          # decoder layers
    n_enc_layers=24,      # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    tie_embeddings=False,
    gated_mlp=False,   # standard ReLU FFN (d_ff = 8d)
    mlp_activation="relu",
)
