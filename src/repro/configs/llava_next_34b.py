"""llava-next-34b [vlm] — anyres-tiled VLM; transformer backbone only.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The modality frontend (anyres patch tiling + CLIP tower) is a STUB:
``input_specs()`` supplies precomputed patch embeddings of length
``n_patches`` that are prepended to the token sequence.
"""
from repro.configs.base import ArchCfg

CONFIG = ArchCfg(
    name="llava-next-34b",
    family="vlm",
    block="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    n_patches=576,      # one anyres tile of 24x24 patches (stub frontend)
)
