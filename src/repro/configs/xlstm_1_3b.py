"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1 ratio, xLSTM[7:1]).

48L d_model=2048 4H d_ff=0 (mixer-internal FFN only) vocab=50304
[arXiv:2405.04517; unverified]
"""
from repro.configs.base import ArchCfg

CONFIG = ArchCfg(
    name="xlstm-1.3b",
    family="ssm",
    block="xlstm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_every=8,       # one sLSTM per 8 layers -> 7:1 mLSTM:sLSTM
)
