"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 experts + MTP.

61L d_model=7168 128H (GQA kv=128) d_ff=2048(per-expert) vocab=129280
[arXiv:2412.19437; hf]
"""
from repro.configs.base import ArchCfg

CONFIG = ArchCfg(
    name="deepseek-v3-671b",
    family="moe",
    block="mla_moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,          # dense-FFN hidden for the first n_dense_layers
    vocab=129280,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    n_dense_layers=3,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mtp=True,
    tie_embeddings=False,
)
