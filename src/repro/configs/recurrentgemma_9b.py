"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 rec.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000 [arXiv:2402.19427]
"""
from repro.configs.base import ArchCfg

CONFIG = ArchCfg(
    name="recurrentgemma-9b",
    family="hybrid",
    block="rglru_hybrid",
    n_layers=38,               # 12 x (rec, rec, attn) + 2 trailing rec
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    window=2048,               # local attention window
    pattern=("rec", "rec", "attn"),
    d_rnn=4096,                # lru width
)
