"""Architecture config schema + parameter-count accounting.

One ``ArchCfg`` describes every assigned architecture; each
``configs/<arch>.py`` instantiates it with the exact published dimensions.
``reduced()`` produces the CPU-smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchCfg:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    block: str                     # dense | moe | mla_moe | xlstm | rglru_hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_theta: float = 10000.0
    window: Optional[int] = None   # sliding-window attention size
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0              # per-expert hidden
    n_dense_layers: int = 0        # leading dense layers (DeepSeek-V3: 3)
    moe_capacity_factor: float = 1.25
    # --- MLA (DeepSeek) ---
    mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    mtp: bool = False              # multi-token-prediction aux head
    # --- xLSTM ---
    slstm_every: int = 0           # one sLSTM per this many layers (0 = none)
    # --- hybrid (RecurrentGemma) ---
    pattern: Tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    d_rnn: int = 0
    # --- enc-dec (Seamless) ---
    n_enc_layers: int = 0
    # --- VLM ---
    n_patches: int = 0             # vision-stub prefix length
    # --- serving ---
    eos_token: Optional[int] = None  # default stop token for generation
    # --- FFN flavour ---
    gated_mlp: bool = True         # SwiGLU-style (3 mats) vs plain (2 mats)
    mlp_activation: str = "silu"
    # --- numerics ---
    dtype: str = "bfloat16"
    tie_embeddings: bool = True
    remat: bool = True
    mlstm_chunk: int = 256
    # Unroll layer-stack scans. Compiled code is identical per layer; the
    # dry-run sets this so XLA cost analysis counts every layer (while-loop
    # bodies are otherwise counted once — see EXPERIMENTS.md §Dry-run).
    scan_unroll: bool = False
    # XLA-path attention: "naive" full-T^2 softmax vs "chunked" online
    # softmax (flash semantics; §Perf iteration 3).  The Pallas kernel is
    # always flash-structured.
    attention_impl: str = "naive"
    # ZeRO stage: FSDP-shard params over the dp axes (True) or replicate
    # them there (False; right for small models where the per-layer
    # all-gathers dominate collectives — §Perf iteration 4).
    fsdp: bool = True
    # Tensor-parallelism: shard weights on the model axis (True).  False
    # replicates weights across the model axis — the right call for small
    # models whose TP'd activations generate more collective traffic than
    # the whole gradient all-reduce (§Perf iteration 4b).
    tp: bool = True

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    # ---------------- parameter accounting (for rooflines) ----------------

    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla:
            qk = self.qk_nope_dim + self.qk_rope_dim
            return (d * self.q_lora_rank
                    + self.q_lora_rank * self.n_heads * qk
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        dh = self.dh
        return d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)

    def _mlp_params(self, d_ff: int) -> int:
        return (3 if self.gated_mlp else 2) * self.d_model * d_ff

    def _moe_layer_params(self) -> tuple[int, int]:
        """(total, active) params of one MoE FFN layer."""
        per = self._mlp_params(self.moe_d_ff)
        shared = self._mlp_params(self.moe_d_ff * self.n_shared_experts) \
            if self.n_shared_experts else 0
        router = self.d_model * self.n_experts
        total = per * self.n_experts + shared + router
        active = per * self.top_k + shared + router
        return total, active

    def _xlstm_layer_params(self) -> int:
        d, h = self.d_model, self.n_heads
        dk = dv = d // h
        return d * h * (2 * dk + 2 * dv) + 2 * d * h + h * dv * d

    def _rglru_layer_params(self) -> int:
        d, dr = self.d_model, self.d_rnn
        return 2 * d * dr + 2 * dr * dr + dr * d

    def param_counts(self) -> tuple[int, int]:
        """(total, active) parameter counts (embeddings included once)."""
        d = self.d_model
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = active = emb
        if self.block in ("dense",):
            per = self._attn_params() + self._mlp_params(self.d_ff)
            total += per * self.n_layers
            active = total
        elif self.block in ("moe", "mla_moe"):
            attn = self._attn_params()
            moe_t, moe_a = self._moe_layer_params()
            n_moe = self.n_layers - self.n_dense_layers
            dense = self._mlp_params(self.d_ff) * self.n_dense_layers
            total += (attn * self.n_layers + dense + moe_t * n_moe)
            active += (attn * self.n_layers + dense + moe_a * n_moe)
        elif self.block == "xlstm":
            per = self._xlstm_layer_params()
            total += per * self.n_layers
            active = total
        elif self.block == "rglru_hybrid":
            n_attn = self.n_layers // len(self.pattern) * self.pattern.count(
                "attn")
            n_rec = self.n_layers - n_attn
            total += (self._attn_params() * n_attn
                      + self._rglru_layer_params() * n_rec
                      + self._mlp_params(self.d_ff) * self.n_layers)
            active = total
        elif self.block == "encdec":
            # enc: self-attn + mlp; dec: self + cross + mlp
            enc = (self._attn_params() + self._mlp_params(self.d_ff)
                   ) * self.n_enc_layers
            dec = (2 * self._attn_params() + self._mlp_params(self.d_ff)
                   ) * self.n_layers
            total += enc + dec
            active = total
        else:
            raise ValueError(self.block)
        return total, active

    def reduced(self) -> "ArchCfg":
        """Small same-family variant for CPU smoke tests."""
        updates = dict(
            n_layers=max(2, min(4, self.n_layers // 16)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            dtype="float32",
            remat=False,
            mlstm_chunk=16,
        )
        if self.block in ("moe", "mla_moe"):
            updates.update(n_experts=4, top_k=2, moe_d_ff=64,
                           n_dense_layers=min(1, self.n_dense_layers))
        if self.mla:
            updates.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                           qk_rope_dim=8, v_head_dim=16, head_dim=None)
        if self.block == "xlstm":
            updates.update(n_layers=max(self.slstm_every or 2, 4),
                           head_dim=None)
        if self.block == "rglru_hybrid":
            updates.update(n_layers=2 * len(self.pattern), d_rnn=128,
                           head_dim=32)
        if self.block == "encdec":
            updates.update(n_enc_layers=2, n_layers=2)
        if self.window:
            updates.update(window=8)
        if self.n_patches:
            updates.update(n_patches=4)
        return dataclasses.replace(self, **updates)
