"""starcoder2-15b [dense] — GQA + RoPE.

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152 [arXiv:2402.19173; hf]
"""
from repro.configs.base import ArchCfg

CONFIG = ArchCfg(
    name="starcoder2-15b",
    family="dense",
    block="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    window=4096,      # starcoder2 uses sliding-window attention
    gated_mlp=False,  # plain GELU FFN (d_ff = 4d)
    mlp_activation="gelu",
)
