"""Assigned input shapes + (arch x shape) applicability rules."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchCfg


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCfg("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524_288, 1),
}

# Families with sub-quadratic sequence handling (O(1)-state recurrence or
# bounded-window attention) run long_500k; pure full-attention archs skip it
# (see DESIGN.md §Arch-applicability).
_SUBQUADRATIC_BLOCKS = ("xlstm", "rglru_hybrid")


def applicable(arch: ArchCfg, shape: ShapeCfg) -> tuple[bool, str]:
    if shape.name == "long_500k":
        if arch.block in _SUBQUADRATIC_BLOCKS:
            return True, ""
        if arch.block == "dense" and arch.window:
            # bounded sliding window -> ring cache of size `window`
            return True, ""
        return False, (
            "long_500k skipped: pure full-attention arch cannot hold a "
            "524k dense KV cache (noted in DESIGN.md)")
    return True, ""
