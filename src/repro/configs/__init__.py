"""Registry of assigned architectures (``--arch <id>``)."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchCfg  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeCfg, applicable  # noqa: F401

_MODULES = {
    "llava-next-34b": "llava_next_34b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "grok-1-314b": "grok_1_314b",
    "starcoder2-15b": "starcoder2_15b",
    "smollm-135m": "smollm_135m",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "mistral-large-123b": "mistral_large_123b",
    "xlstm-1.3b": "xlstm_1_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}

ARCH_NAMES = tuple(_MODULES)


def get(name: str) -> ArchCfg:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG
