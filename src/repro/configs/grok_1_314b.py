"""grok-1-314b [moe] — 8 experts top-2.

64L d_model=6144 48H (GQA kv=8) d_ff=32768(per-expert) vocab=131072
[hf:xai-org/grok-1; unverified]
"""
from repro.configs.base import ArchCfg

CONFIG = ArchCfg(
    name="grok-1-314b",
    family="moe",
    block="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    n_experts=8,
    top_k=2,
    moe_d_ff=32768,
    tie_embeddings=False,
)
