"""Optional-hypothesis shim for mixed test modules.

Modules that are *entirely* property-based guard themselves with
``pytest.importorskip("hypothesis")``.  Mixed modules import ``given``,
``settings`` and ``st`` from here instead: with hypothesis installed these
are the real thing; without it, each ``@given`` test collects as a single
skipped test while the rest of the module still runs (minimal installs
keep full non-property coverage).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*args, **kwargs):
        def deco(fn):
            def skipper():
                pytest.skip(
                    "hypothesis not installed (pip install -e '.[test]')")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
