"""The fused Pallas flash-attention backward: gradient parity against XLA
autodiff, LSE residuals, backward block resolution, and the autotune
surface for ``flash_attention_bwd``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import autotune, blocking, dispatch
from repro.core.blocking import AttnBlocks, AttnBwdBlocks
from repro.kernels.flash_attention import (
    flash_attention,
    flash_attention_bwd,
)
from repro.kernels.flash_attention import ops as FO
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention import bwd as BW


def _randn(*shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed + len(shape))
    return jnp.asarray(rng.normal(size=shape), dtype)


@pytest.fixture(autouse=True)
def _fresh_cache():
    dispatch.clear_tuning_cache()
    yield
    dispatch.clear_tuning_cache()


def _qkv(tq=64, tk=64, hq=2, hkv=2, d=16, seed=0):
    return (_randn(1, hq, tq, d, seed=seed),
            _randn(1, hkv, tk, d, seed=seed + 1),
            _randn(1, hkv, tk, d, seed=seed + 2))


def _grads(backend, q, k, v, dy_w, **kw):
    """dQ/dK/dV of a weighted-sum loss (non-uniform cotangent)."""
    def loss(q_, k_, v_):
        y = flash_attention(q_, k_, v_, backend=backend, **kw)
        return (y.astype(jnp.float32) * dy_w).sum()
    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


# --------------------------------------------------------------------------
# gradient parity: Pallas-fused vs XLA autodiff
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name,kw,shape", [
    ("causal", dict(causal=True), dict()),
    ("windowed", dict(causal=True, window=24), dict()),
    ("noncausal", dict(causal=False), dict()),
    ("noncausal_ragged", dict(causal=False), dict(tq=40, tk=72)),
    ("gqa", dict(causal=True), dict(hq=4, hkv=2)),
])
def test_grad_parity_f32(name, kw, shape):
    q, k, v = _qkv(**shape)
    dy_w = _randn(*q.shape, seed=9)
    got = _grads("pallas", q, k, v, dy_w, **kw)
    want = _grads("xla", q, k, v, dy_w, **kw)
    for grad_name, g, w in zip("qkv", got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=2e-3, atol=2e-3,
            err_msg=f"{name} d{grad_name}")


@pytest.mark.parametrize("kw", [dict(causal=True),
                                dict(causal=True, window=24),
                                dict(causal=False)])
def test_grad_parity_bf16_accum(kw):
    q, k, v = _qkv(seed=20)
    dy_w = _randn(*q.shape, seed=29)
    want = _grads("xla", q, k, v, dy_w, **kw)
    with repro.use(accum_dtype=jnp.bfloat16):
        got = _grads("pallas", q, k, v, dy_w, **kw)
    for grad_name, g, w in zip("qkv", got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=0.1, atol=0.1,
            err_msg=f"bf16-accum d{grad_name}")


def test_standalone_bwd_op_matches_recompute_reference():
    q, k, v = _qkv(seed=30)
    y, lse = flash_attention_pallas(q, k, v, causal=True, interpret=True,
                                    return_residuals=True)
    dy = _randn(*q.shape, seed=33)
    got = flash_attention_bwd(q, k, v, y, lse, dy, causal=True,
                              backend="pallas")
    want = flash_attention_bwd(q, k, v, y, lse, dy, causal=True,
                               backend="xla")
    for grad_name, g, w in zip("qkv", got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=2e-3, atol=2e-3,
            err_msg=f"d{grad_name}")


def test_grad_through_attention_layer():
    """The custom VJP composes inside a larger graph (projections around
    the flash kernel), the path a train step actually takes."""
    from repro.layers import attention as A
    cfg = A.AttnCfg(d_model=32, n_heads=2, n_kv_heads=2)
    params = A.init(jax.random.PRNGKey(0), cfg)
    x = _randn(1, 32, 32, seed=40)

    def loss(params, backend):
        y = A.apply(params, x, cfg, mode="train", backend=backend)
        return (y.astype(jnp.float32) ** 2).sum()

    gp = jax.grad(loss)(params, "pallas")
    gx = jax.grad(loss)(params, "xla")
    for key in params:
        np.testing.assert_allclose(
            np.asarray(gp[key]), np.asarray(gx[key]), rtol=5e-3, atol=5e-3,
            err_msg=key)


# --------------------------------------------------------------------------
# residuals: the forward saves LSE stats, the backward recomputes nothing
# --------------------------------------------------------------------------

def test_forward_emits_lse_residuals():
    q, k, v = _qkv(seed=50)
    y, lse = flash_attention_pallas(q, k, v, causal=True, interpret=True,
                                    return_residuals=True)
    # y is unchanged by residual emission
    y_plain = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_plain),
                               rtol=1e-6, atol=1e-6)
    # lse matches the reference log-sum-exp of the masked scores
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    tq, tk = q.shape[-2], k.shape[-2]
    mask = jnp.arange(tk)[None, :] <= jnp.arange(tq)[:, None]
    s = jnp.where(mask[None, None], s, -jnp.inf)
    want = jax.scipy.special.logsumexp(s, axis=-1)
    assert lse.shape == q.shape[:3]
    assert lse.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_vjp_residuals_carry_lse_not_recompute():
    """The custom-VJP forward rule saves (q, k, v, y, lse) — the backward
    consumes the saved statistics instead of re-running the online
    softmax reduction."""
    q, k, v = _qkv(seed=60)
    cfg = FO._Cfg(causal=True, window=None, scale=None, blocks=None,
                  blocks_bwd=None, interpret=True, acc_dtype=jnp.float32)
    y, res = FO._flash_fwd(cfg, q, k, v)
    assert len(res) == 5
    rq, rk, rv, ry, rlse = res
    assert ry.shape == y.shape
    assert rlse.shape == q.shape[:3]  # per-row stats, not a (Tq, Tk) blob
    # and the stats are sufficient: backward from exactly these residuals
    dy = _randn(*q.shape, seed=66)
    dq, dk, dv = FO._flash_bwd(cfg, res, dy)
    assert dq.shape == q.shape and dk.shape == k.shape
    assert dv.shape == v.shape


# --------------------------------------------------------------------------
# backward block resolution and autotune
# --------------------------------------------------------------------------

def test_bwd_blocks_resolve_through_own_schema():
    blk = dispatch.resolve_blocks("flash_attention_bwd", 128, 128, 64,
                                  jnp.float32, backend="pallas")
    assert isinstance(blk, AttnBwdBlocks)
    d = blocking.blocks_to_dict(blk)
    assert d["kind"] == "attn_bwd"
    assert blocking.blocks_from_dict(d) == blk


def test_bwd_candidates_deterministic_and_include_heuristic():
    c1 = blocking.candidate_blocks("flash_attention_bwd", 128, 256, 64)
    c2 = blocking.candidate_blocks("flash_attention_bwd", 128, 256, 64)
    assert c1 == c2
    assert len(c1) == len(set(c1)) > 1
    assert blocking.default_blocks("flash_attention_bwd", 128, 256, 64) in c1


def test_explicit_blocks_bwd_honored_and_bypass_cache():
    q, k, v = _qkv(seed=70)
    dy_w = _randn(*q.shape, seed=77)
    want = _grads("xla", q, k, v, dy_w, causal=True)
    got = _grads("pallas", q, k, v, dy_w, causal=True,
                 blocks=AttnBlocks(32, 128),
                 blocks_bwd=AttnBwdBlocks(32, 128))
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-3, atol=2e-3)
    assert not dispatch.tuning_cache_info()  # explicit geometry bypasses


def test_backward_tiles_tune_independently_of_forward():
    """Under a tuned context, grad through flash attention leaves separate
    cache entries for the forward and backward ops."""
    q, k, v = _qkv(tq=32, tk=32, seed=80)
    with repro.use(blocks_policy=lambda op, m, n, k_, dt, be:
                   autotune.autotune_blocks(op, m, n, k_, dt, be,
                                            max_candidates=2, repeats=1)):
        jax.grad(lambda q_: flash_attention(
            q_, k, v, backend="pallas").sum())(q)
    ops_tuned = {key[0] for key in dispatch.tuning_cache_info()}
    assert "flash_attention" in ops_tuned
    assert "flash_attention_bwd" in ops_tuned


def test_autotune_proxy_measures_fused_backward():
    before = autotune.STATS.measured
    blk = autotune.autotune_blocks("flash_attention_bwd", 32, 32, 16,
                                   jnp.float32, "pallas",
                                   max_candidates=2, repeats=1)
    assert isinstance(blk, AttnBwdBlocks)
    assert autotune.STATS.measured == before + 2


# --------------------------------------------------------------------------
# deprecated shim: a partial block_q/block_k resolves through the policy
# --------------------------------------------------------------------------

def test_partial_deprecated_kwarg_resolves_missing_dim_via_policy():
    q, k, v = _qkv(seed=90)
    seen = []

    def policy(op, m, n, k_, dtype, backend):
        seen.append(op)
        return blocking.default_blocks(op, m, n, k_, dtype)

    with repro.use(blocks_policy=policy):
        with pytest.warns(DeprecationWarning, match="block_q"):
            got = flash_attention(q, k, v, backend="pallas", block_q=32)
    assert "flash_attention" in seen  # resolved, not hard-coded to 128
    heur = blocking.default_blocks("flash_attention", q.shape[-2],
                                   k.shape[-2], q.shape[-1], q.dtype)
    want = flash_attention(q, k, v, backend="pallas",
                           blocks=AttnBlocks(32, heur.block_k))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# conv autotune fidelity: geometry-true proxy and keyed cache
# --------------------------------------------------------------------------

def test_conv_geometry_keys_cache_separately():
    from repro.kernels.conv2d import conv2d
    x1 = _randn(1, 8, 8, 2, seed=100)
    w1 = _randn(1, 1, 2, 4, seed=101) * 0.3
    # Same canonical (q, c, k) triple, different stride/R/S geometry:
    # a 8x8 stride-1 1x1 conv and a 17x17 stride-2 3x3 conv both have
    # q=8 output pixels per row.
    x2 = _randn(1, 17, 17, 2, seed=102)
    w2 = _randn(3, 3, 2, 4, seed=103) * 0.3
    conv2d(x1, w1, stride=1, backend="pallas")
    conv2d(x2, w2, stride=2, padding=0, backend="pallas")
    conv_keys = [key for key in dispatch.tuning_cache_info()
                 if key[0] == "conv2d"]
    assert len(conv_keys) == 2  # distinct geometry -> distinct entries
    geoms = {key[7] for key in conv_keys}
    assert blocking.ConvGeometry(1, 1, 1) in geoms
    assert blocking.ConvGeometry(2, 3, 3) in geoms


def test_conv_autotune_proxy_uses_true_geometry():
    geom = blocking.ConvGeometry(stride=2, r=3, s=3)
    fn = autotune.proxy_runner(
        "conv2d", 8, 2, 4, jnp.float32,
        blocking.ConvBlocks(8, 128, 128), True, geometry=geom)
    out = jax.block_until_ready(fn())
    assert out.shape == (1, 1, 8, 4)  # q=8 true output pixels at stride 2

    before = autotune.STATS.measured
    blk = autotune.autotune_blocks("conv2d", 16, 2, 4, jnp.float32,
                                   "pallas", geometry=geom,
                                   max_candidates=2, repeats=1)
    assert isinstance(blk, blocking.ConvBlocks)
    assert autotune.STATS.measured == before + 2


def test_load_cache_skips_unknown_geometry_entries(tmp_path):
    """A cache file shared with a newer repo version may hold geometry
    kinds this version doesn't know; the load skips them instead of
    failing the first kernel call."""
    import json
    path = str(tmp_path / "cache.json")
    dispatch.resolve_blocks("conv2d", 28, 128, 64, jnp.float32,
                            backend="pallas",
                            geometry=blocking.ConvGeometry(1, 3, 3))
    assert dispatch.save_cache(path) == 1
    with open(path) as f:
        data = json.load(f)
    data["entries"].append({**data["entries"][0],
                            "geometry": {"kind": "hologram", "phase": 7}})
    with open(path, "w") as f:
        json.dump(data, f)
    dispatch.clear_tuning_cache()
    assert dispatch.load_cache(path) == 1  # alien entry skipped, not fatal


def test_conv_geometry_persists_through_cache_file(tmp_path):
    path = str(tmp_path / "cache.json")
    geom = blocking.ConvGeometry(stride=2, r=3, s=3)
    blk = dispatch.resolve_blocks("conv2d", 28, 128, 64, jnp.float32,
                                  backend="pallas", geometry=geom)
    assert dispatch.save_cache(path) == 1
    dispatch.clear_tuning_cache()
    assert dispatch.load_cache(path) == 1
    again = dispatch.resolve_blocks("conv2d", 28, 128, 64, jnp.float32,
                                    backend="pallas", geometry=geom)
    assert again == blk


# --------------------------------------------------------------------------
# fused delta precompute (rowsum(dY o Y) inside the dQ kernel's first pass)
# --------------------------------------------------------------------------

def test_delta_rowsum_standalone_matches_manual():
    q, k, v = _qkv(seed=70)
    y = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    dy = _randn(*q.shape, seed=77)
    got = BW.delta_rowsum_pallas(y, dy, interpret=True)
    want = (np.asarray(y, np.float32) * np.asarray(dy, np.float32)).sum(-1)
    assert got.shape == q.shape[:3] and got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name,kw,shape", [
    ("causal", dict(causal=True), dict()),
    ("windowed", dict(causal=True, window=24), dict()),
    ("noncausal_ragged", dict(causal=False), dict(tq=40, tk=72)),
    ("gqa", dict(causal=True), dict(hq=4, hkv=2)),
])
def test_fused_delta_matches_standalone_and_leaves_grads_unchanged(
        name, kw, shape):
    q, k, v = _qkv(seed=80, **shape)
    y, lse = flash_attention_pallas(q, k, v, interpret=True,
                                    return_residuals=True, **kw)
    dy = _randn(*q.shape, seed=88)
    dq, dk, dv, delta = BW.flash_attention_bwd_pallas(
        q, k, v, y, lse, dy, interpret=True, return_delta=True, **kw)
    # the fused rowsum is the standalone kernel's answer, bit for bit
    want = BW.delta_rowsum_pallas(y, dy, interpret=True)
    np.testing.assert_array_equal(np.asarray(delta), np.asarray(want),
                                  err_msg=name)
    # and emitting it does not perturb the gradients
    dq0, dk0, dv0 = BW.flash_attention_bwd_pallas(
        q, k, v, y, lse, dy, interpret=True, **kw)
    for g_name, a, b in (("dq", dq, dq0), ("dk", dk, dk0), ("dv", dv, dv0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{name} {g_name}")
