"""Cluster-serving tests: router balancing and tier affinity, admission
control (bounded backlog -> reject / shed), per-request deadlines freeing
slots mid-flight, replica-failure requeue, Prometheus export, and the
asyncio front-end (streaming parity with the sync engine, cancellation,
timeout, rejection)."""
import asyncio

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.serve import (
    AsyncFrontend,
    ClusterMetrics,
    ContinuousEngine,
    EngineReplica,
    EngineRouter,
    PoolConfig,
    Request,
)
from repro.serve import cluster as cl

MAX_LEN = 32


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, n).tolist() for n in lens]


def _engine(dense, n_slots=2):
    cfg, params = dense
    return ContinuousEngine(cfg, params,
                            PoolConfig(n_slots=n_slots, max_len=MAX_LEN))


def _fail_after(engine, n_calls):
    """Make engine.step() raise on its ``n_calls``-th invocation."""
    orig, calls = engine.step, [0]

    def flaky():
        calls[0] += 1
        if calls[0] == n_calls:
            raise RuntimeError("injected replica fault")
        return orig()
    engine.step = flaky


# ==========================================================================
# routing
# ==========================================================================

def test_router_validation(dense):
    with pytest.raises(ValueError, match="at least one"):
        EngineRouter([])
    eng = _engine(dense, n_slots=1)
    with pytest.raises(ValueError, match="duplicate"):
        EngineRouter([EngineReplica("a", eng), EngineReplica("a", eng)])
    with pytest.raises(ValueError, match="admission"):
        EngineRouter([EngineReplica("a", eng)], admission="drop")


def test_least_depth_balancing(dense):
    """Submissions alternate across equally-loaded replicas, and the
    whole workload completes through both."""
    cfg, _ = dense
    router = EngineRouter([EngineReplica("a", _engine(dense)),
                           EngineReplica("b", _engine(dense))])
    prompts = _prompts(cfg, [4, 5, 6, 7], seed=1)
    ids = [router.submit(Request(prompt=p, max_tokens=3, stop_tokens=()))
           for p in prompts]
    placed = [router.tickets[t].replica.name for t in ids]
    assert placed == ["a", "b", "a", "b"]
    while router.has_work():
        router.step()
    assert all(router.tickets[t].status == cl.COMPLETED for t in ids)
    assert all(len(router.tickets[t].tokens) == 3 for t in ids)
    # both replicas actually decoded
    m = router.metrics()
    assert m.replicas["a"].tokens_generated > 0
    assert m.replicas["b"].tokens_generated > 0
    assert (ClusterMetrics.merge(m.replicas.values()).tokens_generated
            == 12)


def test_tier_affinity_prefers_matching_replica(dense):
    cfg, _ = dense
    router = EngineRouter([
        EngineReplica("fast", _engine(dense), tier="bf16"),
        EngineReplica("exact", _engine(dense), tier="fp32"),
    ])
    p = _prompts(cfg, [4], seed=2)[0]
    req = lambda: Request(prompt=p, max_tokens=2, stop_tokens=())  # noqa: E731
    # affinity wins even when the tier's replica is deeper
    for _ in range(3):
        tid = router.submit(req(), tier="fp32")
        assert router.tickets[tid].replica.name == "exact"
    # unknown tier falls back to least depth over all healthy replicas
    tid = router.submit(req(), tier="int4")
    assert router.tickets[tid].replica.name == "fast"


# ==========================================================================
# admission control
# ==========================================================================

def test_backpressure_reject(dense):
    """At the backlog bound the router rejects with a terminal status
    instead of queuing without bound."""
    cfg, _ = dense
    router = EngineRouter([EngineReplica("a", _engine(dense, n_slots=1))],
                          max_waiting=1)
    p = _prompts(cfg, [4], seed=3)[0]
    finishes = []
    ids = [router.submit(Request(prompt=p, max_tokens=2, stop_tokens=()),
                         on_finish=lambda t: finishes.append(
                             (t.ticket_id, t.status)))
           for _ in range(4)]
    # slot capacity 1 + backlog bound 1 => two admitted, two rejected
    statuses = [router.tickets[t].status for t in ids]
    assert statuses == [None, None, cl.REJECTED, cl.REJECTED]
    assert finishes == [(ids[2], cl.REJECTED), (ids[3], cl.REJECTED)]
    assert router.counters["requests_rejected"] == 2
    while router.has_work():
        router.step()
    assert [router.tickets[t].status for t in ids[:2]] == [cl.COMPLETED] * 2
    assert router.tickets[ids[2]].tokens == []


def test_backpressure_shed_lowest_priority(dense):
    """admission="shed": a saturated router evicts the lowest-priority
    waiting request for a higher-priority newcomer, and sheds the
    newcomer itself when nothing waiting is lower."""
    cfg, _ = dense
    router = EngineRouter([EngineReplica("a", _engine(dense, n_slots=1))],
                          max_waiting=1, admission="shed")
    p = _prompts(cfg, [4], seed=4)[0]

    def req(prio):
        return Request(prompt=p, max_tokens=2, stop_tokens=(),
                       priority=prio)

    a = router.submit(req(1.0))
    b = router.submit(req(1.0))
    # equal priority: the newcomer is shed, queued work survives
    c = router.submit(req(1.0))
    assert router.tickets[c].status == cl.SHED
    # higher priority: the lowest-priority (and newest among ties)
    # waiting request is shed to make room
    d = router.submit(req(5.0))
    assert router.tickets[b].status == cl.SHED
    assert router.tickets[d].status is None
    assert router.counters["requests_shed"] == 2
    while router.has_work():
        router.step()
    assert router.tickets[a].status == cl.COMPLETED
    assert router.tickets[d].status == cl.COMPLETED


# ==========================================================================
# deadlines
# ==========================================================================

def test_deadline_expiry_frees_slot(dense):
    """A request past its deadline is cancelled mid-flight: its KV slot
    frees the same step and the next request runs on it."""
    cfg, _ = dense
    eng = _engine(dense, n_slots=1)
    clk = {"t": 0.0}
    router = EngineRouter([EngineReplica("a", eng)],
                          clock=lambda: clk["t"])
    p = _prompts(cfg, [4], seed=5)[0]
    tid = router.submit(Request(prompt=p, max_tokens=20, stop_tokens=()),
                        deadline_s=5.0)
    router.step()
    assert eng.scheduler.n_running == 1
    ticket = router.tickets[tid]
    assert ticket.status is None and len(ticket.tokens) >= 1

    clk["t"] = 10.0
    router.step()
    assert ticket.status == cl.TIMEOUT
    assert eng.pool.n_free == 1                     # slot freed mid-flight
    assert not router.has_work()
    assert router.counters["requests_timeout"] == 1
    assert eng.metrics.requests_cancelled == 1

    tid2 = router.submit(Request(prompt=p, max_tokens=2, stop_tokens=()))
    while router.has_work():
        router.step()
    assert router.tickets[tid2].status == cl.COMPLETED
    assert len(router.tickets[tid2].tokens) == 2


# ==========================================================================
# replica faults
# ==========================================================================

def test_replica_failure_requeues_and_completes(dense):
    """A replica whose step() raises is quarantined; its in-flight
    requests (waiting and mid-generation) requeue onto the survivor and
    every request completes with the single-engine greedy output —
    streamed without duplicating the prefix emitted before the fault."""
    cfg, params = dense
    prompts = _prompts(cfg, [4, 6, 3, 7, 5, 4], seed=6)
    reqs = [Request(prompt=p, max_tokens=4, stop_tokens=())
            for p in prompts]
    reference = _engine(dense).serve(
        [Request(prompt=p, max_tokens=4, stop_tokens=())
         for p in prompts])

    flaky = _engine(dense)
    _fail_after(flaky, 2)
    router = EngineRouter([EngineReplica("a", _engine(dense)),
                           EngineReplica("b", flaky)])
    streams: dict[int, list] = {}
    ids = [router.submit(r, on_token=lambda tid, tok, fin:
                         streams.setdefault(tid, []).append(tok))
           for r in reqs]
    while router.has_work():
        router.step()

    assert router.counters["replicas_quarantined"] == 1
    assert router.counters["requests_requeued"] >= 1
    assert [r.name for r in router.healthy_replicas()] == ["a"]
    assert not router.replicas[1].healthy
    assert isinstance(router.replicas[1].fault, RuntimeError)
    for i, tid in enumerate(ids):
        t = router.tickets[tid]
        assert t.status == cl.COMPLETED
        assert t.finish_reason == "length"
        assert t.tokens == streams[tid] == reference[i], \
            f"request {i} diverged after requeue"


def test_last_replica_failure_fails_tickets_and_raises(dense):
    cfg, _ = dense
    eng = _engine(dense, n_slots=1)
    _fail_after(eng, 1)
    router = EngineRouter([EngineReplica("a", eng)])
    p = _prompts(cfg, [4], seed=7)[0]
    tid = router.submit(Request(prompt=p, max_tokens=2, stop_tokens=()))
    with pytest.raises(RuntimeError, match="no survivors"):
        router.step()
    assert router.tickets[tid].status == cl.FAILED
    assert not router.has_work()


# ==========================================================================
# metrics export
# ==========================================================================

def test_prometheus_export_and_merge(dense):
    cfg, _ = dense
    router = EngineRouter([EngineReplica("r0", _engine(dense)),
                           EngineReplica("r1", _engine(dense))])
    prompts = _prompts(cfg, [4, 5, 6], seed=8)
    out = router.serve([Request(prompt=p, max_tokens=3, stop_tokens=())
                        for p in prompts])
    assert all(len(v) == 3 for v in out.values())
    for t in router.tickets.values():
        assert t.ttft_s is not None and t.ttft_s >= 0

    cm = router.metrics()
    text = cm.to_prometheus()
    # per-replica samples for the acceptance families
    for family in ("occupancy", "queue_depth", "tokens_per_second",
                   "ttft_seconds_mean"):
        for name in ("r0", "r1"):
            assert f'repro_serve_{family}{{replica="{name}"}}' in text, \
                (family, name, text)
    assert '# TYPE repro_serve_tokens_generated_total counter' in text
    assert text.count("# TYPE repro_serve_occupancy gauge") == 1
    assert "repro_serve_requests_rejected_total 0" in text
    assert 'repro_serve_healthy{replica="r0"} 1' in text

    merged = cm.aggregate()
    assert merged.tokens_generated == 9
    assert merged.requests_completed == 3
    assert merged.max_queue_depth == max(
        m.max_queue_depth for m in cm.replicas.values())
    assert merged.ttft_s_sum == pytest.approx(
        sum(m.ttft_s_sum for m in cm.replicas.values()))


# ==========================================================================
# asyncio front-end
# ==========================================================================

def test_async_streaming_matches_sync_token_for_token(dense):
    """Tokens streamed through AsyncFrontend equal the sync engine's
    serve() outputs exactly, per request and in order."""
    cfg, _ = dense
    prompts = _prompts(cfg, [4, 7, 3, 6], seed=9)
    mts = [5, 3, 4, 2]
    reference = _engine(dense).serve(
        [Request(prompt=p, max_tokens=mt, stop_tokens=())
         for p, mt in zip(prompts, mts)])
    router = EngineRouter([EngineReplica("a", _engine(dense))])

    async def main():
        async with AsyncFrontend(router) as fe:
            handles = [await fe.submit(
                Request(prompt=p, max_tokens=mt, stop_tokens=()))
                for p, mt in zip(prompts, mts)]

            async def collect(h):
                return [tok async for tok in h]

            streams = await asyncio.gather(*map(collect, handles))
            results = [await h for h in handles]
        return handles, streams, results

    handles, streams, results = asyncio.run(main())
    for i, (h, s, r) in enumerate(zip(handles, streams, results)):
        assert r.status == cl.COMPLETED
        assert r.finish_reason == "length"
        assert s == r.tokens == reference[i], f"request {i} diverged"
        assert h.done()


def test_async_two_replica_cluster_streams_concurrently(dense):
    """The front-end drives two replicas on different tiers; every
    request completes and lands on its preferred tier."""
    cfg, _ = dense
    router = EngineRouter([
        EngineReplica("bf16", _engine(dense), tier="bf16"),
        EngineReplica("fp32", _engine(dense), tier="fp32"),
    ])
    prompts = _prompts(cfg, [4, 5, 6, 7], seed=10)
    tiers = ["bf16", "fp32", "bf16", "fp32"]

    async def main():
        async with AsyncFrontend(router) as fe:
            handles = [await fe.submit(
                Request(prompt=p, max_tokens=3, stop_tokens=()), tier=t)
                for p, t in zip(prompts, tiers)]
            return [await h for h in handles]

    results = asyncio.run(main())
    assert all(r.status == cl.COMPLETED for r in results)
    assert all(len(r.tokens) == 3 for r in results)
    placed = [router.tickets[i].replica.name for i in range(len(tiers))]
    assert placed == tiers
    m = router.metrics()
    assert m.replicas["bf16"].tokens_generated == 6
    assert m.replicas["fp32"].tokens_generated == 6


def test_async_cancel_timeout_and_reject(dense):
    """Terminal statuses through the front-end: handle.cancel() resolves
    "cancelled" and frees the slot, deadline_s resolves "timeout", and
    admission control resolves "rejected" without raising."""
    cfg, _ = dense
    eng = _engine(dense, n_slots=1)
    router = EngineRouter([EngineReplica("a", eng)], max_waiting=1)
    p = _prompts(cfg, [4], seed=11)[0]

    async def main():
        async with AsyncFrontend(router) as fe:
            long1 = await fe.submit(Request(prompt=p, max_tokens=20,
                                            stop_tokens=()))
            # wait for its first token so long1 is pinned as running
            async for _ in long1:
                break
            # saturate the backlog (bound 1), then one too many
            long2 = await fe.submit(Request(prompt=p, max_tokens=20,
                                            stop_tokens=()))
            rejected = await fe.submit(Request(prompt=p, max_tokens=2,
                                               stop_tokens=()))
            r_rej = await rejected
            # free the backlog, then arm a deadline already in the past:
            # it expires on the next sweep without generating a token
            await long2.cancel()
            r2 = await long2
            timed = await fe.submit(Request(prompt=p, max_tokens=20,
                                            stop_tokens=()),
                                    deadline_s=0.0)
            r_timed = await timed
            await long1.cancel()
            r1 = await long1
        return r_rej, r_timed, r1, r2

    r_rej, r_timed, r1, r2 = asyncio.run(main())
    assert r_rej.status == cl.REJECTED and r_rej.tokens == []
    assert r_timed.status == cl.TIMEOUT
    assert r1.status == cl.CANCELLED
    assert r2.status == cl.CANCELLED
    assert eng.pool.n_free == 1          # cancelled slots were freed
    assert not router.has_work()
    assert router.counters["requests_timeout"] == 1
    assert router.counters["requests_rejected"] == 1


def test_async_frontend_survives_replica_fault(dense):
    """An injected fault mid-service quarantines the replica; awaiting
    clients still get completed results for every request."""
    cfg, _ = dense
    flaky = _engine(dense)
    _fail_after(flaky, 2)
    router = EngineRouter([EngineReplica("a", _engine(dense)),
                           EngineReplica("b", flaky)])
    prompts = _prompts(cfg, [4, 6, 5, 3, 7, 4], seed=12)

    async def main():
        async with AsyncFrontend(router) as fe:
            handles = [await fe.submit(
                Request(prompt=p, max_tokens=4, stop_tokens=()))
                for p in prompts]
            return [await h for h in handles]

    results = asyncio.run(main())
    assert all(r.status == cl.COMPLETED for r in results)
    assert all(len(r.tokens) == 4 for r in results)
    assert router.counters["replicas_quarantined"] == 1
    assert router.counters["requests_requeued"] >= 1


def test_async_total_failure_resolves_failed(dense):
    """Losing the last replica resolves pending handles with "failed"
    (no hung awaits) and surfaces the fault on frontend.error."""
    cfg, _ = dense
    eng = _engine(dense, n_slots=1)
    _fail_after(eng, 1)
    router = EngineRouter([EngineReplica("a", eng)])
    p = _prompts(cfg, [4], seed=13)[0]

    async def main():
        fe = AsyncFrontend(router)
        await fe.start()
        handle = await fe.submit(Request(prompt=p, max_tokens=2,
                                         stop_tokens=()))
        result = await handle
        tokens = [t async for t in handle]
        await fe.stop()
        return fe, result, tokens

    fe, result, tokens = asyncio.run(main())
    assert result.status == cl.FAILED
    assert tokens == []
    assert isinstance(fe.error, RuntimeError)
