"""Mesh-aware dispatch: per-shard local problems, mesh-signature cache
keys (including JSON persistence), sharding-rule divisibility fallbacks,
cross-shape autotune seeding, and mesh capture in the train/serve tiers."""
import os
import pathlib
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import pytest

import repro
from repro.core import autotune, blocking, dispatch
from repro.sharding import annotate, rules
from repro.sharding import local as shlocal

REPO = pathlib.Path(__file__).resolve().parents[1]

# 8-way host-scale mesh, device-free: only axis_names/shape are read by
# the local-shape math and the dispatch tuning key.
MESH8 = shlocal.abstract_mesh((2, 4), ("data", "model"))


@pytest.fixture(autouse=True)
def _fresh_cache():
    dispatch.clear_tuning_cache()
    yield
    dispatch.clear_tuning_cache()


def _key(path):
    return types.SimpleNamespace(key=path)


# --------------------------------------------------------------------------
# local shapes + divisibility fallback
# --------------------------------------------------------------------------

def test_shard_count_and_divisibility_fallback():
    assert shlocal.shard_count(8192, ("data",), MESH8) == 2
    assert shlocal.shard_count(8192, "model", MESH8) == 4
    assert shlocal.shard_count(8192, ("data", "model"), MESH8) == 8
    # non-divisible / too-small dims replicate, never raise
    assert shlocal.shard_count(7, ("data", "model"), MESH8) == 1
    assert shlocal.shard_count(6, "model", MESH8) == 1
    assert shlocal.shard_count(3, ("data",), MESH8) == 1  # 3 % 2 != 0
    # axes absent from the mesh are skipped (production specs on host mesh)
    assert shlocal.shard_count(64, ("pod", "data"), MESH8) == 2
    assert shlocal.shard_count(64, None, MESH8) == 1


def test_local_shape_applies_spec_per_dim():
    got = shlocal.local_shape((8192, 512, 1024),
                              (("data",), "model", None), MESH8)
    assert got == (4096, 128, 1024)
    # trailing dims without a spec entry replicate
    assert shlocal.local_shape((64, 64, 64), ("model",), MESH8) \
        == (16, 64, 64)


def test_default_axis_specs_follow_sharding_rules():
    specs = shlocal.default_axis_specs(MESH8)
    assert set(specs) == set(blocking.BLOCK_SCHEMAS)
    # GEMM: rows on DP (batch rule), out dim on model (column-parallel
    # weight rule), contraction gathered
    assert specs["matmul"] == (("data",), "model", None)
    assert shlocal.local_problem("matmul", 8192, 512, 1024, MESH8) \
        == (4096, 128, 1024)
    # attention triple is head-sharded -> mesh-invariant by default
    assert shlocal.local_problem("flash_attention", 128, 4096, 64, MESH8) \
        == (128, 4096, 64)
    # conv out-channels on model
    assert shlocal.local_problem("conv2d", 28, 128, 512, MESH8) \
        == (28, 128, 128)


def test_axis_specs_override_row_parallel():
    got = shlocal.local_problem(
        "matmul", 8192, 512, 1024, MESH8,
        axis_specs={"matmul": (("data",), None, "model")})
    assert got == (4096, 512, 256)


def test_mesh_signature_is_axis_names_not_sizes():
    assert shlocal.mesh_signature(MESH8) == ("data", "model")
    big = shlocal.abstract_mesh((16, 16), ("data", "model"))
    assert shlocal.mesh_signature(big) == shlocal.mesh_signature(MESH8)


# --------------------------------------------------------------------------
# resolve_blocks under a mesh
# --------------------------------------------------------------------------

def test_resolve_blocks_returns_local_shard_tiles():
    """Acceptance: on an 8-way mesh a model-sharded GEMM resolves the tile
    of the *local* shard shape, not the global shape."""
    spec = {"matmul": (("data",), None, "model")}  # row-parallel: k/model
    glob = dispatch.resolve_blocks("matmul", 8192, 512, 1024, jnp.float32,
                                   backend="pallas")
    with repro.use(mesh=MESH8, axis_specs=spec):
        local = dispatch.resolve_blocks("matmul", 8192, 512, 1024,
                                        jnp.float32, backend="pallas")
    assert glob == blocking.default_blocks("matmul", 8192, 512, 1024,
                                           jnp.float32)
    assert local == blocking.default_blocks("matmul", 4096, 512, 256,
                                            jnp.float32)
    assert local != glob  # bk tracks the sharded contraction dim


def test_mesh_signature_joins_cache_key():
    dispatch.resolve_blocks("matmul", 256, 256, 256, jnp.float32,
                            backend="pallas")
    with repro.use(mesh=MESH8):
        dispatch.resolve_blocks("matmul", 256, 256, 256, jnp.float32,
                                backend="pallas")
    keys = set(dispatch.tuning_cache_info())
    sigs = {k[-2] for k in keys}              # mesh sig sits before quant tag
    assert sigs == {None, ("data", "model")}
    # the meshed entry is keyed by the *local* problem
    assert ("matmul", "pallas", 128, 64, 256, "float32", "heuristic",
            None, ("data", "model"), None) in keys


def test_cache_transfers_across_mesh_sizes_when_local_shapes_match():
    calls = []

    def policy(op, m, n, k, dtype, backend):
        calls.append((m, n, k))
        return blocking.default_blocks(op, m, n, k, dtype)

    small = shlocal.abstract_mesh((2, 4), ("data", "model"))
    big = shlocal.abstract_mesh((4, 8), ("data", "model"))
    with repro.use(blocks_policy=policy):
        with repro.use(mesh=small):
            dispatch.resolve_blocks("matmul", 256, 128, 1024, jnp.float32,
                                    backend="pallas")
        with repro.use(mesh=big):
            dispatch.resolve_blocks("matmul", 512, 256, 1024, jnp.float32,
                                    backend="pallas")
    # both globals localize to (128, 32, 1024) -> one policy call, one entry
    assert calls == [(128, 32, 1024)]
    assert len(dispatch.tuning_cache_info()) == 1


def test_mesh_signature_survives_save_load_round_trip(tmp_path):
    path = str(tmp_path / "cache.json")
    dispatch.resolve_blocks("matmul", 512, 512, 512, jnp.float32,
                            backend="pallas")
    with repro.use(mesh=MESH8):
        dispatch.resolve_blocks("matmul", 512, 512, 512, jnp.float32,
                                backend="pallas")
    before = dispatch.tuning_cache_info()
    assert dispatch.save_cache(path) == 2
    dispatch.clear_tuning_cache()
    assert dispatch.load_cache(path) == 2
    assert dispatch.tuning_cache_info() == before
    # a second save round-trips entries merged back from the file
    assert dispatch.save_cache(path) == 2


def test_unknown_axis_specs_op_rejected():
    with pytest.raises(ValueError, match="axis_specs"):
        with repro.use(axis_specs={"not_an_op": (None, None, None)}):
            pass


def test_malformed_axis_spec_rejected():
    # a bare string would iterate per character and silently replicate
    with pytest.raises(ValueError, match="sequence of 3"):
        with repro.use(axis_specs={"matmul": "model"}):
            pass
    with pytest.raises(ValueError, match="3 entries"):
        with repro.use(axis_specs={"matmul": (None, "model")}):
            pass
    with pytest.raises(ValueError, match="axis name"):
        with repro.use(axis_specs={"matmul": (None, 4, None)}):
            pass
    # PartitionSpec-like triples are fine
    from jax.sharding import PartitionSpec as P
    with repro.use(axis_specs={"matmul": P(("data",), "model", None)}):
        pass


# --------------------------------------------------------------------------
# sharding.rules divisibility fallback (param / batch rules)
# --------------------------------------------------------------------------

def test_param_spec_divisible_dims_shard():
    from jax.sharding import PartitionSpec as P
    spec = rules.param_spec([_key("wq")], (256, 128), MESH8)
    assert spec == P(("data",), "model")
    spec = rules.param_spec([_key("wo")], (128, 256), MESH8)
    assert spec == P("model", ("data",))


def test_param_spec_non_divisible_dims_replicate():
    from jax.sharding import PartitionSpec as P
    # 255 % 2 != 0 and 126 % 4 != 0: both dims fall back to replication
    assert rules.param_spec([_key("wq")], (255, 126), MESH8) == P(None, None)
    # one divisible dim still shards while the other replicates
    assert rules.param_spec([_key("wq")], (255, 128), MESH8) \
        == P(None, "model")
    assert rules.param_spec([_key("wo")], (126, 256), MESH8) \
        == P(None, ("data",))
    # 1-D leaves always replicate
    assert rules.param_spec([_key("b")], (129,), MESH8) == P()


def test_batch_spec_sequence_parallel_fallback():
    from jax.sharding import PartitionSpec as P
    # batch divides -> batch-sharded
    assert rules.batch_spec((4, 16), MESH8) == P(("data",), None)
    # batch=1 -> sequence dim takes the DP axes
    assert rules.batch_spec((1, 16), MESH8) == P(None, ("data",))
    # neither divides -> fully replicated
    assert rules.batch_spec((1, 15), MESH8) == P(None, None)


# --------------------------------------------------------------------------
# cross-shape transfer seeding in the autotuner
# --------------------------------------------------------------------------

def test_autotune_seeds_grid_from_nearest_tuned_neighbor(monkeypatch):
    monkeypatch.setenv(autotune.ENV_MAX_CANDIDATES, "3")
    monkeypatch.setenv(autotune.ENV_REPEATS, "1")
    # a fresh cache has no neighbors: no seeding
    order = []

    def timer(op, m, n, k, dtype, backend, blocks):
        order.append(blocks)
        return 1.0

    before = autotune.STATS.seeded
    autotune.autotune_blocks("matmul", 32, 16, 16, jnp.float32, "pallas",
                             timer=timer)
    assert autotune.STATS.seeded == before
    # tune a tiny neighbor for real (interpret-safe) under the named policy
    with repro.use(blocks_policy="autotune"):
        winner = dispatch.resolve_blocks("matmul", 16, 16, 16, jnp.float32,
                                         backend="pallas")
    assert autotune.nearest_tuned_neighbor(
        "matmul", 32, 16, 16, jnp.float32, "pallas") == winner
    # the next search on a nearby shape measures the neighbor's winner
    # first, ahead of the heuristic
    order.clear()
    got = autotune.autotune_blocks("matmul", 32, 16, 16, jnp.float32,
                                   "pallas", timer=timer)
    assert autotune.STATS.seeded == before + 1
    assert order[0] == winner
    assert got == winner  # flat costs: ties keep the seeded candidate


def test_neighbor_ignores_other_ops_dtypes_and_heuristic_entries():
    # heuristic entries are not measured winners -> never seed
    dispatch.resolve_blocks("matmul", 16, 16, 16, jnp.float32,
                            backend="pallas")
    assert autotune.nearest_tuned_neighbor(
        "matmul", 32, 16, 16, jnp.float32, "pallas") is None


# --------------------------------------------------------------------------
# consumers capture the mesh at trace time
# --------------------------------------------------------------------------

def test_train_step_captures_explicit_and_annotate_mesh(monkeypatch):
    from repro import configs
    from repro.train import optimizer as opt
    from repro.train import train_step as ts

    seen = []

    def fake_loss(params, batch, cfg):
        seen.append(dispatch.current_context().mesh)
        return params["w"].sum(), {}

    monkeypatch.setattr(ts.api, "loss_fn", fake_loss)
    cfg = configs.get("smollm-135m").reduced()
    ocfg = opt.AdamWCfg()
    state = {"opt": opt.adamw_init({"w": jnp.ones((4,), jnp.float32)},
                                   ocfg)}
    batch = {"tokens": jnp.zeros((1,), jnp.int32)}

    ts.make_train_step(cfg, ocfg, mesh=MESH8)(state, batch)
    assert seen[-1] is MESH8
    # unset mesh falls back to the launcher-installed one at trace time
    with annotate.use_rules(lambda x, kind: None, MESH8):
        ts.make_train_step(cfg, ocfg)(state, batch)
    assert seen[-1] is MESH8
    ts.make_train_step(cfg, ocfg)(state, batch)
    assert seen[-1] is None


def test_serve_tier_context_mesh_fallback():
    from repro.serve.engine import _tier_context
    assert _tier_context(None, None, None)["mesh"] is None
    with annotate.use_rules(lambda x, kind: None, MESH8):
        assert _tier_context(None, None, None)["mesh"] is MESH8
        other = shlocal.abstract_mesh((4, 2), ("data", "model"))
        assert _tier_context(None, None, None, mesh=other)["mesh"] is other


def test_continuous_engine_resolves_per_shard_blocks():
    """End to end: the serving tier's jit trace resolves *local* GEMM
    problems under its mesh — the spy policy sees model-sharded out dims."""
    from repro import configs
    from repro.models import api
    from repro.serve import ContinuousEngine, PoolConfig, Request

    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    def run(mesh):
        calls = []

        def spy(op, m, n, k, dtype, backend):
            calls.append((op, m, n, k))
            return blocking.default_blocks(op, m, n, k, dtype)

        eng = ContinuousEngine(
            cfg, params, PoolConfig(n_slots=1, max_len=16),
            backend="pallas", interpret=True, blocks_policy=spy, mesh=mesh)
        eng.serve([Request(prompt=[3, 5, 7], max_tokens=1,
                           stop_tokens=())])
        return set(calls)

    meshless = run(None)
    meshed = run(MESH8)
    assert meshed != meshless
    # every meshless matmul out-dim that divides by the model axis shows up
    # quartered in the meshed trace
    shrunk = {(op, m, n // 4, k) for op, m, n, k in meshless
              if op == "matmul" and n % 4 == 0}
    assert shrunk & meshed


# --------------------------------------------------------------------------
# the dry-run cell records per-shard choices (8 real host devices)
# --------------------------------------------------------------------------

def test_dryrun_blocks_smoke_on_8way_host_mesh():
    """Acceptance: a real 8-device host mesh resolves per-shard blocks
    that differ from the global-shape choice, via the CLI CI uses."""
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--blocks-smoke",
         "--devices", "8"],
        capture_output=True, text=True, env=env, cwd=str(REPO), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert '"differs": true' in r.stdout
    assert "per_shard_differs=" in r.stdout


def test_importing_dryrun_does_not_clobber_xla_flags(monkeypatch):
    """The module must be importable without forcing 512 host devices."""
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    import repro.launch.dryrun  # noqa: F401  (idempotent re-import)
    assert "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", "")
    # and the gate composes with pre-existing flags
    monkeypatch.setenv("XLA_FLAGS", "--xla_dump_to=/tmp/x")
    repro.launch.dryrun.force_host_device_count(8)
    assert os.environ["XLA_FLAGS"] == (
        "--xla_dump_to=/tmp/x --xla_force_host_platform_device_count=8")
    # an existing device-count flag wins over a later request
    repro.launch.dryrun.force_host_device_count(512)
    assert "=8" in os.environ["XLA_FLAGS"]
