"""Pallas BRGEMM kernel vs pure-jnp oracle: shape/dtype/epilogue sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.brgemm import batched_matmul, brgemm, matmul
from repro.kernels.brgemm import ref as R
from repro.core.blocking import Blocks, choose_blocks

RNG = np.random.default_rng(1234)


def randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=3e-5, atol=3e-5)


MATMUL_SHAPES = [
    (1, 1, 1),
    (8, 128, 128),
    (7, 33, 17),          # ragged, forces padding on every dim
    (128, 256, 128),      # exact multiples
    (200, 100, 300),
    (256, 512, 64),
    (130, 129, 131),      # just-over-block
]


@pytest.mark.parametrize("m,k,n", MATMUL_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, k, n, dtype):
    x, w = randn(m, k, dtype=dtype), randn(k, n, dtype=dtype)
    got = matmul(x, w, backend="pallas")
    want = matmul(x, w, backend="xla")
    assert got.shape == (m, n) and got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize(
    "act", ["none", "relu", "gelu", "silu", "sigmoid", "tanh"])
def test_matmul_fused_epilogues(act):
    x, w, b = randn(48, 96), randn(96, 64), randn(64)
    got = matmul(x, w, b, activation=act, backend="pallas")
    want = matmul(x, w, b, activation=act, backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_matmul_alpha_beta_c0():
    x, w, c0 = randn(40, 60), randn(60, 50), randn(40, 50)
    got = matmul(x, w, c0=c0, alpha=0.25, beta=-1.5, backend="pallas")
    want = matmul(x, w, c0=c0, alpha=0.25, beta=-1.5, backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("nb", [1, 3, 9])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_brgemm_stacked(nb, dtype):
    a, b = randn(nb, 33, 65, dtype=dtype), randn(nb, 65, 47, dtype=dtype)
    got = brgemm(a, b, backend="pallas")
    want = brgemm(a, b, backend="xla")
    assert got.shape == (33, 47)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype))


def test_brgemm_matches_loop_of_gemms():
    """Semantics check straight from the paper's definition."""
    a, b = randn(6, 16, 24), randn(6, 24, 32)
    got = brgemm(a, b, backend="pallas")
    acc = np.zeros((16, 32), np.float32)
    for i in range(6):
        acc += np.asarray(a[i]) @ np.asarray(b[i])
    np.testing.assert_allclose(np.asarray(got), acc, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("bcast", ["none", "a", "b"])
def test_batched_matmul_broadcast(bcast):
    a = randn(24, 40) if bcast == "a" else randn(4, 24, 40)
    b = randn(40, 56) if bcast == "b" else randn(4, 40, 56)
    got = batched_matmul(a, b, backend="pallas")
    want = batched_matmul(a, b, backend="xla")
    assert got.shape == (4, 24, 56)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("act", ["none", "relu", "gelu", "sigmoid", "tanh"])
def test_matmul_grads_match_ref_autodiff(act):
    x, w, b = randn(24, 48), randn(48, 32), randn(32)

    def lp(x, w, b):
        return (matmul(x, w, b, activation=act, backend="pallas") ** 2).sum()

    def lr(x, w, b):
        return (matmul(x, w, b, activation=act, backend="xla") ** 2).sum()

    gp = jax.grad(lp, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(lr, argnums=(0, 1, 2))(x, w, b)
    for p, r in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(p), np.asarray(r),
                                   rtol=2e-4, atol=2e-4)


def test_brgemm_grads_match_ref_autodiff():
    a, b = randn(3, 16, 24), randn(3, 24, 32)

    def lp(a, b):
        return (brgemm(a, b, activation="silu", backend="pallas") ** 2).sum()

    def lr(a, b):
        return (brgemm(a, b, activation="silu", backend="xla") ** 2).sum()

    gp = jax.grad(lp, argnums=(0, 1))(a, b)
    gr = jax.grad(lr, argnums=(0, 1))(a, b)
    for p, r in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(p), np.asarray(r),
                                   rtol=2e-4, atol=2e-4)


def test_explicit_blocks_respected():
    x, w = randn(64, 256), randn(256, 128)
    got = matmul(x, w, backend="pallas", blocks=Blocks(32, 128, 128))
    want = matmul(x, w, backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_choose_blocks_vmem_budget():
    blk = choose_blocks(4096, 4096, 65536, jnp.bfloat16)
    bm, bn, bk = blk.astuple()
    itemsize = 2
    ws = (bm * bk + bk * bn) * itemsize * 2 + bm * bn * 4 + bm * bn * itemsize * 2
    assert ws <= 8 * 1024 * 1024
    assert bn % 128 == 0 and bk % 128 == 0 and bm % 16 == 0
