"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs; plus prefill/decode parity checks
for a representative subset."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import ShapeCfg
from repro.models import api

SMALL_TRAIN = ShapeCfg("smoke_train", "train", 32, 2)
SMALL_PREFILL = ShapeCfg("smoke_prefill", "prefill", 32, 2)


def _reduced(name):
    return configs.get(name).reduced()


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_train_step_shapes_and_finite(name):
    cfg = _reduced(name)
    shape = SMALL_TRAIN
    batch = api.make_batch(jax.random.PRNGKey(0), cfg, shape)
    params = api.init_params(jax.random.PRNGKey(1), cfg)

    loss, metrics = api.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), (name, metrics)

    grads = jax.grad(lambda p: api.loss_fn(p, batch, cfg)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat), \
        f"{name}: non-finite grads"

    logits, _ = api.forward(params, batch, cfg)
    tl = api.token_len(cfg, shape)
    assert logits.shape == (shape.global_batch, tl, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_prefill_decode_matches_forward(name):
    """Serving path parity: prefill + stepwise decode == train forward."""
    cfg = _reduced(name)
    if cfg.block == "xlstm":
        cfg = dataclasses.replace(cfg, mlstm_chunk=4)
    if cfg.n_experts:
        # dropless capacity so train forward == serve path exactly (the
        # capacity-dropped train approximation is exercised elsewhere)
        cfg = dataclasses.replace(cfg,
                                  moe_capacity_factor=float(cfg.n_experts))
    shape = SMALL_PREFILL
    t_pre, n_dec = 24, 4
    max_len = t_pre + n_dec

    params = api.init_params(jax.random.PRNGKey(1), cfg)
    full_shape = ShapeCfg("tmp", "train", max_len + (cfg.n_patches or 0)
                          + (api.encdec_src_len(cfg, shape)
                             if api.is_encdec(cfg) else 0),
                          shape.global_batch)
    # build a consistent token stream
    key = jax.random.PRNGKey(2)
    b = shape.global_batch
    tokens = jax.random.randint(key, (b, max_len), 0, cfg.vocab, jnp.int32)
    batch_train = {"tokens": tokens, "labels": tokens}
    if cfg.n_patches:
        batch_train["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.n_patches, cfg.d_model))
    if api.is_encdec(cfg):
        batch_train["src_embeds"] = jax.random.normal(
            jax.random.PRNGKey(4), (b, 8, cfg.d_model))

    logits_full, _ = api.forward(params, batch_train, cfg)

    # prefill on the first t_pre tokens; absolute positions include any
    # modality prefix (the serve engine tracks this offset)
    pos_off = cfg.n_patches or 0
    if api.is_encdec(cfg):
        from repro.models import encdec
        cache = encdec.init_cache(cfg, b, max_len, 8)
        batch_pre = {"tokens": tokens[:, :t_pre],
                     "src_embeds": batch_train["src_embeds"]}
    else:
        from repro.models import transformer
        cache = transformer.init_cache(cfg, b, max_len + pos_off)
        batch_pre = {"tokens": tokens[:, :t_pre]}
        if cfg.n_patches:
            batch_pre["patch_embeds"] = batch_train["patch_embeds"]
    logits_pre, cache = api.prefill(params, batch_pre, cfg, cache)
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits_full[:, t_pre - 1]),
        rtol=2e-3, atol=2e-3, err_msg=f"{name} prefill mismatch")

    for i in range(t_pre, max_len):
        logits_i, cache = api.decode_step(
            params, tokens[:, i:i + 1], cfg, cache, i + pos_off)
        np.testing.assert_allclose(
            np.asarray(logits_i), np.asarray(logits_full[:, i]),
            rtol=5e-3, atol=5e-3, err_msg=f"{name} decode step {i}")


def test_param_counts_match_published_scale():
    """Full configs must land near their published parameter counts."""
    expect = {
        "deepseek-v3-671b": (671e9, 0.10),
        "grok-1-314b": (314e9, 0.10),
        "starcoder2-15b": (15e9, 0.15),
        "smollm-135m": (135e6, 0.15),
        "deepseek-coder-33b": (33e9, 0.10),
        "mistral-large-123b": (123e9, 0.10),
        "xlstm-1.3b": (1.3e9, 0.35),
        "llava-next-34b": (34e9, 0.15),
        "recurrentgemma-9b": (9e9, 0.35),
    }
    for name, (target, tol) in expect.items():
        total, _ = configs.get(name).param_counts()
        assert abs(total - target) / target < tol, \
            f"{name}: {total/1e9:.2f}B vs {target/1e9:.2f}B"


def test_deepseek_active_params():
    total, active = configs.get("deepseek-v3-671b").param_counts()
    assert active < total * 0.12  # ~37B active of 671B
