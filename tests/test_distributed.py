"""Distribution tests: sharding rules, small-mesh pjit training parity,
pipeline parallelism (all on forced host devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.sharding import rules

# NOTE: multi-device behaviours run in subprocesses so this test module can
# keep the default 1-device config (per the dry-run isolation rule).

_SUBPROC_ENV = {**os.environ,
                "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
                "PYTHONPATH": "src"}


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=_SUBPROC_ENV,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_param_spec_rules():
    # spec derivation is mesh-shape arithmetic; use abstract mesh via
    # production mesh on 512 fake devices is heavy — use small subprocess
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.sharding.rules import param_spec
        mesh = make_mesh((2, 4), ("data", "model"))

        class KP:
            def __init__(self, key): self.key = key

        # column-parallel qkv: (embed, heads*dh)
        s = param_spec((KP("blocks"), KP("attn"), KP("wq")), (30, 512, 256),
                       mesh)
        assert s == P(None, ("data",), "model"), s
        # row-parallel wo
        s = param_spec((KP("attn"), KP("wo")), (256, 512), mesh)
        assert s == P("model", ("data",)), s
        # embedding (vocab, embed)
        s = param_spec((KP("embed"), KP("table")), (1024, 512), mesh)
        assert s == P("model", ("data",)), s
        # MoE expert stack (L, E, D, F): expert on model
        s = param_spec((KP("moe"), KP("w_gate")), (4, 8, 64, 128), mesh)
        assert s == P(None, "model", ("data",), None), s
        # indivisible dims fall back to replication
        s = param_spec((KP("attn"), KP("wq")), (30, 7, 9), mesh)
        assert s == P(None, None, None), s
        # scalars
        s = param_spec((KP("opt"), KP("step")), (), mesh)
        assert s == P(), s
        print("rules-ok")
    """)
    assert "rules-ok" in out


def test_small_mesh_train_matches_single_device():
    """pjit on a 2x2 mesh must reproduce single-device training losses."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.configs.shapes import ShapeCfg
        from repro.launch.mesh import make_mesh
        from repro.launch.train import run

        cfg = configs.get("smollm-135m").reduced()
        shape = ShapeCfg("t", "train", 32, 4)
        mesh1 = make_mesh((1, 1), ("data", "model"))
        _, l1 = run(cfg, shape, mesh=mesh1, steps=4, log_every=100)
        mesh4 = make_mesh((2, 2), ("data", "model"))
        _, l4 = run(cfg, shape, mesh=mesh4, steps=4, log_every=100)
        np.testing.assert_allclose(l1, l4, rtol=2e-3, atol=2e-3)
        print("parity-ok", l1, l4)
    """)
    assert "parity-ok" in out


def test_pipeline_parallel_parity():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.pipeline import pipeline_apply
        mesh = make_mesh((4,), ("stage",))
        S, M, mb, d = 4, 8, 2, 16
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(S, d, d)) * (1/d)**0.5, jnp.float32)
        x = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)
        layer = lambda p, h: jax.nn.relu(h @ p["w"])
        y = pipeline_apply({"w": w}, x, layer, mesh=mesh, n_microbatches=M)
        ref = x
        for s in range(S):
            ref = jax.nn.relu(ref @ w[s])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("pp-ok")
    """)
    assert "pp-ok" in out


def test_make_production_mesh_shapes():
    out = _run("""
        import os
        # this subprocess uses 8 devices; production mesh needs 512 — only
        # check the axis bookkeeping helpers here
        from repro.launch.mesh import make_mesh, dp_axes, dp_size, model_size
        m = make_mesh((2, 4), ("data", "model"))
        assert dp_axes(m) == ("data",)
        assert dp_size(m) == 2 and model_size(m) == 4
        m2 = make_mesh((2, 2, 2), ("pod", "data", "model"))
        assert dp_axes(m2) == ("pod", "data")
        assert dp_size(m2) == 4
        print("mesh-ok")
    """)
    assert "mesh-ok" in out


def test_dryrun_artifacts_complete():
    """Every (arch x shape x mesh) cell has a recorded outcome, and every
    recorded outcome is ok or an explained skip."""
    import json
    import pathlib
    art = pathlib.Path(__file__).parent.parent / "benchmarks" / "artifacts"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated yet")
    from repro.configs.shapes import SHAPES
    missing, bad = [], []
    for arch in configs.ARCH_NAMES:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                f = art / f"dryrun_{arch}_{shape}_{mesh}.json"
                if not f.exists():
                    missing.append(f.name)
                    continue
                rec = json.loads(f.read_text())
                if rec["status"] == "error":
                    bad.append(f.name)
                elif rec["status"] == "skipped" and not rec.get("reason"):
                    bad.append(f.name)
    assert not missing, f"missing cells: {missing}"
    assert not bad, f"failed cells: {bad}"
