"""Hypothesis property tests on the batch-reduce GEMM invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: install via `pip install -e ".[test]"`
from hypothesis import given, settings, strategies as st

from repro.kernels.brgemm import brgemm, matmul

_dims = st.integers(min_value=1, max_value=48)
_batch = st.integers(min_value=1, max_value=5)


def _arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(nb=_batch, m=_dims, k=_dims, n=_dims, seed=st.integers(0, 2**31 - 1))
def test_batch_split_associativity(nb, m, k, n, seed):
    """sum_i A_i B_i == brgemm(first half) + brgemm(second half).

    This is the invariant that makes the kernel's grid-order free: the
    reduction over the block batch can be split at any point.
    """
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, nb, m, k), _arr(rng, nb, k, n)
    whole = brgemm(a, b, backend="pallas")
    if nb == 1:
        np.testing.assert_allclose(
            np.asarray(whole),
            np.asarray(brgemm(a[:1], b[:1], backend="pallas")),
            rtol=1e-4, atol=1e-4)
        return
    s = nb // 2
    first = brgemm(a[:s], b[:s], backend="pallas")
    both = brgemm(a[s:], b[s:], c0=first, beta=1.0, backend="pallas")
    np.testing.assert_allclose(np.asarray(whole), np.asarray(both),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(m=_dims, k=_dims, n=_dims, seed=st.integers(0, 2**31 - 1),
       alpha=st.floats(-2, 2, allow_nan=False), beta=st.floats(-2, 2))
def test_alpha_beta_linearity(m, k, n, seed, alpha, beta):
    rng = np.random.default_rng(seed)
    x, w, c0 = _arr(rng, m, k), _arr(rng, k, n), _arr(rng, m, n)
    got = matmul(x, w, c0=c0, alpha=alpha, beta=beta, backend="pallas")
    want = alpha * np.asarray(x) @ np.asarray(w) + beta * np.asarray(c0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(nb=_batch, m=_dims, k=_dims, n=_dims, seed=st.integers(0, 2**31 - 1))
def test_brgemm_reduction_is_permutation_invariant(nb, m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, nb, m, k), _arr(rng, nb, k, n)
    perm = rng.permutation(nb)
    y1 = brgemm(a, b, backend="pallas")
    y2 = brgemm(a[perm], b[perm], backend="pallas")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=_dims, k=_dims, n=_dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_pallas_equals_xla_path(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, m, k), _arr(rng, k, n)
    np.testing.assert_allclose(
        np.asarray(matmul(x, w, backend="pallas")),
        np.asarray(matmul(x, w, backend="xla")),
        rtol=1e-4, atol=1e-4)
