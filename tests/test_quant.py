"""Quantized building block: QuantConfig validation, quantize/dequantize
round-trips, int8-vs-fp32 tolerance bands per op (GEMM, conv-as-GEMM,
attention projections), pallas<->xla parity, offline calibration,
quant-tagged tuning-cache keys/persistence, and int8-decode serve parity."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import autotune, dispatch
from repro.core.quantize import (
    QuantConfig,
    QuantizedTensor,
    as_quant_config,
    calibrate_params,
    dequantize,
    quantize,
    quantize_weight,
)
from repro.kernels.brgemm import batched_matmul, brgemm, matmul
from repro.kernels.conv2d import conv2d


def _randn(*shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed + len(shape))
    return jnp.asarray(rng.normal(size=shape), dtype)


def _rel(got, want):
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    return np.linalg.norm(got - want) / max(np.linalg.norm(want), 1e-30)


@pytest.fixture(autouse=True)
def _fresh_cache():
    dispatch.clear_tuning_cache()
    yield
    dispatch.clear_tuning_cache()


# --------------------------------------------------------------------------
# config
# --------------------------------------------------------------------------

def test_quant_config_validates_fields():
    with pytest.raises(ValueError, match="w_dtype"):
        QuantConfig(w_dtype="int4")
    with pytest.raises(ValueError, match="granularity"):
        QuantConfig(granularity="per_block")
    with pytest.raises(ValueError, match="calibration"):
        QuantConfig(calibration="percentile")
    assert QuantConfig().integer
    assert not QuantConfig(w_dtype="float8_e4m3fn",
                           a_dtype="float8_e4m3fn").integer


def test_as_quant_config_shorthands_and_tag_round_trip():
    int8 = as_quant_config("int8")
    assert int8 == QuantConfig()
    fp8 = as_quant_config("fp8")
    assert fp8.w_dtype == "float8_e4m3fn"
    assert as_quant_config("float8_e5m2").a_dtype == "float8_e5m2"
    assert as_quant_config(int8.tag()) == int8          # tag round-trips
    assert as_quant_config({"granularity": "per_tensor"}).granularity \
        == "per_tensor"
    assert as_quant_config(int8) is int8
    with pytest.raises(ValueError, match="unknown quant spec"):
        as_quant_config("int16")
    with pytest.raises(TypeError):
        as_quant_config(8)


# --------------------------------------------------------------------------
# quantize / dequantize round-trips
# --------------------------------------------------------------------------

def test_quantize_round_trip_per_channel():
    w = _randn(64, 32, seed=1)
    q, scale = quantize(w, "int8", axis=(-2,))
    assert q.dtype == jnp.int8 and scale.shape == (32,)
    # absmax scaling: each entry reconstructs to within half an lsb
    err = np.abs(np.asarray(dequantize(q, scale)) - np.asarray(w))
    assert (err <= np.asarray(scale)[None, :] * 0.5 + 1e-7).all()


def test_quantize_per_tensor_scalar_scale():
    w = _randn(16, 8, seed=2)
    q, scale = quantize(w, "int8", axis=None)
    assert scale.shape == ()
    assert _rel(dequantize(q, scale), w) < 0.02


def test_quantize_zero_channel_guard():
    w = np.array(_randn(16, 4, seed=3))
    w[:, 2] = 0.0                                # an all-zero channel
    q, scale = quantize(jnp.asarray(w), "int8", axis=(-2,))
    deq = np.asarray(dequantize(q, scale))
    assert np.isfinite(deq).all()
    assert (deq[:, 2] == 0.0).all()


def test_quantize_unknown_dtype_and_bad_weight_rank():
    with pytest.raises(ValueError, match="storage dtype"):
        quantize(_randn(4, 4), "int4")
    with pytest.raises(ValueError, match=">= 2-D"):
        quantize_weight(_randn(8), "int8")


# --------------------------------------------------------------------------
# int8 vs fp32 tolerance bands, per op, through the public entry points
# --------------------------------------------------------------------------

def test_matmul_int8_band_and_epilogue_fusion():
    x, w = _randn(24, 48, seed=4), _randn(48, 32, seed=5)
    bias = _randn(32, seed=6)
    want = matmul(x, w, bias, activation="gelu", alpha=1.5, backend="xla")
    got = matmul(x, w, bias, activation="gelu", alpha=1.5, quant="int8")
    assert _rel(got, want) < 0.03
    assert not np.array_equal(np.asarray(got), np.asarray(want))


def test_brgemm_int8_band():
    xs, ws = _randn(3, 16, 32, seed=7), _randn(3, 32, 24, seed=8)
    want = brgemm(xs, ws, backend="xla")
    got = brgemm(xs, ws, quant="int8")
    assert _rel(got, want) < 0.03


def test_batched_matmul_int8_band():
    a, b = _randn(3, 16, 32, seed=9), _randn(3, 32, 8, seed=10)
    want = batched_matmul(a, b, backend="xla")
    got = batched_matmul(a, b, quant="int8")
    assert _rel(got, want) < 0.03


def test_conv_as_gemm_int8_band():
    """im2col patches x reshaped filter IS the conv; quantize that GEMM."""
    x, w = _randn(2, 6, 6, 3, seed=11), _randn(3, 3, 3, 8, seed=12) * 0.3
    xn, wn = np.asarray(x), np.asarray(w)
    patches = np.stack([
        xn[n, p:p + 3, q:q + 3, :].ravel()
        for n in range(2) for p in range(4) for q in range(4)])
    x2, w2 = jnp.asarray(patches), jnp.asarray(wn.reshape(27, 8))
    want = conv2d(x, w, stride=1, padding=0, backend="xla")
    gemm32 = matmul(x2, w2, backend="xla").reshape(2, 4, 4, 8)
    np.testing.assert_allclose(np.asarray(gemm32), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    got = matmul(x2, w2, quant="int8").reshape(2, 4, 4, 8)
    assert _rel(got, want) < 0.03


def test_attention_projections_quantize_with_zero_call_site_changes():
    from repro.layers import attention as attn
    from repro.layers.attention import AttnCfg
    cfg = AttnCfg(d_model=64, n_heads=4, n_kv_heads=4)
    p = attn.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64)) * 0.5
    want = attn.apply(p, x, cfg, mode="train")
    with repro.use(quant="int8"):                # no call-site changes
        got = attn.apply(p, x, cfg, mode="train")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.1, atol=0.1)
    assert not np.array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# pallas <-> xla parity on the quantized path
# --------------------------------------------------------------------------

def test_matmul_q_pallas_xla_parity_with_epilogue():
    x, w = _randn(24, 48, seed=13), _randn(48, 32, seed=14)
    bias = _randn(32, seed=15)
    kw = dict(activation="gelu", alpha=1.5, quant="int8")
    got = matmul(x, w, bias, backend="pallas", **kw)
    want = matmul(x, w, bias, backend="xla", **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op,shapes", [
    (brgemm, ((3, 16, 32), (3, 32, 24))),
    (batched_matmul, ((3, 16, 32), (3, 32, 8))),
])
def test_rank3_q_pallas_xla_parity(op, shapes):
    a, b = _randn(*shapes[0], seed=16), _randn(*shapes[1], seed=17)
    got = op(a, b, backend="pallas", quant="int8")
    want = op(a, b, backend="xla", quant="int8")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_per_tensor_granularity_parity_and_band():
    x, w = _randn(16, 64, seed=18), _randn(64, 16, seed=19)
    q = QuantConfig(granularity="per_tensor", a_granularity="per_tensor")
    want = matmul(x, w, backend="xla")
    got_p = matmul(x, w, backend="pallas", quant=q)
    got_x = matmul(x, w, backend="xla", quant=q)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(got_x),
                               rtol=1e-5, atol=1e-5)
    assert _rel(got_x, want) < 0.05              # coarser scales, wider band


# --------------------------------------------------------------------------
# fallbacks and refusals
# --------------------------------------------------------------------------

@pytest.mark.skipif(jax.default_backend() != "cpu",
                    reason="fp8 pallas gate is CPU-specific")
def test_fp8_falls_back_to_xla_on_cpu_and_explicit_pallas_refuses():
    x, w = _randn(8, 32, seed=20), _randn(32, 16, seed=21)
    got = matmul(x, w, quant="fp8")              # silent xla fallback
    assert _rel(got, matmul(x, w, backend="xla")) < 0.2
    with pytest.raises(RuntimeError, match="pallas"):
        matmul(x, w, quant="fp8", backend="pallas")


def test_mixed_int8_fp8_families_unsupported():
    x, w = _randn(8, 16, seed=22), _randn(16, 8, seed=23)
    mixed = QuantConfig(w_dtype="int8", a_dtype="float8_e4m3fn")
    with pytest.raises(NotImplementedError):
        matmul(x, w, quant=mixed)


def test_ambient_quant_degrades_accumulator_chains_explicit_raises():
    x, w = _randn(8, 16, seed=24), _randn(16, 8, seed=25)
    c0 = _randn(8, 8, seed=26)
    want = matmul(x, w, None, c0, beta=1.0, backend="xla")
    with repro.use(quant="int8"):                # LSTM-gate style chaining
        got = matmul(x, w, None, c0, beta=1.0, backend="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    with pytest.raises(NotImplementedError):
        matmul(x, w, None, c0, beta=1.0, quant="int8")


# --------------------------------------------------------------------------
# context resolution
# --------------------------------------------------------------------------

def test_resolve_quant_precedence_and_nesting():
    assert dispatch.resolve_quant() is None
    with repro.use(quant="int8"):
        assert dispatch.resolve_quant() == QuantConfig()
        # explicit spec beats the ambient context
        assert dispatch.resolve_quant("fp8").w_dtype == "float8_e4m3fn"
        with repro.use(quant="fp8"):
            assert dispatch.resolve_quant().w_dtype == "float8_e4m3fn"
        assert dispatch.resolve_quant() == QuantConfig()
    assert dispatch.resolve_quant() is None


# --------------------------------------------------------------------------
# offline calibration
# --------------------------------------------------------------------------

def test_calibrate_params_selects_gemm_weights_only():
    params = {
        "wq": _randn(16, 16, seed=29),
        "w_stack": _randn(2, 16, 16, seed=30),
        "wkv_b": _randn(16, 16, seed=31),        # denylisted (MLA einsum)
        "bias": _randn(16, seed=32),
        "norm": {"w": _randn(16, seed=33)},      # 1-D: never quantized
    }
    qp = calibrate_params(params, "int8")
    assert isinstance(qp["wq"], QuantizedTensor)
    assert isinstance(qp["w_stack"], QuantizedTensor)
    assert qp["w_stack"].scale.shape == (2, 16)  # per-layer channel scales
    assert not isinstance(qp["wkv_b"], QuantizedTensor)
    assert not isinstance(qp["bias"], QuantizedTensor)
    assert not isinstance(qp["norm"]["w"], QuantizedTensor)
    # idempotent: re-calibrating leaves QuantizedTensors alone
    assert calibrate_params(qp, "int8")["wq"] is qp["wq"]


def test_calibrated_weight_matches_dynamic_quant_exactly():
    x, w = _randn(8, 32, seed=34), _randn(32, 16, seed=35)
    dyn = matmul(x, w, quant="int8")
    cal = matmul(x, quantize_weight(w, "int8"))  # no context needed
    np.testing.assert_array_equal(np.asarray(dyn), np.asarray(cal))


def test_quantized_tensor_scans_leaf_wise():
    x = _randn(4, 16, seed=36)
    ws = _randn(3, 16, 16, seed=37)              # stacked per-layer weights
    qt = quantize_weight(ws, "int8")

    def body(h, layer_w):
        return h, matmul(x, layer_w)

    _, ys = jax.lax.scan(body, 0, qt)
    for i in range(3):
        want = matmul(x, QuantizedTensor(qt.q[i], qt.scale[i]))
        np.testing.assert_allclose(np.asarray(ys[i]), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# tuning cache: quant-tagged keys, JSON persistence, back-compat
# --------------------------------------------------------------------------

def test_quant_tags_key_the_cache_separately(tmp_path):
    qcfg = as_quant_config("int8")
    b_fp = dispatch.resolve_blocks("brgemm", 64, 128, 128, jnp.float32,
                                   backend="pallas")
    b_q = dispatch.resolve_blocks("brgemm", 64, 128, 128, jnp.int8,
                                  backend="pallas", quant=qcfg)
    assert b_fp is not None and b_q is not None
    keys = list(dispatch.tuning_cache_info())
    assert len(keys) == 2
    assert {k[-1] for k in keys} == {None, qcfg.tag()}

    path = tmp_path / "cache.json"
    dispatch.save_cache(str(path))
    entries = json.loads(path.read_text())["entries"]
    assert {e.get("quant") for e in entries} == {None, qcfg.tag()}
    dispatch.clear_tuning_cache()
    assert dispatch.load_cache(str(path)) == 2
    assert set(dispatch.tuning_cache_info()) == set(keys)


def test_pre_quant_cache_files_still_load(tmp_path):
    dispatch.resolve_blocks("brgemm", 64, 128, 128, jnp.float32,
                            backend="pallas")
    path = tmp_path / "cache.json"
    dispatch.save_cache(str(path))
    doc = json.loads(path.read_text())
    for e in doc["entries"]:                     # strip the quant field —
        e.pop("quant", None)                     # the pre-quant file format
    path.write_text(json.dumps(doc))
    dispatch.clear_tuning_cache()
    assert dispatch.load_cache(str(path)) == 1
    (key,) = dispatch.tuning_cache_info()
    assert key[-1] is None


def test_int8_autotune_measures_then_memoizes():
    qcfg = as_quant_config("int8")
    before = autotune.STATS.measured

    def policy(op, m, n, k, dt, be, quant=None):
        return autotune.autotune_blocks(op, m, n, k, dt, be, quant=quant,
                                        max_candidates=2, repeats=1)

    with repro.use(blocks_policy=policy):
        b1 = dispatch.resolve_blocks("brgemm", 64, 128, 128, jnp.int8,
                                     backend="pallas", quant=qcfg)
        mid = autotune.STATS.measured
        b2 = dispatch.resolve_blocks("brgemm", 64, 128, 128, jnp.int8,
                                     backend="pallas", quant=qcfg)
    assert mid - before > 0                      # really measured int8 runs
    assert autotune.STATS.measured == mid        # second resolve is a hit
    assert b1 == b2


# --------------------------------------------------------------------------
# serving: int8 decode tier
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense():
    from repro import configs
    from repro.models import api
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_int8_decode_greedy_parity_static_vs_continuous(dense):
    from repro.serve import (ContinuousEngine, Engine, PoolConfig, Request,
                             ServeConfig)
    cfg, params = dense
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in (5, 9, 3, 12)]
    max_tokens = [6, 4, 8, 3]

    static = Engine(cfg, params, ServeConfig(max_len=32),
                    decode_quant="int8")
    want = []
    for p, mt in zip(prompts, max_tokens):
        ids = static.generate({"tokens": jnp.asarray([p], jnp.int32)},
                              n_tokens=mt, stop_tokens=())
        want.append(np.asarray(ids)[0].tolist())

    cont = ContinuousEngine(cfg, params, PoolConfig(n_slots=2, max_len=32),
                            decode_quant="int8")
    out = cont.serve([Request(prompt=p, max_tokens=mt, stop_tokens=())
                      for p, mt in zip(prompts, max_tokens)])
    got = [out[i] for i in sorted(out)]
    assert got == want                           # token-for-token greedy


def test_calibrated_params_serve_end_to_end(dense):
    from repro.serve import ContinuousEngine, PoolConfig, Request
    cfg, params = dense
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, 4 + i).tolist(),
                    max_tokens=3, stop_tokens=()) for i in range(4)]
    eng = ContinuousEngine(cfg, calibrate_params(params, "int8"),
                           PoolConfig(n_slots=2, max_len=32))
    out = eng.serve(reqs)
    assert sorted(out) == list(range(4))
    assert all(len(toks) == 3 for toks in out.values())
