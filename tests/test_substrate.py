"""Substrate tests: checkpoint/restart, fault tolerance, data pipeline,
optimizer, gradient compression, serving engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_testlib import given, settings, st  # optional-hypothesis shim

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.shapes import ShapeCfg
from repro.data.pipeline import TokenPipeline
from repro.distributed.collectives import (
    compress_grads, compress_with_error_feedback, decompress_grads)
from repro.models import api
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor, StragglerDetector, WorkerFailure, run_with_restarts)
from repro.serve.engine import Engine, ServeConfig
from repro.train import optimizer as opt


# ------------------------------ checkpoint ------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "c": (jnp.ones((3,)), jnp.zeros((2, 2)))}}


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    ckpt.save(3, t)
    restored, step = ckpt.restore(jax.tree.map(jnp.zeros_like, t))
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_latest(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(s, _tree(s))
    assert ckpt.latest_step() == 4
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checkpoint_async(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    ckpt.save_async(7, _tree())
    ckpt.wait()
    assert ckpt.latest_step() == 7


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Restore under a different device layout (elastic rescale)."""
    ckpt = CheckpointManager(tmp_path)
    t = _tree()
    ckpt.save(1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), t)
    restored, _ = ckpt.restore(t, shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------- fault tolerance ----------------------------

def test_run_with_restarts_recovers(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=5)
    fail_at = {7, 13}

    def step_fn(state, step):
        if step in fail_at:
            fail_at.discard(step)          # fail once per step
            raise WorkerFailure(f"sim fail at {step}")
        return {"x": state["x"] + 1}

    state, restarts, executed = run_with_restarts(
        total_steps=20, ckpt=ckpt, make_state=lambda: {"x": jnp.zeros(())},
        step_fn=step_fn, save_every=5)
    assert restarts == 2
    assert int(state["x"]) == 20 - 0  # every step effect applied exactly...
    # ...at-least-once between checkpoints; final value >= steps since resume
    assert int(state["x"]) >= 15


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(4, timeout_s=10)
    for h in range(4):
        hb.beat(h, step=1, now=100.0)
    hb.beat(0, 2, now=120.0)
    hb.beat(1, 2, now=120.0)
    hb.beat(2, 2, now=120.0)
    assert hb.dead_hosts(now=120.0) == [3]


def test_straggler_detector():
    sd = StragglerDetector(4, factor=2.0, patience=2)
    flagged = sd.observe({0: 1.0, 1: 1.0, 2: 1.1, 3: 5.0})
    assert flagged == []
    flagged = sd.observe({0: 1.0, 1: 1.0, 2: 0.9, 3: 5.0})
    assert flagged == [3]


# ------------------------------ data pipeline ---------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = configs.get("smollm-135m").reduced()
    shape = ShapeCfg("t", "train", 16, 4)
    p1 = TokenPipeline(cfg, shape, seed=3)
    batches1 = [next(p1) for _ in range(4)]
    p1.close()
    # resume from step 2 reproduces batches 2,3 exactly
    p2 = TokenPipeline(cfg, shape, seed=3, start_step=2)
    batches2 = [next(p2) for _ in range(2)]
    p2.close()
    np.testing.assert_array_equal(batches1[2]["tokens"],
                                  batches2[0]["tokens"])
    np.testing.assert_array_equal(batches1[3]["tokens"],
                                  batches2[1]["tokens"])


def test_pipeline_host_sharding_disjoint_streams():
    cfg = configs.get("smollm-135m").reduced()
    shape = ShapeCfg("t", "train", 16, 4)
    a = TokenPipeline(cfg, shape, seed=0, host_id=0, n_hosts=2)
    b = TokenPipeline(cfg, shape, seed=0, host_id=1, n_hosts=2)
    ba, bb = next(a), next(b)
    a.close(), b.close()
    assert ba["tokens"].shape[0] == 2
    assert not np.array_equal(ba["tokens"], bb["tokens"])


# ------------------------------ optimizer -------------------------------

def test_adamw_matches_reference_math():
    cfg = opt.AdamWCfg(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                       grad_clip=1e9)
    params = {"w": jnp.asarray([1.0, -2.0])}
    state = opt.adamw_init(params, cfg)
    g = {"w": jnp.asarray([0.5, -0.5])}
    state, _ = opt.adamw_update(g, state, cfg)
    # step 1: mhat = g, vhat = g^2 -> update = lr * g/|g| = lr * sign(g)
    want = np.asarray([1.0, -2.0]) - 0.1 * np.sign([0.5, -0.5])
    np.testing.assert_allclose(np.asarray(state["master"]["w"]), want,
                               rtol=1e-5)


def test_adamw_grad_clip():
    cfg = opt.AdamWCfg(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = opt.adamw_init(params, cfg)
    g = {"w": jnp.full((4,), 100.0)}
    state, metrics = opt.adamw_update(g, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    assert np.isfinite(np.asarray(state["master"]["w"])).all()


# --------------------------- grad compression ---------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_int8_compression_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    q, s = compress_grads(g, kind="int8")
    deq = decompress_grads(q, s, kind="int8")
    scale = float(np.abs(np.asarray(g["w"])).max()) / 127.0
    assert float(jnp.abs(deq["w"] - g["w"]).max()) <= scale * 0.5 + 1e-7


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(256,)) * 1e-3, jnp.float32)}
    residual = None
    acc_plain = np.zeros(256, np.float32)
    acc_ef = np.zeros(256, np.float32)
    for _ in range(50):
        q, s = compress_grads(g, kind="int8")
        acc_plain += np.asarray(decompress_grads(q, s, kind="int8")["w"])
        deq, residual = compress_with_error_feedback(g, residual,
                                                     kind="int8")
        acc_ef += np.asarray(deq["w"])
    true = np.asarray(g["w"]) * 50
    assert np.abs(acc_ef - true).max() <= np.abs(acc_plain - true).max() + 1e-6


# ------------------------------ serving ---------------------------------

def test_engine_greedy_generation_deterministic():
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab, jnp.int32)}
    out1 = eng.generate(batch, n_tokens=6)
    out2 = eng.generate(batch, n_tokens=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_engine_matches_forward_argmax():
    """Greedy serve path must reproduce train-forward argmax next-token."""
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab, jnp.int32)
    logits, _ = api.forward(params, {"tokens": tokens, "labels": tokens},
                            cfg)
    want_first = np.argmax(np.asarray(logits[:, -1]), axis=-1)
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    out = eng.generate({"tokens": tokens}, n_tokens=1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), want_first)
