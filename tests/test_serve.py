"""Serving subsystem tests: scheduler invariants, slot-pool hygiene, and
greedy token-for-token parity between ``ContinuousEngine`` and the static
``Engine`` across ragged prompt lengths and an enc-dec config."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.serve import (
    ContinuousEngine,
    Engine,
    PoolConfig,
    Request,
    Scheduler,
    ServeConfig,
    completed_lengths,
)

MAX_LEN = 32
SRC_LEN = 6

PROMPT_LENS = [5, 9, 3, 12, 7]
MAX_TOKENS = [6, 4, 8, 3, 5]


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def seamless():
    cfg = configs.get("seamless-m4t-large-v2").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, n).tolist() for n in lens]


def _static_per_request(cfg, params, prompts, max_tokens, *, src=None):
    """Greedy reference: one static B=1 generate per request."""
    eng = Engine(cfg, params, ServeConfig(max_len=MAX_LEN,
                                          src_len=SRC_LEN if src else 0))
    out = []
    for i, (p, mt) in enumerate(zip(prompts, max_tokens)):
        batch = {"tokens": jnp.asarray([p], jnp.int32)}
        if src is not None:
            batch["src_embeds"] = src[i][None]
        ids = eng.generate(batch, n_tokens=mt, stop_tokens=())
        out.append(np.asarray(ids)[0].tolist())
    return out


# ==========================================================================
# scheduler unit invariants (no jax)
# ==========================================================================

def test_scheduler_fcfs_and_finish_bookkeeping():
    s = Scheduler()
    ids = [s.submit(Request(prompt=[1], max_tokens=2), stop_tokens=(9,))
           for _ in range(3)]
    assert [s.next_waiting().request_id for _ in range(3)] == ids
    assert s.next_waiting() is None

    s = Scheduler()
    rid = s.submit(Request(prompt=[1], max_tokens=3), stop_tokens=(9,))
    st = s.next_waiting()
    s.start(st, slot=0, step=1)
    assert not s.record_token(st, 4, step=1)
    assert st.first_token_step == 1
    assert s.record_token(st, 9, step=2)          # stop token
    assert st.finish_reason == "stop"
    assert st.finish_step == 2
    assert not s.running and s.finished[rid] is st


def test_scheduler_priority_hook():
    s = Scheduler(priority_fn=lambda r: r.priority)
    a = s.submit(Request(prompt=[1], max_tokens=1, priority=0.0))
    b = s.submit(Request(prompt=[1], max_tokens=1, priority=5.0))
    c = s.submit(Request(prompt=[1], max_tokens=1, priority=0.0))
    order = [s.next_waiting().request_id for _ in range(3)]
    assert order == [b, a, c]   # priority first, FCFS among ties


def test_scheduler_max_tokens_finish():
    s = Scheduler()
    s.submit(Request(prompt=[1], max_tokens=2), stop_tokens=())
    st = s.next_waiting()
    s.start(st, slot=0, step=1)
    assert not s.record_token(st, 4, step=1)
    assert s.record_token(st, 5, step=2)
    assert st.finish_reason == "length"
    assert st.generated == [4, 5]


# ==========================================================================
# static engine satellites: PRNG hygiene + early stop
# ==========================================================================

def test_engine_prng_no_key_reuse(dense, monkeypatch):
    """The first sample must use a *split* of the caller's key, not the key
    itself (which the loop then splits again, correlating steps 1 and 2)."""
    cfg, params = dense
    eng = Engine(cfg, params, ServeConfig(max_len=MAX_LEN, temperature=1.0))
    seen = []
    orig = jax.random.categorical

    def spy(key, *a, **kw):
        seen.append(tuple(np.asarray(key).tolist()))
        return orig(key, *a, **kw)

    monkeypatch.setattr(jax.random, "categorical", spy)
    root = jax.random.PRNGKey(42)
    eng.generate({"tokens": jnp.zeros((2, 4), jnp.int32)}, n_tokens=3,
                 key=root, stop_tokens=())
    assert len(seen) == 3
    assert len(set(seen)) == 3, "sampling keys must be distinct"
    assert tuple(np.asarray(root).tolist()) not in seen, \
        "the caller's key must never be consumed directly"


def test_engine_early_stop(dense):
    cfg, params = dense
    eng = Engine(cfg, params, ServeConfig(max_len=MAX_LEN))
    batch = {"tokens": jnp.asarray(_prompts(cfg, [6], seed=3), jnp.int32)}
    base = np.asarray(eng.generate(batch, n_tokens=8, stop_tokens=()))
    stop = int(base[0, 2])

    ids = np.asarray(eng.generate(batch, n_tokens=8, stop_tokens=(stop,)))
    hit = int(np.nonzero(base[0] == stop)[0][0])
    assert ids.shape[1] == hit + 1          # loop ended at the stop token
    np.testing.assert_array_equal(ids[0], base[0, :hit + 1])
    assert completed_lengths(ids, (stop,)).tolist() == [hit + 1]

    # EOS from ArchCfg is the default stop set
    cfg_eos = dataclasses.replace(cfg, eos_token=stop)
    eng_eos = Engine(cfg_eos, params, ServeConfig(max_len=MAX_LEN))
    ids_eos = np.asarray(eng_eos.generate(batch, n_tokens=8))
    np.testing.assert_array_equal(ids_eos, ids)


def test_completed_lengths_no_stops():
    ids = np.arange(6).reshape(2, 3)
    assert completed_lengths(ids, ()).tolist() == [3, 3]
    assert completed_lengths(ids, (1,)).tolist() == [2, 3]


# ==========================================================================
# continuous engine: parity + pool hygiene + metrics
# ==========================================================================

def test_continuous_greedy_parity_ragged_and_no_slot_leaks(dense):
    """Requests outnumber slots (churn + mid-stream joins); greedy outputs
    must match the static engine token-for-token, and the pool must drain
    with alloc_count == free_count."""
    cfg, params = dense
    prompts = _prompts(cfg, PROMPT_LENS)
    static = _static_per_request(cfg, params, prompts, MAX_TOKENS)

    ce = ContinuousEngine(cfg, params, PoolConfig(n_slots=3,
                                                  max_len=MAX_LEN))
    out = ce.serve([Request(prompt=p, max_tokens=mt, stop_tokens=())
                    for p, mt in zip(prompts, MAX_TOKENS)])
    for i, rid in enumerate(sorted(out)):
        assert out[rid] == static[i], f"request {i} diverged"

    # slot hygiene: full drain, no leaks, no double accounting
    assert ce.pool.n_free == ce.pool.n_slots
    assert ce.pool.alloc_count == ce.pool.free_count == len(prompts)
    assert not ce.scheduler.has_work()
    assert (ce.pool.lengths == 0).all() and (ce.pool.positions == 0).all()

    # metrics sanity
    m = ce.metrics
    assert m.tokens_generated == sum(len(v) for v in out.values())
    assert m.requests_submitted == m.requests_completed == len(prompts)
    assert m.prefills == len(prompts)
    assert 0.0 < m.occupancy() <= 1.0
    assert m.ttft_count == len(prompts)
    assert m.max_queue_depth == len(prompts)  # all queued before step 1
    assert m.wall_time_s > 0 and m.tokens_per_s() > 0


def test_continuous_early_stop_parity(dense):
    """A request that hits EOS finishes early and matches the truncated
    static output."""
    cfg, params = dense
    prompts = _prompts(cfg, [6, 4])
    static = _static_per_request(cfg, params, prompts, [8, 8])
    stop = static[0][2]   # greedy token the first request will emit

    cfg_eos = dataclasses.replace(cfg, eos_token=stop)
    ce = ContinuousEngine(cfg_eos, params,
                          PoolConfig(n_slots=2, max_len=MAX_LEN))
    out = ce.serve([Request(prompt=p, max_tokens=8) for p in prompts])
    lens = completed_lengths(np.asarray([static[0]]), (stop,))
    assert out[0] == static[0][:lens[0]]
    assert out[0][-1] == stop
    assert ce.scheduler.finished[0].finish_reason == "stop"
    exp1 = static[1][:completed_lengths(np.asarray([static[1]]),
                                        (stop,))[0]]
    assert out[1] == exp1


def test_continuous_bucketed_prefill_parity(dense):
    """Right-padded bucketed prefill must not perturb greedy outputs."""
    cfg, params = dense
    prompts = _prompts(cfg, PROMPT_LENS)
    static = _static_per_request(cfg, params, prompts, MAX_TOKENS)
    ce = ContinuousEngine(
        cfg, params,
        PoolConfig(n_slots=3, max_len=MAX_LEN, prefill_bucket=8))
    out = ce.serve([Request(prompt=p, max_tokens=mt, stop_tokens=())
                    for p, mt in zip(prompts, MAX_TOKENS)])
    for i, rid in enumerate(sorted(out)):
        assert out[rid] == static[i]


def test_bucketing_rejected_for_recurrent_archs(dense):
    cfg, params = dense
    rg = configs.get("recurrentgemma-9b").reduced()
    with pytest.raises(ValueError, match="prefill_bucket"):
        ContinuousEngine(rg, None, PoolConfig(n_slots=1, max_len=MAX_LEN,
                                              prefill_bucket=8))


def test_continuous_greedy_parity_encdec(seamless):
    cfg, params = seamless
    lens = [4, 7, 3]
    mts = [5, 3, 6]
    prompts = _prompts(cfg, lens, seed=1)
    src = [jax.random.normal(jax.random.PRNGKey(10 + i),
                             (SRC_LEN, cfg.d_model), jnp.float32)
           for i in range(len(prompts))]
    static = _static_per_request(cfg, params, prompts, mts, src=src)

    ce = ContinuousEngine(
        cfg, params, PoolConfig(n_slots=2, max_len=MAX_LEN,
                                src_len=SRC_LEN))
    out = ce.serve([Request(prompt=p, max_tokens=mt, stop_tokens=(),
                            src_embeds=s)
                    for p, mt, s in zip(prompts, mts, src)])
    for i, rid in enumerate(sorted(out)):
        assert out[rid] == static[i], f"encdec request {i} diverged"
    assert ce.pool.n_free == ce.pool.n_slots


def test_fifo_admission_under_capacity_pressure(dense):
    """With fewer slots than requests, admission follows submission order."""
    cfg, params = dense
    prompts = _prompts(cfg, [4] * 6, seed=2)
    ce = ContinuousEngine(cfg, params, PoolConfig(n_slots=2,
                                                  max_len=MAX_LEN))
    ids = [ce.submit(Request(prompt=p, max_tokens=3, stop_tokens=()))
           for p in prompts]
    while ce.scheduler.has_work():
        ce.step()
    admits = [ce.scheduler.finished[r].admit_step for r in ids]
    assert admits == sorted(admits), "admission must be FCFS"
    assert admits[0] == admits[1] == 1      # both slots filled at step 1
    assert admits[2] > admits[1]            # later requests waited


def test_priority_admission(dense):
    cfg, params = dense
    prompts = _prompts(cfg, [4] * 3, seed=4)
    ce = ContinuousEngine(cfg, params,
                          PoolConfig(n_slots=1, max_len=MAX_LEN),
                          priority_fn=lambda r: r.priority)
    ids = [ce.submit(Request(prompt=p, max_tokens=2, stop_tokens=(),
                             priority=pr))
           for p, pr in zip(prompts, [0.0, 5.0, 0.0])]
    while ce.scheduler.has_work():
        ce.step()
    admits = {r: ce.scheduler.finished[r].admit_step for r in ids}
    assert admits[ids[1]] < admits[ids[0]] < admits[ids[2]]


def test_finished_requests_evicted_same_step(dense):
    """A request is evicted (slot freed) in the very step it hits
    max_tokens, and the freed slot is re-admitted the next step."""
    cfg, params = dense
    prompts = _prompts(cfg, [4, 5], seed=5)
    ce = ContinuousEngine(cfg, params, PoolConfig(n_slots=1,
                                                  max_len=MAX_LEN))
    first, second = [ce.submit(Request(prompt=p, max_tokens=3,
                                       stop_tokens=()))
                     for p in prompts]
    finish_step = None
    while ce.scheduler.has_work():
        events = ce.step()
        done = [rid for rid, _, fin in events if fin]
        if first in done:
            finish_step = ce.metrics.steps
            # evicted within the same step: slot already free (or re-used
            # at the next admission sweep; with one slot it must be free
            # now because admission for this step already ran)
            assert first not in [s.request_id
                                 for s in ce.scheduler.running.values()]
            assert ce.pool.n_free == 1 or second in [
                s.request_id for s in ce.scheduler.running.values()]
    st1 = ce.scheduler.finished[first]
    st2 = ce.scheduler.finished[second]
    assert st1.finish_step == finish_step
    assert st2.admit_step == finish_step + 1


def test_step_events_cover_admission_tokens(dense):
    """Every generated token appears in the step() event stream —
    including first tokens sampled at admission, and requests that finish
    on their very first token (max_tokens=1)."""
    cfg, params = dense
    prompts = _prompts(cfg, [4, 5], seed=7)
    ce = ContinuousEngine(cfg, params, PoolConfig(n_slots=2,
                                                  max_len=MAX_LEN))
    one = ce.submit(Request(prompt=prompts[0], max_tokens=1,
                            stop_tokens=()))
    two = ce.submit(Request(prompt=prompts[1], max_tokens=3,
                            stop_tokens=()))
    seen = {one: [], two: []}
    while ce.scheduler.has_work():
        for rid, tok, fin in ce.step():
            seen[rid].append((tok, fin))
    assert seen[one] == [(ce.scheduler.finished[one].generated[0], True)]
    gen2 = ce.scheduler.finished[two].generated
    assert [t for t, _ in seen[two]] == gen2
    assert [f for _, f in seen[two]] == [False, False, True]


def test_scheduler_cancel_waiting_and_running():
    s = Scheduler()
    a = s.submit(Request(prompt=[1], max_tokens=5), stop_tokens=())
    b = s.submit(Request(prompt=[1], max_tokens=5), stop_tokens=())
    st = s.next_waiting()
    s.start(st, slot=0, step=1)
    # waiting request: leaves the queue, lands in finished
    cancelled = s.cancel(b, step=2)
    assert cancelled is not None and cancelled.slot is None
    assert s.queue_depth == 0
    assert s.finished[b].finish_reason == "cancelled"
    assert s.finished[b].finish_step == 2
    # running request: popped from running, slot reported for freeing
    cancelled = s.cancel(a, step=3)
    assert cancelled is not None and cancelled.slot == 0
    assert not s.running and s.finished[a].finish_reason == "cancelled"
    # unknown / already-finished ids are a no-op
    assert s.cancel(a) is None
    assert s.cancel(99) is None


def test_engine_cancel_frees_slot_mid_flight(dense):
    """cancel() in both states: a waiting request leaves the queue, a
    running one frees its KV slot the same step (no leak until
    max_tokens), and the slot is immediately reusable."""
    cfg, params = dense
    prompts = _prompts(cfg, [4, 5], seed=11)
    ce = ContinuousEngine(cfg, params, PoolConfig(n_slots=1,
                                                  max_len=MAX_LEN))
    streamed = []
    r1 = ce.submit(Request(prompt=prompts[0], max_tokens=8,
                           stop_tokens=()),
                   on_token=lambda rid, t, f: streamed.append(t))
    r2 = ce.submit(Request(prompt=prompts[1], max_tokens=8,
                           stop_tokens=()))
    ce.step()   # r1 running (holds the only slot), r2 waiting
    assert ce.scheduler.n_running == 1 and ce.scheduler.queue_depth == 1

    assert ce.cancel(r2)
    assert ce.scheduler.queue_depth == 0
    assert ce.scheduler.finished[r2].finish_reason == "cancelled"

    n_streamed = len(streamed)
    assert ce.cancel(r1)
    assert ce.pool.n_free == 1          # freed same step, not at max_tokens
    assert ce.scheduler.finished[r1].finish_reason == "cancelled"
    assert not ce.scheduler.has_work()
    assert ce.metrics.requests_cancelled == 2
    assert not ce._on_token             # callback dropped, no finished call
    assert len(streamed) == n_streamed
    assert (ce._temps == 0).all() and (ce._tokens == 0).all()

    assert not ce.cancel(r1)            # already finished
    assert not ce.cancel(999)           # unknown

    # the freed slot serves new work
    out = ce.serve([Request(prompt=prompts[0], max_tokens=3,
                            stop_tokens=())])
    assert [len(v) for v in out.values()] == [3]
    assert ce.pool.alloc_count == ce.pool.free_count == 2


def test_wall_clock_ttft_recorded(dense):
    cfg, params = dense
    prompts = _prompts(cfg, [4, 6], seed=12)
    ce = ContinuousEngine(cfg, params, PoolConfig(n_slots=2,
                                                  max_len=MAX_LEN))
    ce.serve([Request(prompt=p, max_tokens=2, stop_tokens=())
              for p in prompts])
    for st in ce.scheduler.finished.values():
        assert st.ttft_s is not None and st.ttft_s >= 0
        assert st.first_token_time > st.submit_time > 0
    assert ce.metrics.ttft_s_sum > 0
    snap = ce.metrics.snapshot()
    assert snap["mean_ttft_s"] == pytest.approx(
        ce.metrics.ttft_s_sum / len(prompts))


def test_submit_validation(dense):
    cfg, params = dense
    ce = ContinuousEngine(cfg, params, PoolConfig(n_slots=1,
                                                  max_len=MAX_LEN))
    with pytest.raises(ValueError, match="max_len"):
        ce.submit(Request(prompt=[1] * 30, max_tokens=10))
    with pytest.raises(ValueError, match="empty"):
        ce.submit(Request(prompt=[], max_tokens=1))


def test_sampled_serving_runs(dense):
    """Temperature/top-k requests complete (no parity claim, just liveness
    + determinism under a fixed engine key)."""
    cfg, params = dense
    prompts = _prompts(cfg, [5, 6], seed=6)
    reqs = [Request(prompt=prompts[0], max_tokens=4, temperature=0.8,
                    top_k=16, stop_tokens=()),
            Request(prompt=prompts[1], max_tokens=4, stop_tokens=())]
    ce1 = ContinuousEngine(cfg, params, PoolConfig(n_slots=2,
                                                   max_len=MAX_LEN))
    out1 = ce1.serve(reqs, key=jax.random.PRNGKey(7))
    ce2 = ContinuousEngine(cfg, params, PoolConfig(n_slots=2,
                                                   max_len=MAX_LEN))
    out2 = ce2.serve(reqs, key=jax.random.PRNGKey(7))
    assert out1 == out2
    assert all(len(v) == 4 for v in out1.values())
    assert all(0 <= t < cfg.vocab for v in out1.values() for t in v)


def test_streaming_on_token_callback(dense):
    """submit(on_token=...) streams each request's tokens in generation
    order, inside the step that produced them, and stops at finished."""
    cfg, params = dense
    prompts = _prompts(cfg, [4, 7, 5], seed=9)
    ce = ContinuousEngine(cfg, params, PoolConfig(n_slots=2,
                                                  max_len=MAX_LEN))
    streamed: dict[int, list] = {}
    order: list = []

    def on_token(rid, tok, finished):
        streamed.setdefault(rid, []).append(tok)
        order.append((rid, tok, finished))

    reqs = [Request(prompt=p, max_tokens=mt, stop_tokens=())
            for p, mt in zip(prompts, [5, 3, 4])]
    ids = [ce.submit(r, on_token=on_token) for r in reqs]

    events = []
    while ce.scheduler.has_work():
        before = len(order)
        step_events = ce.step()
        events += step_events
        # callbacks fired inside this step, one per event, same order
        assert order[before:] == step_events

    # per-request streams match the recorded generations, in order
    for rid in ids:
        assert streamed[rid] == list(ce.scheduler.finished[rid].generated)
    # the merged stream is exactly the event stream (generation order)
    assert order == events
    # finished fired exactly once per request, as the last event of each
    for rid in ids:
        flags = [f for r, _, f in order if r == rid]
        assert flags == [False] * (len(flags) - 1) + [True]
    # callbacks are dropped after finish (no leak)
    assert not ce._on_token


def test_on_token_without_callback_unchanged(dense):
    """Requests without callbacks serve exactly as before (parity of the
    event stream with a callback-free engine)."""
    cfg, params = dense
    prompts = _prompts(cfg, [4, 6], seed=10)
    reqs = [Request(prompt=p, max_tokens=3, stop_tokens=()) for p in prompts]
    ce1 = ContinuousEngine(cfg, params, PoolConfig(n_slots=2,
                                                   max_len=MAX_LEN))
    ce2 = ContinuousEngine(cfg, params, PoolConfig(n_slots=2,
                                                   max_len=MAX_LEN))
    out1 = ce1.serve(reqs)
    out2 = ce2.serve([dataclasses.replace(r) for r in reqs])
    assert out1 == out2
