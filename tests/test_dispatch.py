"""Dispatch semantics: registry, context nesting, precedence, fallback,
tuning cache, deprecation shims, and pallas<->xla parity for every
registered op routed *through the context* (no backend kwargs)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import dispatch
from repro.core.blocking import Blocks
from repro.kernels.brgemm import batched_matmul, brgemm, matmul
from repro.kernels.conv2d import conv2d
from repro.kernels.flash_attention import flash_attention

ALL_OPS = ("matmul", "brgemm", "batched_matmul", "conv2d",
           "flash_attention", "flash_attention_bwd")


def _randn(*shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed + len(shape))
    return jnp.asarray(rng.normal(size=shape), dtype)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def test_registry_has_all_ops_with_both_backends():
    assert set(repro.registered_ops()) == set(ALL_OPS)
    for op in ALL_OPS:
        assert repro.backends_for(op) == ("pallas", "xla")
        # on CPU and TPU both are available (pallas interprets on CPU)
        assert "xla" in repro.available_backends(op)


def test_unknown_op_error_lists_registered_ops():
    with pytest.raises(ValueError, match="registered ops.*matmul"):
        repro.resolve("not_an_op")


def test_unknown_backend_error_lists_registered_backends():
    with pytest.raises(ValueError, match="pallas, xla"):
        repro.resolve("matmul", "cuda")
    x, w = _randn(4, 8), _randn(8, 4)
    with pytest.raises(ValueError, match="unknown backend 'cuda'"):
        matmul(x, w, backend="cuda")
    with pytest.raises(ValueError, match="unknown backend"):
        with repro.use(backend="cuda"):
            pass


# --------------------------------------------------------------------------
# context nesting / restoration
# --------------------------------------------------------------------------

def test_context_nesting_and_restoration():
    assert repro.current_context().backend is None
    with repro.use(backend="xla", interpret=True):
        assert repro.current_context().backend == "xla"
        assert repro.current_context().interpret is True
        with repro.use(backend="pallas"):
            ctx = repro.current_context()
            # innermost backend wins; unset fields inherit outward
            assert ctx.backend == "pallas"
            assert ctx.interpret is True
        assert repro.current_context().backend == "xla"
    assert repro.current_context().backend is None
    assert repro.current_context().interpret is None


def test_context_restored_on_exception():
    with pytest.raises(RuntimeError, match="boom"):
        with repro.use(backend="xla"):
            raise RuntimeError("boom")
    assert repro.current_context().backend is None


# --------------------------------------------------------------------------
# precedence: call arg > context > env > hardware default
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_call_arg_beats_context(backend):
    other = "xla" if backend == "pallas" else "pallas"
    with repro.use(backend=other):
        assert repro.resolve("matmul", backend) == backend


def test_context_beats_env(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas")
    assert repro.resolve("matmul") == "pallas"
    with repro.use(backend="xla"):
        assert repro.resolve("matmul") == "xla"


def test_env_beats_hardware_default(monkeypatch):
    default = repro.resolve("matmul")
    other = "xla" if default == "pallas" else "pallas"
    monkeypatch.setenv(dispatch.ENV_VAR, other)
    assert repro.resolve("matmul") == other


def test_legacy_env_var_still_honored(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    monkeypatch.setenv(dispatch.LEGACY_ENV_VAR, "pallas")
    assert repro.resolve("brgemm") == "pallas"
    # the canonical var wins over the legacy alias
    monkeypatch.setenv(dispatch.ENV_VAR, "xla")
    assert repro.resolve("brgemm") == "xla"


def test_hardware_default():
    want = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert repro.resolve("conv2d") == want


@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_precedence_end_to_end_numerics(backend, monkeypatch):
    """The full chain on real calls: kwarg beats context beats env."""
    x, w = _randn(8, 16, seed=1), _randn(16, 8, seed=2)
    other = "xla" if backend == "pallas" else "pallas"
    monkeypatch.setenv(dispatch.ENV_VAR, other)
    with repro.use(backend=other):
        y_kwarg = matmul(x, w, backend=backend)
    with repro.use(backend=backend):
        y_ctx = matmul(x, w)
    y_direct = matmul(x, w, backend=backend)
    np.testing.assert_allclose(np.asarray(y_kwarg), np.asarray(y_ctx),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_kwarg), np.asarray(y_direct),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# pallas <-> xla parity through the context, all five ops
# --------------------------------------------------------------------------

def _run_op(op):
    if op == "matmul":
        return matmul(_randn(16, 32), _randn(32, 8), _randn(8),
                      activation="relu")
    if op == "brgemm":
        return brgemm(_randn(3, 16, 32), _randn(3, 32, 8))
    if op == "batched_matmul":
        return batched_matmul(_randn(3, 16, 32), _randn(3, 32, 8))
    if op == "conv2d":
        return conv2d(_randn(1, 6, 6, 2), _randn(3, 3, 2, 4, seed=3) * 0.3,
                      stride=2, padding=1)
    if op == "flash_attention":
        return flash_attention(_randn(1, 2, 32, 16), _randn(1, 2, 32, 16),
                               _randn(1, 2, 32, 16), causal=True)
    if op == "flash_attention_bwd":
        from repro.kernels.flash_attention import flash_attention_bwd
        from repro.kernels.flash_attention.kernel import (
            flash_attention_pallas,
        )
        q = _randn(1, 2, 32, 16, seed=5)
        k = _randn(1, 2, 32, 16, seed=6)
        v = _randn(1, 2, 32, 16, seed=7)
        y, lse = flash_attention_pallas(q, k, v, causal=True, interpret=True,
                                        return_residuals=True)
        dy = _randn(1, 2, 32, 16, seed=8)
        return flash_attention_bwd(q, k, v, y, lse, dy, causal=True)
    raise AssertionError(op)


@pytest.mark.parametrize("op", ALL_OPS)
def test_context_routed_parity(op):
    with repro.use(backend="xla"):
        want = _run_op(op)
    with repro.use(backend="pallas"):
        got = _run_op(op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# tuning cache + block policies
# --------------------------------------------------------------------------

def test_tuning_cache_memoizes_by_shape_key():
    dispatch.clear_tuning_cache()
    b1 = dispatch.resolve_blocks("matmul", 64, 128, 256, jnp.float32,
                                 backend="pallas")
    b2 = dispatch.resolve_blocks("matmul", 64, 128, 256, jnp.float32,
                                 backend="pallas")
    assert b1 is b2
    assert len(dispatch.tuning_cache_info()) == 1
    # distinct shape/dtype/op -> distinct entries
    dispatch.resolve_blocks("matmul", 64, 128, 512, jnp.float32,
                            backend="pallas")
    dispatch.resolve_blocks("brgemm", 64, 128, 256, jnp.bfloat16,
                            backend="pallas")
    assert len(dispatch.tuning_cache_info()) == 3


def test_explicit_blocks_bypass_cache():
    dispatch.clear_tuning_cache()
    blk = Blocks(8, 128, 128)
    got = dispatch.resolve_blocks("matmul", 64, 128, 256, jnp.float32,
                                  backend="pallas", blocks=blk)
    assert got is blk
    assert not dispatch.tuning_cache_info()


def test_custom_block_policy_via_context():
    calls = []

    def policy(op, m, n, k, dtype, backend):
        calls.append((op, m, n, k))
        return Blocks(8, 128, 128)

    x, w = _randn(16, 32), _randn(32, 8)
    with repro.use(blocks_policy=policy):
        y = matmul(x, w, backend="pallas")
    assert calls and calls[0][0] == "matmul"
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(matmul(x, w, backend="xla")),
                               rtol=1e-5, atol=1e-5)


def test_callable_policy_is_memoized_per_shape():
    calls = []

    def policy(op, m, n, k, dtype, backend):
        calls.append((m, n, k))
        return Blocks(8, 128, 128)

    dispatch.clear_tuning_cache()
    with repro.use(blocks_policy=policy):
        for _ in range(3):  # same shape -> one policy invocation
            dispatch.resolve_blocks("matmul", 16, 8, 32, jnp.float32,
                                    backend="pallas")
        dispatch.resolve_blocks("matmul", 32, 8, 32, jnp.float32,
                                backend="pallas")
    assert calls == [(16, 8, 32), (32, 8, 32)]


def test_xla_impl_validated_on_every_backend():
    q = _randn(1, 2, 32, 16)
    for backend in ("pallas", "xla"):
        with pytest.raises(ValueError, match="xla_impl"):
            flash_attention(q, q, q, backend=backend, xla_impl="chunkd")


def test_unknown_blocks_policy_rejected():
    with pytest.raises(ValueError, match="blocks_policy"):
        with repro.use(blocks_policy="autotune-v99"):
            pass


# --------------------------------------------------------------------------
# interpret / accum_dtype resolution
# --------------------------------------------------------------------------

def test_interpret_resolution():
    default = jax.default_backend() != "tpu"
    assert dispatch.resolve_interpret() is default
    with repro.use(interpret=not default):
        assert dispatch.resolve_interpret() is (not default)
        assert dispatch.resolve_interpret(default) is default  # arg wins


def test_accum_dtype_resolution_and_execution():
    assert dispatch.resolve_accum_dtype() == jnp.dtype(jnp.float32)
    with repro.use(accum_dtype=jnp.bfloat16):
        assert dispatch.resolve_accum_dtype() == jnp.dtype(jnp.bfloat16)
        y = matmul(_randn(8, 16), _randn(16, 8), backend="xla")
    # bf16 accumulation is lossier but must stay in the right ballpark
    want = matmul(_randn(8, 16), _randn(16, 8), backend="xla")
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=0.1,
                               atol=0.1)


# --------------------------------------------------------------------------
# deprecated shims
# --------------------------------------------------------------------------

def test_deprecated_set_default_backend_shim():
    from repro.kernels.brgemm import resolve_backend, set_default_backend
    try:
        with pytest.warns(DeprecationWarning):
            set_default_backend("xla")
        with pytest.warns(DeprecationWarning):
            assert resolve_backend() == "xla"
        # an explicit context still overrides the deprecated global
        with repro.use(backend="pallas"):
            assert repro.resolve("matmul") == "pallas"
        assert repro.resolve("matmul") == "xla"
    finally:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            set_default_backend(None)


def test_deprecated_global_beats_env(monkeypatch):
    """Legacy precedence preserved: the global override beat the env var."""
    from repro.kernels.brgemm import set_default_backend
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas")
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            set_default_backend("xla")
        assert repro.resolve("matmul") == "xla"
    finally:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            set_default_backend(None)


# --------------------------------------------------------------------------
# jit interaction
# --------------------------------------------------------------------------

def test_context_captured_at_trace_time_under_jit():
    x, w = _randn(8, 16), _randn(16, 8)

    @jax.jit
    def f(x, w):
        return matmul(x, w)

    with repro.use(backend="xla"):
        y = f(x, w)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(matmul(x, w, backend="xla")),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# per-op backend pins in axis_specs
# --------------------------------------------------------------------------

def test_axis_specs_backend_pin_scopes_to_one_op():
    with repro.use(backend="pallas", interpret=True,
                   axis_specs={"matmul": {"backend": "xla"}}):
        assert repro.resolve("matmul") == "xla"      # pin beats context
        assert repro.resolve("brgemm") == "pallas"   # others keep context
        assert repro.resolve("matmul", "pallas") == "pallas"  # arg beats pin
    assert repro.resolve("matmul") != "xla" or True  # context fully popped
    assert dispatch.current_context().axis_specs is None


def test_axis_specs_backend_pin_routes_the_call():
    x, w = _randn(16, 32, seed=70), _randn(32, 16, seed=71)
    want = matmul(x, w, backend="xla")
    dispatch.clear_tuning_cache()
    with repro.use(backend="pallas", interpret=True,
                   axis_specs={"matmul": {"backend": "xla"}}):
        got = matmul(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # the pinned op never reached the pallas block resolver
    assert "matmul" not in {k[0] for k in dispatch.tuning_cache_info()}
    dispatch.clear_tuning_cache()


def test_axis_specs_pin_validation():
    with pytest.raises(ValueError, match="unknown key"):
        with repro.use(axis_specs={"matmul": {"nope": 1}}):
            pass
    with pytest.raises(ValueError, match="not.*registered|unknown backend"):
        with repro.use(axis_specs={"matmul": {"backend": "cuda"}}):
            pass
    # dict form carries axes and a pin together
    with repro.use(axis_specs={"matmul": {"axes": ("data", None, None),
                                          "backend": "xla"}}):
        assert repro.resolve("matmul") == "xla"
