"""Model-zoo layer tests: attention modes, MoE invariants, recurrent cells."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_testlib import given, settings, st  # optional-hypothesis shim

from repro.kernels.flash_attention import flash_attention
from repro.layers import attention as attn
from repro.layers import moe
from repro.layers import recurrent as rec
from repro.layers.attention import AttnCfg
from repro.layers.moe import MoECfg


# ------------------------------ attention -------------------------------

def test_gqa_prefill_decode_parity():
    cfg = AttnCfg(d_model=64, n_heads=8, n_kv_heads=2)
    p = attn.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64)) * 0.5
    y_full = attn.apply(p, x, cfg, mode="train")
    cache = attn.init_cache(cfg, 2, 16)
    y_pre, cache = attn.apply(p, x[:, :8], cfg, mode="prefill", cache=cache)
    np.testing.assert_allclose(np.asarray(y_full[:, :8]), np.asarray(y_pre),
                               rtol=2e-4, atol=2e-4)
    for i in range(8, 12):
        y_i, cache = attn.apply(p, x[:, i:i + 1], cfg, mode="decode",
                                cache=cache, pos=i)
        np.testing.assert_allclose(np.asarray(y_full[:, i]),
                                   np.asarray(y_i[:, 0]), rtol=2e-4,
                                   atol=2e-4)


def test_mla_compressed_cache_decode_parity():
    cfg = AttnCfg(d_model=64, n_heads=4, n_kv_heads=4, mla=True,
                  q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                  qk_rope_dim=8, v_head_dim=16)
    p = attn.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64)) * 0.5
    y_full = attn.apply(p, x, cfg, mode="train")
    cache = attn.init_cache(cfg, 2, 16)
    assert set(cache) == {"c_kv", "k_rope"}  # compressed, not per-head K/V
    y_pre, cache = attn.apply(p, x[:, :8], cfg, mode="prefill", cache=cache)
    np.testing.assert_allclose(np.asarray(y_full[:, :8]), np.asarray(y_pre),
                               rtol=1e-3, atol=1e-3)
    for i in range(8, 12):
        y_i, cache = attn.apply(p, x[:, i:i + 1], cfg, mode="decode",
                                cache=cache, pos=i)
        np.testing.assert_allclose(np.asarray(y_full[:, i]),
                                   np.asarray(y_i[:, 0]), rtol=1e-3,
                                   atol=1e-3)


def test_sliding_window_masks_old_positions():
    cfg = AttnCfg(d_model=32, n_heads=2, n_kv_heads=2, window=4)
    p = attn.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    y = attn.apply(p, x, cfg, mode="train")
    # perturbing a token > window positions back must not change output
    x2 = x.at[:, 2].add(10.0)
    y2 = attn.apply(p, x2, cfg, mode="train")
    np.testing.assert_allclose(np.asarray(y[:, 10:]), np.asarray(y2[:, 10:]),
                               rtol=1e-4, atol=1e-4)
    assert not np.allclose(np.asarray(y[:, 3]), np.asarray(y2[:, 3]))


def test_flash_attention_causality():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 32, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 32, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 32, 16))
    y = flash_attention(q, k, v, causal=True, backend="pallas")
    k2 = k.at[:, :, -1].add(100.0)
    v2 = v.at[:, :, -1].add(100.0)
    y2 = flash_attention(q, k2, v2, causal=True, backend="pallas")
    # only the last query position may change
    np.testing.assert_allclose(np.asarray(y[:, :, :-1]),
                               np.asarray(y2[:, :, :-1]), rtol=1e-4,
                               atol=1e-4)


# -------------------------------- MoE ------------------------------------

def test_moe_top1_equals_dense_expert():
    """With 1 expert and top-1, MoE == plain (gated) MLP of that expert."""
    cfg = MoECfg(d_model=16, d_ff=32, n_experts=1, top_k=1,
                 capacity_factor=4.0, renormalize=True)
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe.apply(p, x, cfg)
    from repro.kernels.brgemm import matmul
    xf = x.reshape(-1, 16)
    g = np.asarray(matmul(xf, p["w_gate"][0], activation="silu"))
    u = np.asarray(matmul(xf, p["w_up"][0]))
    want = np.asarray(matmul(jnp.asarray(g * u), p["w_down"][0]))
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 16)), want,
                               rtol=1e-4, atol=1e-4)
    assert float(aux["dropped_fraction"]) == 0.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_moe_gates_sum_to_one(seed):
    cfg = MoECfg(d_model=16, d_ff=16, n_experts=8, top_k=2,
                 capacity_factor=8.0)
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed % 2**31), (1, 16, 16))
    y, aux = moe.apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["dropped_fraction"]) == 0.0  # dropless capacity


def test_moe_capacity_dropping_reported():
    cfg = MoECfg(d_model=8, d_ff=8, n_experts=4, top_k=2,
                 capacity_factor=0.25)
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))
    _, aux = moe.apply(p, x, cfg)
    assert float(aux["dropped_fraction"]) > 0.0


# ------------------------------ recurrent --------------------------------

def test_mlstm_chunkwise_matches_scan_oracle():
    b, h, t, dk, dv = 2, 2, 64, 16, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, h, t, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, t, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, t, dv)), jnp.float32)
    li = jnp.asarray(rng.normal(size=(b, h, t)), jnp.float32)
    lf = jnp.asarray(np.log(1 / (1 + np.exp(-rng.normal(size=(b, h, t))))),
                     jnp.float32)
    hs_scan, st_scan = rec.mlstm_scan(q, k, v, li, lf)
    for chunk in (8, 16, 64):
        hs_ck, st_ck = rec.mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
        np.testing.assert_allclose(np.asarray(hs_scan), np.asarray(hs_ck),
                                   rtol=3e-4, atol=3e-4)
    for a, b_ in zip(st_scan, st_ck):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=3e-3, atol=3e-3)


def test_rglru_state_decay_bounds():
    """RG-LRU recurrence weight a in (0, 1): state cannot blow up."""
    cfg = rec.RGLRUCfg(d_model=16, d_rnn=16)
    p = rec.rglru_init(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((1, 256, 16))
    y, state = rec.rglru_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    assert np.abs(np.asarray(state["h"])).max() < 1e3


def test_slstm_long_sequence_stable():
    cfg = rec.SLSTMCfg(d_model=16, n_heads=2)
    p = rec.slstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 512, 16)) * 3.0
    y, state = rec.slstm_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(state["c"])).all()


# --------------------- §Perf optimization paths --------------------------

def test_chunked_attention_matches_naive():
    """Online-softmax (flash-semantics) XLA path == naive oracle."""
    from repro.kernels.flash_attention.ref import mha_chunked
    rng = np.random.default_rng(3)
    for (b, hq, hkv, tq, tk, causal, win) in [
            (2, 4, 2, 64, 64, True, None),
            (1, 4, 1, 96, 96, True, 32),
            (1, 2, 2, 32, 80, False, None)]:
        q = jnp.asarray(rng.normal(size=(b, hq, tq, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, hkv, tk, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, hkv, tk, 16)), jnp.float32)
        a = flash_attention(q, k, v, causal=causal, window=win,
                            backend="xla", xla_impl="naive")
        c = flash_attention(q, k, v, causal=causal, window=win,
                            backend="xla", xla_impl="chunked")
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-5, atol=2e-5)


def test_moe_grouped_matches_ungrouped_dropless():
    """Grouped (per-batch-row) dispatch == global dispatch when no tokens
    drop — the §Perf iteration-1 change is semantics-preserving."""
    import dataclasses
    cfg_g = MoECfg(d_model=24, d_ff=32, n_experts=4, top_k=2,
                   capacity_factor=4.0, grouped=True)
    cfg_u = dataclasses.replace(cfg_g, grouped=False)
    p = moe.init(jax.random.PRNGKey(5), cfg_g)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 12, 24))
    yg, ag = moe.apply(p, x, cfg_g)
    yu, au = moe.apply(p, x, cfg_u)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yu),
                               rtol=1e-4, atol=1e-4)
    assert float(ag["dropped_fraction"]) == 0.0


def test_moe_grouped_grads_finite():
    cfg = MoECfg(d_model=16, d_ff=16, n_experts=4, top_k=2,
                 capacity_factor=2.0, grouped=True)
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))

    def loss(p):
        y, aux = moe.apply(p, x, cfg)
        return y.sum() + aux["load_balance_loss"]

    g = jax.grad(loss)(p)
    assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))
