"""Self-healing serving tests: fault classification, retry/backoff,
quarantine -> health probe -> re-admission, the hang watchdog, graceful
degradation (off-tier routing, parked requests on a hard-down cluster),
the corrupt-tuning-cache fallback, and the frontend stop/submit race.

Everything is seeded and runs on an injectable clock, so every recovery
path is deterministic on CPU."""
import asyncio
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import dispatch
from repro.models import api
from repro.serve import (
    AsyncFrontend,
    ContinuousEngine,
    EngineReplica,
    EngineRouter,
    FatalError,
    FaultClock,
    FaultInjector,
    FaultSpec,
    HealthConfig,
    PoolConfig,
    Request,
    RetryPolicy,
    TransientError,
    classify_failure,
)
from repro.serve import cluster as cl
from repro.serve.health import ReplicaHungError

MAX_LEN = 32


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, n).tolist() for n in lens]


def _engine(dense, n_slots=2):
    cfg, params = dense
    return ContinuousEngine(cfg, params,
                            PoolConfig(n_slots=n_slots, max_len=MAX_LEN))


def _requests(cfg, lens, seed=0, max_tokens=3):
    return [Request(prompt=p, max_tokens=max_tokens, stop_tokens=())
            for p in _prompts(cfg, lens, seed=seed)]


def _reference(dense, requests):
    """Greedy fault-free token streams, in submission order."""
    out = _engine(dense, n_slots=4).serve(requests)
    return [out[i] for i in sorted(out)]


# ==========================================================================
# taxonomy / policy units (no engine)
# ==========================================================================

def test_classify_failure():
    assert classify_failure(TransientError("x")) == "transient"
    assert classify_failure(FatalError("x")) == "fatal"
    assert classify_failure(RuntimeError("plain")) == "fatal"
    # the transient tag propagates through the __cause__ chain
    try:
        try:
            raise TransientError("inner")
        except TransientError as inner:
            raise RuntimeError("wrapped") from inner
    except RuntimeError as outer:
        assert classify_failure(outer) == "transient"
    # any exception type can self-tag without importing the serve layer
    exc = ValueError("tagged")
    exc.transient = True
    assert classify_failure(exc) == "transient"


def test_retry_policy_backoff():
    pol = RetryPolicy(max_retries=3, backoff_s=0.1, backoff_mult=2.0,
                      max_backoff_s=0.3, jitter=0.1, seed=7)
    delays = [pol.backoff(a) for a in (1, 2, 3, 4)]
    # exponential then capped, each within +-10% jitter
    for d, base in zip(delays, (0.1, 0.2, 0.3, 0.3)):
        assert base * 0.9 <= d <= base * 1.1
    # seeded: a fresh policy with the same seed replays the schedule
    again = RetryPolicy(max_retries=3, backoff_s=0.1, backoff_mult=2.0,
                        max_backoff_s=0.3, jitter=0.1, seed=7)
    assert [again.backoff(a) for a in (1, 2, 3, 4)] == delays
    assert RetryPolicy(jitter=0.0).backoff(1) == 0.05


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(site="step", kind="explode")
    with pytest.raises(ValueError, match="hang_s"):
        FaultSpec(site="step", kind="hang")


def test_injector_schedule_and_counters():
    clk = FaultClock()
    inj = FaultInjector([
        FaultSpec(site="step", target="a", at=2, kind="transient"),
        FaultSpec(site="step", target="b", at=1, kind="fatal"),
        FaultSpec(site="io", at=1, kind="hang", hang_s=3.0,
                  repeat=True, until=2),
    ], clock=clk)
    inj.fire("step", "a")                       # call 1: clean
    with pytest.raises(TransientError):
        inj.fire("step", "a")                   # call 2: fires
    inj.fire("step", "a")                       # one-shot: clear again
    with pytest.raises(FatalError):
        inj.fire("step", "b")
    inj.fire("io")                              # hang: advances the clock
    inj.fire("io")
    inj.fire("io")                              # past until: clean
    assert clk.now() == 6.0
    assert inj.calls[("step", "a")] == 3
    assert [f[3] for f in inj.fired] == ["transient", "fatal",
                                         "hang", "hang"]


def test_hang_requires_clock():
    inj = FaultInjector([FaultSpec(site="s", kind="hang", hang_s=1.0)])
    with pytest.raises(ValueError, match="clock"):
        inj.fire("s")


# ==========================================================================
# retry: transient faults survived in place
# ==========================================================================

@pytest.mark.parametrize("site", ["step", "prefill", "decode"])
def test_transient_retry_token_parity(dense, site):
    """A transient fault at any injection site is retried in place and
    the greedy streams match a fault-free run token for token."""
    cfg, _ = dense
    requests = _requests(cfg, [4, 6, 5], seed=3)
    ref = _reference(dense, requests)

    clk = FaultClock()
    inj = FaultInjector([FaultSpec(site=site, target="a", at=2,
                                   kind="transient")], clock=clk)
    router = EngineRouter(
        [EngineReplica("a", inj.instrument(_engine(dense, 4), "a"))],
        clock=clk, sleep=clk.advance,
        retry=RetryPolicy(max_retries=2, backoff_s=0.01, seed=0))
    out = router.serve(requests)
    assert [out[t] for t in sorted(out)] == ref
    assert router.counters["retries"] == 1
    assert router.counters["replicas_quarantined"] == 0
    assert all(router.tickets[t].status == cl.COMPLETED for t in out)


def test_retry_exhaustion_quarantines(dense):
    """A fault that keeps firing past max_retries condemns the replica;
    requests requeue onto the survivor and still complete."""
    cfg, _ = dense
    requests = _requests(cfg, [4, 5], seed=4)
    ref = _reference(dense, requests)
    clk = FaultClock()
    inj = FaultInjector([FaultSpec(site="step", target="sick", at=2,
                                   kind="transient", repeat=True)],
                        clock=clk)
    router = EngineRouter(
        [EngineReplica("sick", inj.instrument(_engine(dense), "sick")),
         EngineReplica("ok", _engine(dense))],
        clock=clk, sleep=clk.advance,
        retry=RetryPolicy(max_retries=2, backoff_s=0.01, seed=0))
    out = router.serve(requests)
    assert [out[t] for t in sorted(out)] == ref
    assert router.counters["retries"] == 2
    assert router.counters["replicas_quarantined"] == 1
    sick = router._by_name["sick"]
    assert not sick.healthy
    assert classify_failure(sick.fault) == "transient"   # what killed it


# ==========================================================================
# quarantine -> probe -> re-admission
# ==========================================================================

def _healing_router(dense, *, specs, clk, n_slots=2, health=None,
                    names=("bad", "ok")):
    inj = FaultInjector(specs, clock=clk)
    make = lambda: _engine(dense, n_slots)  # noqa: E731
    replicas = [
        EngineReplica(names[0], inj.instrument(make(), names[0]),
                      factory=make),
        EngineReplica(names[1], make(), factory=make),
    ]
    router = EngineRouter(
        replicas, clock=clk, sleep=clk.advance,
        retry=RetryPolicy(max_retries=1, backoff_s=0.01, seed=0),
        health=health or HealthConfig(probe_interval_s=1.0,
                                      probes_to_readmit=2, max_probes=4,
                                      watchdog_s=5.0))
    return router, inj


def test_quarantine_probe_readmit_roundtrip(dense):
    """A fatally-faulted replica is quarantined, health-probed on the
    clock, re-admitted with a warm-restarted engine, and serves new
    traffic again."""
    cfg, _ = dense
    requests = _requests(cfg, [4, 5, 6], seed=5)
    ref = _reference(dense, requests)
    clk = FaultClock()
    router, _ = _healing_router(dense, clk=clk, specs=[
        FaultSpec(site="step", target="bad", at=2, kind="fatal")])
    out = router.serve(requests)
    assert [out[t] for t in sorted(out)] == ref
    bad = router._by_name["bad"]
    assert not bad.healthy
    assert router.metrics().gauges["bad"]["probing"] == 1.0

    faulted_engine = bad.engine
    for _ in range(8):
        if bad.healthy:
            break
        clk.advance(1.0)
        router.step()
    assert bad.healthy and bad.restarts == 1
    assert bad.engine is not faulted_engine          # the warm restart
    assert router.counters["replicas_readmitted"] == 1
    assert router.counters["probes"] == 2            # 2 passes to readmit
    assert router.metrics().gauges["bad"]["probing"] == 0.0

    # the re-admitted replica takes traffic again (fresh engine: clean)
    wave2 = _requests(cfg, [4, 4, 4, 4], seed=6)
    out2 = router.serve(wave2)
    assert all(router.tickets[t].status == cl.COMPLETED for t in out2)
    assert bad.engine.metrics.tokens_generated > 0


def test_watchdog_hang_quarantines(dense):
    """A step consuming more than watchdog_s of router-clock time is
    declared hung; the replica is quarantined, not stepped forever."""
    cfg, _ = dense
    requests = _requests(cfg, [4, 5], seed=7)
    ref = _reference(dense, requests)
    clk = FaultClock()
    router, _ = _healing_router(dense, clk=clk, specs=[
        FaultSpec(site="step", target="bad", at=2, kind="hang",
                  hang_s=9.0)])
    out = router.serve(requests)
    assert [out[t] for t in sorted(out)] == ref
    bad = router._by_name["bad"]
    assert not bad.healthy
    assert isinstance(bad.fault, ReplicaHungError)
    assert router.counters["replicas_quarantined"] == 1


def test_hang_under_watchdog_is_tolerated(dense):
    """A slow-but-under-deadline step is not a hang."""
    cfg, _ = dense
    clk = FaultClock()
    router, _ = _healing_router(dense, clk=clk, specs=[
        FaultSpec(site="step", target="bad", at=2, kind="hang",
                  hang_s=2.0)])
    out = router.serve(_requests(cfg, [4, 5], seed=8))
    assert all(router.tickets[t].status == cl.COMPLETED for t in out)
    assert router.counters["replicas_quarantined"] == 0


def test_hard_down_cluster_parks_then_recovers(dense):
    """Losing the last replica with health enabled parks the in-flight
    requests; the probe loop re-admits and they complete — serve() runs
    the whole outage end-to-end on the injected clock."""
    cfg, _ = dense
    requests = _requests(cfg, [4, 6], seed=9)
    ref = _reference(dense, requests)
    clk = FaultClock()
    inj = FaultInjector([FaultSpec(site="step", target="only", at=2,
                                   kind="fatal")], clock=clk)
    make = lambda: _engine(dense)  # noqa: E731
    router = EngineRouter(
        [EngineReplica("only", inj.instrument(make(), "only"),
                       factory=make)],
        clock=clk, sleep=clk.advance,
        health=HealthConfig(probe_interval_s=1.0, probes_to_readmit=1,
                            max_probes=4))
    out = router.serve(requests)
    assert [out[t] for t in sorted(out)] == ref
    assert all(router.tickets[t].status == cl.COMPLETED for t in out)
    assert router.counters["replicas_readmitted"] == 1
    assert router.counters["requests_requeued"] == 2


def test_probe_exhaustion_retires_and_fails_parked(dense):
    """When every probe fails, the replica retires permanently and
    parked requests resolve ``failed`` — the driver loop terminates."""
    cfg, _ = dense
    clk = FaultClock()
    inj = FaultInjector([FaultSpec(site="step", target="only", at=2,
                                   kind="fatal")], clock=clk)

    def broken_factory():
        raise RuntimeError("restart failed")

    router = EngineRouter(
        [EngineReplica("only", inj.instrument(_engine(dense), "only"),
                       factory=broken_factory)],
        clock=clk, sleep=clk.advance,
        health=HealthConfig(probe_interval_s=1.0, probes_to_readmit=1,
                            max_probes=2))
    out = router.serve(_requests(cfg, [4, 6], seed=10))
    assert all(router.tickets[t].status == cl.FAILED for t in out)
    only = router._by_name["only"]
    assert only.retired and not only.healthy
    assert router.counters["probe_failures"] == 2
    assert not router.has_work()


def test_no_health_preserves_legacy_last_replica_raise(dense):
    """Without health=, the last replica's death still fails tickets and
    propagates (the PR 6 contract)."""
    cfg, _ = dense
    eng = _engine(dense)
    orig = eng.step
    calls = [0]

    def flaky():
        calls[0] += 1
        if calls[0] == 2:
            raise RuntimeError("boom")
        return orig()
    eng.step = flaky
    router = EngineRouter([EngineReplica("a", eng)])
    tid = router.submit(Request(prompt=_prompts(cfg, [4])[0],
                                max_tokens=4, stop_tokens=()))
    with pytest.raises(RuntimeError, match="no survivors"):
        while router.has_work():
            router.step()
    assert router.tickets[tid].status == cl.FAILED


# ==========================================================================
# graceful degradation + metrics
# ==========================================================================

def test_degraded_tier_routing_counted(dense):
    """Tier-affinity requests cross tiers when the tier has no healthy
    replica — flagged on the ticket and counted, not silent."""
    cfg, _ = dense
    clk = FaultClock()
    inj = FaultInjector([FaultSpec(site="step", target="gold", at=1,
                                   kind="fatal")], clock=clk)
    router = EngineRouter(
        [EngineReplica("gold", inj.instrument(_engine(dense), "gold"),
                       tier="fp32"),
         EngineReplica("base", _engine(dense), tier="bf16")],
        clock=clk, sleep=clk.advance)
    reqs = _requests(cfg, [4, 5], seed=11)
    t0 = router.submit(reqs[0], tier="fp32")     # lands on gold, requeues
    while router.has_work():
        router.step()
    t1 = router.submit(reqs[1], tier="fp32")     # gold is gone: degrades
    while router.has_work():
        router.step()
    assert router.tickets[t0].status == cl.COMPLETED
    assert router.tickets[t1].status == cl.COMPLETED
    assert router.tickets[t1].replica.name == "base"
    assert router.tickets[t1].degraded
    assert router.counters["requests_degraded"] >= 1


def test_self_healing_metrics_exposition(dense):
    """The new counters and per-replica gauges render as Prometheus
    families with their own HELP text."""
    cfg, _ = dense
    clk = FaultClock()
    router, _ = _healing_router(dense, clk=clk, specs=[
        FaultSpec(site="step", target="bad", at=2, kind="fatal")])
    router.serve(_requests(cfg, [4, 5], seed=12))
    text = router.metrics().to_prometheus()
    for family in ("repro_serve_retries_total",
                   "repro_serve_replicas_readmitted_total",
                   "repro_serve_probe_failures_total",
                   "repro_serve_requests_degraded_total"):
        assert f"# TYPE {family} counter" in text
    assert 'repro_serve_healthy{replica="bad"} 0' in text
    assert 'repro_serve_probing{replica="bad"} 1' in text
    assert "under health probes" in text       # family-specific HELP


# ==========================================================================
# tuning-cache hardening
# ==========================================================================

@pytest.fixture
def cache_env(tmp_path, monkeypatch):
    path = tmp_path / "tuning.json"
    monkeypatch.setenv(dispatch.TUNING_CACHE_ENV, str(path))
    dispatch.clear_tuning_cache()
    yield str(path)
    dispatch.clear_tuning_cache()


def _resolve(dtype=jnp.float32):
    return dispatch.resolve_blocks("matmul", 64, 64, 64, dtype,
                                   backend="pallas")


@pytest.mark.parametrize("mode", ["garbage", "truncate", "unknown"])
def test_corrupt_cache_falls_back_to_heuristics(cache_env, mode):
    """A corrupt REPRO_TUNING_CACHE warns and degrades to heuristic
    blocks instead of failing the first resolve."""
    _resolve()                                   # seeds a valid file
    assert json.load(open(cache_env))["entries"]
    dispatch.clear_tuning_cache()
    FaultInjector.corrupt_cache(cache_env, mode)
    with pytest.warns(UserWarning, match="corrupt tuning cache"):
        blocks = _resolve()
    assert blocks is not None
    assert dispatch.cache_load_errors() == 1
    # and the next write-through atomically replaces the corrupt file
    dispatch.save_cache(cache_env)
    assert isinstance(json.load(open(cache_env))["entries"], list)


def test_strict_load_cache_still_raises(cache_env):
    FaultInjector.corrupt_cache(cache_env, "garbage")
    with pytest.raises(ValueError):
        dispatch.load_cache(cache_env)           # explicit call: strict
    assert dispatch.load_cache(cache_env, strict=False) == 0
    assert dispatch.cache_load_errors() == 2
    # junk entries inside a valid wrapper are skipped, not fatal
    with open(cache_env, "w") as f:
        json.dump({"version": 1, "entries": ["junk", 7]}, f)
    assert dispatch.load_cache(cache_env) == 0


def test_save_cache_survives_junk_prior_entries(cache_env):
    """save_cache merges over a file with unrecognizable entries by
    dropping them instead of raising mid-write."""
    _resolve()
    with open(cache_env, "w") as f:
        json.dump({"version": 1, "entries": [{"nonsense": True}, "x"]}, f)
    assert dispatch.save_cache(cache_env) >= 1
    data = json.load(open(cache_env))
    assert all(isinstance(e, dict) and "op" in e for e in data["entries"])


# ==========================================================================
# frontend stop/submit race
# ==========================================================================

def test_frontend_abort_resolves_inflight_submit(dense):
    """A submit racing stop(drain=False) resolves terminally — the
    awaiter never hangs on a command in a dead inbox."""
    cfg, _ = dense
    router = EngineRouter([EngineReplica("a", _engine(dense))])
    req = Request(prompt=_prompts(cfg, [4])[0], max_tokens=16,
                  stop_tokens=())

    async def main():
        frontend = AsyncFrontend(router)
        await frontend.start()
        # submit lands in the inbox; stop(drain=False) lands right after,
        # before the loop has stepped either
        handle = await frontend.submit(req)
        stop = asyncio.create_task(frontend.stop(drain=False))
        result = await asyncio.wait_for(handle, timeout=10)
        await asyncio.wait_for(stop, timeout=10)
        assert result.status in (cl.CANCELLED, cl.COMPLETED)

        # and a submit issued *while* aborting resolves immediately
        await frontend.start()
        stop = asyncio.create_task(frontend.stop(drain=False))
        await asyncio.sleep(0)                   # let stop set the flag
        late = await frontend.submit(req)
        late_result = await asyncio.wait_for(late, timeout=10)
        await asyncio.wait_for(stop, timeout=10)
        assert late_result.status == cl.CANCELLED
        assert late_result.tokens == []
    asyncio.run(main())


# ==========================================================================
# runtime alias
# ==========================================================================

def test_runtime_package_exports():
    from repro import runtime
    from repro.runtime.fault_tolerance import HeartbeatMonitor
    assert runtime.HeartbeatMonitor is HeartbeatMonitor
    assert hasattr(runtime, "StragglerDetector")
    assert hasattr(runtime, "run_with_restarts")
