"""The measured tuning surface: op-specific block tuples through
``resolve_blocks``, the autotune policy, and tuning-cache persistence."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import autotune, blocking, dispatch
from repro.core.blocking import AttnBlocks, Blocks, ConvBlocks
from repro.kernels.conv2d import conv2d
from repro.kernels.flash_attention import flash_attention


def _randn(*shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed + len(shape))
    return jnp.asarray(rng.normal(size=shape), dtype)


@pytest.fixture(autouse=True)
def _fresh_cache():
    dispatch.clear_tuning_cache()
    yield
    dispatch.clear_tuning_cache()


# --------------------------------------------------------------------------
# op-specific block tuples through one resolution surface
# --------------------------------------------------------------------------

def test_heuristic_policy_returns_op_specific_tuples():
    assert isinstance(
        dispatch.resolve_blocks("matmul", 64, 64, 64, jnp.float32,
                                backend="pallas"), Blocks)
    assert isinstance(
        dispatch.resolve_blocks("conv2d", 28, 128, 64, jnp.float32,
                                backend="pallas"), ConvBlocks)
    assert isinstance(
        dispatch.resolve_blocks("flash_attention", 128, 128, 64,
                                jnp.float32, backend="pallas"), AttnBlocks)


@pytest.mark.parametrize("blk", [
    Blocks(bm=32, bn=128, bk=256),
    ConvBlocks(bq=16, bc=128, bk=128),
    AttnBlocks(block_q=64, block_k=128),
])
def test_block_tuple_json_round_trip(blk):
    d = blocking.blocks_to_dict(blk)
    json.loads(json.dumps(d))  # actually JSON-serializable
    assert blocking.blocks_from_dict(d) == blk


def test_explicit_conv_blocks_honored_and_parity():
    x = _randn(1, 8, 8, 2, seed=1)
    w = _randn(3, 3, 2, 4, seed=2) * 0.3
    want = conv2d(x, w, stride=1, padding=1, backend="xla")
    for blk in (ConvBlocks(8, 128, 128), ConvBlocks(16, 128, 128)):
        got = conv2d(x, w, stride=1, padding=1, backend="pallas",
                     blocks=blk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
    assert not dispatch.tuning_cache_info()  # explicit blocks bypass


def test_explicit_attn_blocks_honored_and_parity():
    q = _randn(1, 2, 64, 16, seed=3)
    want = flash_attention(q, q, q, backend="xla")
    for blk in (AttnBlocks(32, 128), AttnBlocks(64, 128)):
        got = flash_attention(q, q, q, backend="pallas", blocks=blk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
    assert not dispatch.tuning_cache_info()


def test_conv_and_attention_resolve_through_cache():
    x = _randn(1, 8, 8, 2, seed=1)
    w = _randn(3, 3, 2, 4, seed=2) * 0.3
    conv2d(x, w, backend="pallas")
    q = _randn(1, 2, 32, 16, seed=3)
    flash_attention(q, q, q, backend="pallas")
    ops = {key[0] for key in dispatch.tuning_cache_info()}
    assert {"conv2d", "flash_attention"} <= ops


def test_accum_dtype_threads_into_conv_and_attention():
    x = _randn(1, 8, 8, 2, seed=1)
    w = _randn(3, 3, 2, 4, seed=2) * 0.3
    q = _randn(1, 2, 32, 16, seed=3)
    want_c = conv2d(x, w, backend="xla")
    want_a = flash_attention(q, q, q, backend="xla")
    with repro.use(accum_dtype=jnp.bfloat16):
        got_c = conv2d(x, w, backend="pallas")
        got_a = flash_attention(q, q, q, backend="pallas")
    # bf16 accumulation is lossier but must stay in the right ballpark
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c),
                               rtol=0.1, atol=0.1)
    np.testing.assert_allclose(np.asarray(got_a), np.asarray(want_a),
                               rtol=0.1, atol=0.1)


def test_deprecated_block_kwargs_still_work():
    q = _randn(1, 2, 64, 16, seed=4)
    want = flash_attention(q, q, q, backend="pallas",
                           blocks=AttnBlocks(32, 128))
    with pytest.warns(DeprecationWarning, match="block_q"):
        got = flash_attention(q, q, q, backend="pallas", block_q=32,
                              block_k=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="not both"):
            flash_attention(q, q, q, blocks=AttnBlocks(32, 128), block_q=32)


# --------------------------------------------------------------------------
# candidate grids
# --------------------------------------------------------------------------

@pytest.mark.parametrize("op,shape", [
    ("matmul", (64, 128, 256)),
    ("conv2d", (28, 128, 64)),
    ("flash_attention", (128, 256, 64)),
])
def test_candidates_deterministic_and_include_heuristic(op, shape):
    c1 = blocking.candidate_blocks(op, *shape)
    c2 = blocking.candidate_blocks(op, *shape)
    assert c1 == c2
    assert len(c1) == len(set(c1)) > 1
    assert blocking.default_blocks(op, *shape) in c1


# --------------------------------------------------------------------------
# the measured policy
# --------------------------------------------------------------------------

def _seeded_timer(seed):
    """Deterministic fake cost, pseudo-random in the candidate tuple."""
    def timer(op, m, n, k, dtype, backend, blocks):
        h = hash((seed, op, blocks.astuple()))
        return (h % 1000) / 1000.0
    return timer


def test_autotune_deterministic_under_seeded_costs():
    timer = _seeded_timer(42)
    picks = [autotune.autotune_blocks("matmul", 64, 128, 256, jnp.float32,
                                      "pallas", timer=timer)
             for _ in range(3)]
    assert picks[0] == picks[1] == picks[2]
    # and the pick is the argmin of the injected cost over the pruned grid
    cands = autotune._prune(
        blocking.candidate_blocks("matmul", 64, 128, 256, jnp.float32),
        blocking.default_blocks("matmul", 64, 128, 256, jnp.float32),
        autotune.DEFAULT_MAX_CANDIDATES)
    want = min(cands, key=lambda b: timer(
        "matmul", 64, 128, 256, jnp.float32, "pallas", b))
    assert picks[0] == want


def test_autotune_measurably_changes_selected_tiles():
    heur = blocking.default_blocks("matmul", 256, 256, 256, jnp.float32)

    def timer(op, m, n, k, dtype, backend, blocks):
        return 2.0 if blocks == heur else 1.0  # any non-heuristic tile wins

    with repro.use(blocks_policy=lambda op, m, n, k, dt, be:
                   autotune.autotune_blocks(op, m, n, k, dt, be,
                                            timer=timer)):
        tuned = dispatch.resolve_blocks("matmul", 256, 256, 256,
                                        jnp.float32, backend="pallas")
    assert tuned != heur


def test_autotune_survives_failing_candidates():
    heur = blocking.default_blocks("matmul", 64, 64, 64, jnp.float32)

    def timer(op, m, n, k, dtype, backend, blocks):
        raise RuntimeError("measurement exploded")

    got = autotune.autotune_blocks("matmul", 64, 64, 64, jnp.float32,
                                   "pallas", timer=timer)
    assert got == heur  # falls back to the heuristic pick


def test_autotune_skips_measurement_off_pallas():
    before = autotune.STATS.measured
    got = autotune.autotune_blocks("matmul", 64, 64, 64, jnp.float32, "xla")
    assert got == blocking.default_blocks("matmul", 64, 64, 64, jnp.float32)
    assert autotune.STATS.measured == before


def test_autotune_policy_runs_real_measurement_and_memoizes():
    """Tiny real search (interpret-safe on CPU) through the named policy."""
    before = autotune.STATS.measured
    with repro.use(blocks_policy=lambda op, m, n, k, dt, be:
                   autotune.autotune_blocks(op, m, n, k, dt, be,
                                            max_candidates=2, repeats=1)):
        b1 = dispatch.resolve_blocks("matmul", 16, 16, 16, jnp.float32,
                                     backend="pallas")
        b2 = dispatch.resolve_blocks("matmul", 16, 16, 16, jnp.float32,
                                     backend="pallas")
    assert b1 is b2  # memoized: one search, two resolutions
    assert autotune.STATS.measured == before + 2


# --------------------------------------------------------------------------
# cache persistence
# --------------------------------------------------------------------------

def test_cache_save_load_round_trip(tmp_path):
    path = str(tmp_path / "cache.json")
    for op, shape in [("matmul", (64, 128, 256)), ("conv2d", (28, 128, 64)),
                      ("flash_attention", (128, 128, 64))]:
        dispatch.resolve_blocks(op, *shape, jnp.float32, backend="pallas")
    saved = dispatch.save_cache(path)
    assert saved == 3
    before = dispatch.tuning_cache_info()
    dispatch.clear_tuning_cache()
    assert dispatch.load_cache(path) == 3
    assert dispatch.tuning_cache_info() == before


def test_callable_policy_entries_not_persisted(tmp_path):
    path = str(tmp_path / "cache.json")
    with repro.use(blocks_policy=lambda op, m, n, k, dt, be:
                   Blocks(8, 128, 128)):
        dispatch.resolve_blocks("matmul", 16, 16, 16, jnp.float32,
                                backend="pallas")
    assert dispatch.save_cache(path) == 0


def test_env_cache_written_through_and_reloaded(tmp_path, monkeypatch):
    """Simulates the two-process flow: a cold run persists winners; a fresh
    process (cache cleared) reloads them and re-measures nothing."""
    path = str(tmp_path / "cache.json")
    monkeypatch.setenv(dispatch.TUNING_CACHE_ENV, path)

    calls = []

    def counting_policy(op, m, n, k, dtype, backend):
        calls.append(op)
        return blocking.default_blocks(op, m, n, k, dtype)

    dispatch.register_block_policy("counting", counting_policy)
    try:
        with repro.use(blocks_policy="counting"):
            first = dispatch.resolve_blocks("conv2d", 28, 128, 64,
                                            jnp.float32, backend="pallas")
        assert calls == ["conv2d"]
        assert json.load(open(path))["entries"]  # written through

        dispatch.clear_tuning_cache()  # "new process"
        with repro.use(blocks_policy="counting"):
            second = dispatch.resolve_blocks("conv2d", 28, 128, 64,
                                             jnp.float32, backend="pallas")
        assert calls == ["conv2d"]  # served from the persisted file
        assert second == first
    finally:
        dispatch.BLOCK_POLICIES.pop("counting", None)


def test_load_cache_requires_path(monkeypatch):
    monkeypatch.delenv(dispatch.TUNING_CACHE_ENV, raising=False)
    with pytest.raises(ValueError, match=dispatch.TUNING_CACHE_ENV):
        dispatch.save_cache()
    with pytest.raises(ValueError, match=dispatch.TUNING_CACHE_ENV):
        dispatch.load_cache()


# --------------------------------------------------------------------------
# end-to-end: tuned context changes execution, parity holds
# --------------------------------------------------------------------------

def test_conv_and_attention_parity_under_autotune_policy():
    x = _randn(1, 8, 8, 2, seed=5)
    w = _randn(3, 3, 2, 4, seed=6) * 0.3
    q = _randn(1, 2, 32, 16, seed=7)
    want_c = conv2d(x, w, backend="xla")
    want_a = flash_attention(q, q, q, backend="xla")
    with repro.use(blocks_policy=lambda op, m, n, k, dt, be:
                   autotune.autotune_blocks(op, m, n, k, dt, be,
                                            max_candidates=2, repeats=1)):
        got_c = conv2d(x, w, backend="pallas")
        got_a = flash_attention(q, q, q, backend="pallas")
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(got_a), np.asarray(want_a),
                               rtol=2e-3, atol=2e-3)
