"""Paper-faithful primitive tests: FC (Alg 5), LSTM (Alg 2), conv (Alg 4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv2d import conv2d
from repro.kernels.conv2d.ref import conv2d_loops_ref, conv2d_ref
from repro.layers import conv as conv_layer
from repro.layers import linear, lstm

RNG = np.random.default_rng(7)


def randn(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


# ----------------------------- FC (Alg 5) -----------------------------

def test_fc_forward_matches_blas():
    p = linear.init(jax.random.PRNGKey(0), 96, 64)
    x = randn(32, 96)
    got = linear.apply(p, x, activation="relu", backend="pallas")
    want = np.maximum(np.asarray(x) @ np.asarray(p["w"])
                      + np.asarray(p["b"]), 0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_fc_bwd_upd_via_brgemm():
    """Paper Sec 4.1.3: BWD uses N/C parallelism, UPD reduces over N."""
    p = linear.init(jax.random.PRNGKey(0), 48, 40)
    x = randn(16, 48)

    def loss(p, x):
        return (linear.apply(p, x, activation="sigmoid",
                             backend="pallas") ** 2).sum()

    gp = jax.grad(loss, argnums=(0, 1))(p, x)
    gr = jax.grad(lambda p, x: (linear.apply(p, x, activation="sigmoid",
                                             backend="xla") ** 2).sum(),
                  argnums=(0, 1))(p, x)
    for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ----------------------------- LSTM (Alg 2) ---------------------------

def test_lstm_cell_equations():
    """Pin Eq. 1-6 semantics against a numpy reimplementation."""
    c, k, n = 16, 24, 4
    p = lstm.init(jax.random.PRNGKey(0), c, k)
    x = randn(3, n, c)
    h, s = lstm.forward(p, x, backend="xla")

    def sig(v):
        return 1 / (1 + np.exp(-v))

    W, R, B = (np.asarray(p[k_]) for k_ in ("w", "r", "b"))
    h_prev = np.zeros((n, k), np.float32)
    s_prev = np.zeros((n, k), np.float32)
    for t in range(3):
        xt = np.asarray(x[t])
        pre = [xt @ W[i] + h_prev @ R[i] + B[i] for i in range(4)]
        i_t, c_t, f_t, o_t = sig(pre[0]), np.tanh(pre[1]), sig(pre[2]), \
            sig(pre[3])
        s_prev = f_t * s_prev + i_t * c_t
        h_prev = o_t * np.tanh(s_prev)
        np.testing.assert_allclose(np.asarray(h[t]), h_prev, rtol=2e-4,
                                   atol=2e-4)
        np.testing.assert_allclose(np.asarray(s[t]), s_prev, rtol=2e-4,
                                   atol=2e-4)


def test_lstm_pallas_matches_xla():
    p = lstm.init(jax.random.PRNGKey(1), 20, 28)
    x = randn(4, 3, 20)
    hp, sp = lstm.forward(p, x, backend="pallas")
    hr, sr = lstm.forward(p, x, backend="xla")
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), rtol=1e-4,
                               atol=1e-4)


# ----------------------------- conv (Alg 4) ---------------------------

@pytest.mark.parametrize("case", [
    dict(n=1, h=8, w=8, c=4, k=8, r=3, s=3, stride=1, padding=1),
    dict(n=2, h=10, w=10, c=6, k=5, r=3, s=3, stride=2, padding=1),
    dict(n=1, h=6, w=6, c=3, k=4, r=1, s=1, stride=1, padding=0),
    dict(n=1, h=9, w=9, c=3, k=4, r=7, s=7, stride=2, padding=3),
])
def test_conv_pallas_matches_ref(case):
    x = randn(case["n"], case["h"], case["w"], case["c"])
    w = randn(case["r"], case["s"], case["c"], case["k"]) * 0.2
    b = randn(case["k"])
    got = conv2d(x, w, b, stride=case["stride"], padding=case["padding"],
                 activation="relu", backend="pallas")
    want = conv2d_ref(x, w, b, stride=case["stride"],
                      padding=case["padding"], activation="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv_semantics_vs_paper_loop_nest():
    """Algorithm 3/4 semantics pinned by the literal loop oracle."""
    x = randn(1, 6, 6, 2)
    w = randn(3, 3, 2, 4) * 0.3
    want = conv2d_loops_ref(x, w, stride=2, padding=1)
    got = conv2d(x, w, stride=2, padding=1, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv_dual_backward():
    """Paper Sec 3.2.2: bwd-data/weight-update as dual convolutions."""
    x = randn(2, 8, 8, 4)
    p = conv_layer.init(jax.random.PRNGKey(0), 4, 8, 3, 3)

    def lp(p, x):
        return (conv_layer.apply(p, x, stride=2, padding=1,
                                 activation="relu",
                                 backend="pallas") ** 2).sum()

    def lr(p, x):
        return (conv_layer.apply(p, x, stride=2, padding=1,
                                 activation="relu",
                                 backend="xla") ** 2).sum()

    gp = jax.grad(lp, argnums=(0, 1))(p, x)
    gr = jax.grad(lr, argnums=(0, 1))(p, x)
    for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)
