"""Paged KV cache tests: page-allocator invariants under random churn,
paged-vs-slotted greedy token parity (dense + enc-dec), the slotted
fallback for non-pageable architectures, chunked-prefill equivalence,
quantized page storage, preemption under page pressure, and the
page-aware attention block geometry."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import blocking, dispatch
from repro.models import api
from repro.serve import (
    ContinuousEngine,
    PagedKVCache,
    PoolConfig,
    Request,
    SlotKVCache,
)

MAX_LEN = 32
SRC_LEN = 6
PAGE = 8
PROMPT_LENS = [5, 20, 3, 17, 7]
MAX_TOKENS = [6, 4, 8, 3, 5]


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def seamless():
    cfg = configs.get("seamless-m4t-large-v2").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, n).tolist() for n in lens]


def _requests(prompts, src=None):
    return [Request(prompt=p, max_tokens=m, stop_tokens=(),
                    src_embeds=None if src is None else src[i])
            for i, (p, m) in enumerate(zip(prompts, MAX_TOKENS))]


def _serve(cfg, params, pool, requests):
    eng = ContinuousEngine(cfg, params, pool, interpret=True)
    return eng, eng.serve(requests)


# ==========================================================================
# page allocator invariants (no jax compute)
# ==========================================================================

def test_page_allocator_churn_no_leaks_no_double_free(dense):
    cfg, _ = dense
    pool = PagedKVCache(cfg, n_slots=4, max_len=MAX_LEN, page_size=PAGE,
                        n_pages=12)
    rng = np.random.default_rng(0)
    live = {}
    for _ in range(300):
        if live and (rng.random() < 0.4 or pool.n_free == 0):
            slot = rng.choice(sorted(live))
            pool.free(slot)
            del live[slot]
            continue
        slot = pool.alloc()
        if slot is None:
            continue
        n = int(rng.integers(1, MAX_LEN + 1))
        if pool.alloc_pages(slot, -(-n // PAGE)):
            pool.lengths[slot] = n
            live[slot] = n
        else:
            pool.free(slot)   # all-or-nothing: nothing was allocated
    # invariant under churn: every page is either free or in exactly one
    # live slot's table
    held = sum(int(pool.pages_used[s]) for s in live)
    assert held + pool.n_free_pages == pool.n_pages
    table_ids = [int(p) for s in live
                 for p in pool.page_tables[s][:pool.pages_used[s]]]
    assert len(table_ids) == len(set(table_ids)) == held
    for slot in sorted(live):
        pool.free(slot)
    assert pool.n_free == 4 and pool.n_free_pages == pool.n_pages
    assert pool.alloc_count == pool.free_count
    assert pool.page_alloc_count == pool.page_free_count
    assert pool.fragmentation == 0.0 and pool.page_occupancy == 0.0


def test_page_allocator_double_free_and_overflow_raise(dense):
    cfg, _ = dense
    pool = PagedKVCache(cfg, n_slots=2, max_len=MAX_LEN, page_size=PAGE)
    slot = pool.alloc()
    assert pool.ensure(slot, 0)
    pool.free(slot)
    with pytest.raises(ValueError, match="double free"):
        pool.free(slot)
    slot = pool.alloc()
    with pytest.raises(ValueError, match="pages_per_slot"):
        pool.alloc_pages(slot, pool.pages_per_slot + 1)


def test_page_allocator_all_or_nothing(dense):
    cfg, _ = dense
    pool = PagedKVCache(cfg, n_slots=2, max_len=MAX_LEN, page_size=PAGE,
                        n_pages=4)
    a, b = pool.alloc(), pool.alloc()
    assert pool.alloc_pages(a, 3)
    assert not pool.alloc_pages(b, 2)      # only 1 free: refuse whole ask
    assert pool.pages_used[b] == 0          # nothing partially granted
    assert pool.alloc_pages(b, 1)
    assert pool.n_free_pages == 0


def test_fragmentation_counts_trailing_page_waste(dense):
    cfg, _ = dense
    pool = PagedKVCache(cfg, n_slots=2, max_len=MAX_LEN, page_size=PAGE)
    slot = pool.alloc()
    assert pool.ensure(slot, PAGE)          # 2 pages for position 8
    pool.lengths[slot] = PAGE + 1           # 9 live tokens in 16 capacity
    assert pool.fragmentation == pytest.approx(1 - 9 / 16)


def test_paged_pool_rejected_for_windowed_arch():
    cfg = configs.get("recurrentgemma-9b").reduced()
    with pytest.raises(ValueError, match="paging is not supported"):
        PagedKVCache(cfg, n_slots=2, max_len=MAX_LEN, page_size=PAGE)


# ==========================================================================
# paged decode parity
# ==========================================================================

def test_paged_greedy_parity_dense(dense):
    cfg, params = dense
    prompts = _prompts(cfg, PROMPT_LENS)
    _, ref = _serve(cfg, params, PoolConfig(n_slots=3, max_len=MAX_LEN),
                    _requests(prompts))
    eng, out = _serve(cfg, params,
                      PoolConfig(n_slots=3, max_len=MAX_LEN,
                                 page_size=PAGE),
                      _requests(prompts))
    assert eng.paged and isinstance(eng.pool, PagedKVCache)
    assert out == ref
    assert eng.pool.page_alloc_count == eng.pool.page_free_count
    assert eng.pool.n_free_pages == eng.pool.n_pages


def test_paged_greedy_parity_encdec(seamless):
    cfg, params = seamless
    prompts = _prompts(cfg, PROMPT_LENS)
    rng = np.random.default_rng(3)
    src = [jnp.asarray(rng.normal(size=(SRC_LEN, cfg.d_model)), jnp.float32)
           for _ in prompts]
    _, ref = _serve(cfg, params,
                    PoolConfig(n_slots=3, max_len=MAX_LEN, src_len=SRC_LEN),
                    _requests(prompts, src))
    eng, out = _serve(cfg, params,
                      PoolConfig(n_slots=3, max_len=MAX_LEN,
                                 src_len=SRC_LEN, page_size=PAGE),
                      _requests(prompts, src))
    assert eng.paged
    # the cross-KV leaves must have stayed slot-resident
    assert any(t == -1 for t in jax.tree.leaves(eng.pool.time_axes))
    assert out == ref


def test_windowed_arch_falls_back_to_slotted():
    cfg = configs.get("recurrentgemma-9b").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousEngine(
        cfg, params, PoolConfig(n_slots=2, max_len=MAX_LEN, page_size=PAGE),
        interpret=True)
    assert not eng.paged and isinstance(eng.pool, SlotKVCache)
    prompts = _prompts(cfg, [4, 6])
    out = eng.serve([Request(prompt=p, max_tokens=3, stop_tokens=())
                     for p in prompts])
    assert all(len(t) == 3 for t in out.values())


def test_preemption_under_page_pressure_keeps_parity(dense):
    cfg, params = dense
    prompts = _prompts(cfg, PROMPT_LENS, seed=1)
    _, ref = _serve(cfg, params, PoolConfig(n_slots=3, max_len=MAX_LEN),
                    _requests(prompts))
    # 8 pages of 4 = 32 tokens of KV for 3 slots wanting up to 96: the
    # engine must preempt to make progress, and still match greedy
    eng, out = _serve(cfg, params,
                      PoolConfig(n_slots=3, max_len=MAX_LEN, page_size=4,
                                 n_pages=8),
                      _requests(prompts))
    assert eng.metrics.preemptions > 0
    assert out == ref
    assert eng.pool.page_alloc_count == eng.pool.page_free_count


def test_quantized_pages_parity_within_tolerance(dense):
    cfg, params = dense
    prompts = _prompts(cfg, PROMPT_LENS)
    _, ref = _serve(cfg, params, PoolConfig(n_slots=3, max_len=MAX_LEN),
                    _requests(prompts))
    eng, out = _serve(cfg, params,
                      PoolConfig(n_slots=3, max_len=MAX_LEN,
                                 page_size=PAGE, kv_quant="int8"),
                      _requests(prompts))
    assert eng.pool.scales is not None
    paged_leaves = [x for x, t in zip(jax.tree.leaves(eng.pool.data),
                                      jax.tree.leaves(eng.pool.time_axes))
                    if t != -1]
    assert all(x.dtype == jnp.int8 for x in paged_leaves)
    # int8 KV is lossy, so token-for-token equality is not guaranteed;
    # on this reduced model the greedy argmax should still rarely flip
    match = sum(out[k] == ref[k] for k in ref)
    assert match >= len(ref) - 1


def test_kv_quant_requires_paged_pool(dense):
    cfg, params = dense
    with pytest.raises(ValueError, match="kv_quant requires page_size"):
        ContinuousEngine(cfg, params,
                         PoolConfig(n_slots=2, max_len=MAX_LEN,
                                    kv_quant="int8"))


# ==========================================================================
# chunked prefill
# ==========================================================================

def test_chunked_prefill_matches_one_shot_logits(dense):
    cfg, params = dense
    prompt = _prompts(cfg, [19], seed=2)[0]
    cache = api.init_cache(cfg, 1, MAX_LEN)
    logits_full, _ = api.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, cfg,
        api.init_cache(cfg, 1, MAX_LEN))
    pos, logits = 0, None
    for chunk in (prompt[0:8], prompt[8:16], prompt[16:19]):
        logits, cache = api.prefill_chunk(
            params, {"tokens": jnp.asarray([chunk], jnp.int32)}, cfg,
            cache, pos)
        pos += len(chunk)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_full),
                               rtol=2e-4, atol=2e-4)


def test_chunked_prefill_serving_parity(dense):
    cfg, params = dense
    prompts = _prompts(cfg, PROMPT_LENS)
    _, ref = _serve(cfg, params, PoolConfig(n_slots=3, max_len=MAX_LEN),
                    _requests(prompts))
    eng, out = _serve(cfg, params,
                      PoolConfig(n_slots=3, max_len=MAX_LEN, page_size=4,
                                 prefill_chunk=8),
                      _requests(prompts))
    assert eng.metrics.prefill_chunks > 0
    assert out == ref


def test_chunked_prefill_serving_parity_encdec(seamless):
    cfg, params = seamless
    prompts = _prompts(cfg, PROMPT_LENS)
    rng = np.random.default_rng(3)
    src = [jnp.asarray(rng.normal(size=(SRC_LEN, cfg.d_model)), jnp.float32)
           for _ in prompts]
    _, ref = _serve(cfg, params,
                    PoolConfig(n_slots=3, max_len=MAX_LEN, src_len=SRC_LEN),
                    _requests(prompts, src))
    eng, out = _serve(cfg, params,
                      PoolConfig(n_slots=3, max_len=MAX_LEN,
                                 src_len=SRC_LEN, page_size=4,
                                 prefill_chunk=8),
                      _requests(prompts, src))
    assert eng.metrics.prefill_chunks > 0
    assert out == ref


def test_chunked_prefill_stalls_decode_at_most_one_step(dense):
    """While a long prompt is chunking, already-running requests must
    keep generating one token every step (no multi-step stalls)."""
    cfg, params = dense
    eng = ContinuousEngine(
        cfg, params,
        PoolConfig(n_slots=2, max_len=MAX_LEN, page_size=4,
                   prefill_chunk=4),
        interpret=True)
    prompts = _prompts(cfg, [3, 20])
    first = eng.submit(Request(prompt=prompts[0], max_tokens=10,
                               stop_tokens=()))
    eng.step()   # request 0 admitted and decoding
    eng.submit(Request(prompt=prompts[1], max_tokens=2, stop_tokens=()))
    first_done = False
    for _ in range(40):
        got = [e for e in eng.step() if e[0] == first]
        if not first_done:
            assert got, "running decode stalled during chunked prefill"
            first_done = any(e[2] for e in got)
        if not eng.has_work():
            break
    assert not eng.has_work() and eng.metrics.prefill_chunks >= 5


def test_chunk_rejected_for_windowed_arch():
    cfg = configs.get("recurrentgemma-9b").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="prefill_chunk is not supported"):
        ContinuousEngine(cfg, params,
                         PoolConfig(n_slots=2, max_len=MAX_LEN,
                                    prefill_chunk=8))


def test_chunk_must_align_to_page(dense):
    cfg, params = dense
    with pytest.raises(ValueError, match="multiple of page_size"):
        ContinuousEngine(cfg, params,
                         PoolConfig(n_slots=2, max_len=MAX_LEN,
                                    page_size=8, prefill_chunk=12))


# ==========================================================================
# page-table view round trip + paged attention geometry
# ==========================================================================

def test_pages_to_view_round_trip():
    rng = np.random.default_rng(0)
    view = jnp.asarray(rng.normal(size=(4, 1, 2, 16, 8)), jnp.float32)
    pages = api.view_to_pages(view, a=1, t=3, page_size=4)
    assert pages.shape == (4, 4, 2, 4, 8)
    back = api.pages_to_view(pages, a=1, t=3)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(view))


def test_paged_attn_geometry_clamps_block_k():
    geom = blocking.PagedAttnGeometry(page_size=128, pages=64)
    blocks = blocking.default_blocks("flash_attention", 256, 8192, 64,
                                     geometry=geom)
    assert blocks.block_k <= 128
    cands = blocking.candidate_blocks("flash_attention", 256, 8192, 64,
                                      geometry=geom)
    assert all(c.block_k <= 128 or c == blocks for c in cands)
    # distinct tuning-cache identity + JSON round trip
    d = geom.asdict()
    assert d["kind"] == "paged_attn"
    assert blocking.geometry_from_dict(d) == geom
    free = blocking.candidate_blocks("flash_attention", 256, 8192, 64)
    assert max(c.block_k for c in free) > 128


def test_paged_geometry_resolves_through_dispatch():
    geom = blocking.PagedAttnGeometry(page_size=256, pages=32)
    paged = dispatch.resolve_blocks("flash_attention", 128, 4096, 64,
                                    jnp.float32, backend="pallas",
                                    geometry=geom)
    flat = dispatch.resolve_blocks("flash_attention", 128, 4096, 64,
                                   jnp.float32, backend="pallas")
    assert paged.block_k <= 256
    assert isinstance(paged, blocking.AttnBlocks)
    assert isinstance(flat, blocking.AttnBlocks)


# ==========================================================================
# trace sampling
# ==========================================================================

def test_trace_sample_rate_every_nth(dense):
    from repro import obs
    cfg, params = dense
    eng = ContinuousEngine(
        cfg, params, PoolConfig(n_slots=2, max_len=MAX_LEN),
        interpret=True, trace_sample_rate=3)
    prompts = _prompts(cfg, [4] * 6)
    tracer = obs.Tracer()
    prev = obs.install(tracer)
    try:
        eng.serve([Request(prompt=p, max_tokens=2, stop_tokens=())
                   for p in prompts])
    finally:
        obs.install(prev)
    reqs = [s for s in tracer.spans() if s.name == "request"]
    # every 3rd submission sampled: requests 0 and 3 of 6
    assert sorted(s.attrs["request_id"] for s in reqs) == [0, 3]
    # counters stay always-on for unsampled requests
    assert eng.metrics.requests_completed == 6


def test_trace_explicit_id_and_opt_out(dense):
    from repro import obs
    cfg, params = dense
    eng = ContinuousEngine(
        cfg, params, PoolConfig(n_slots=2, max_len=MAX_LEN),
        interpret=True, trace_sample_rate=1000)
    prompts = _prompts(cfg, [4] * 3)
    tracer = obs.Tracer()
    prev = obs.install(tracer)
    try:
        reqs = [Request(prompt=p, max_tokens=2, stop_tokens=())
                for p in prompts]
        eng.submit(reqs[0])                   # rate-sampled (first => yes)
        eng.submit(reqs[1], trace="forced")   # explicit id => sampled
        eng.submit(reqs[2], trace="")         # opt-out
        while eng.has_work():
            eng.step()
    finally:
        obs.install(prev)
    sampled = {s.attrs["request_id"] for s in tracer.spans()
               if s.name == "request"}
    assert sampled == {0, 1}
