"""Observability tests: tracer fast path and thread safety, dispatch
telemetry + blocks-source classification, unified autotune STATS, FLOP
accounting, Chrome export round-trip, latency histograms, engine TTFT
breakdown exactness, and the serve-layer span/event wiring."""
import asyncio
import json
import threading
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import configs, obs
from repro.core import autotune, dispatch
from repro.models import api
from repro.obs.telemetry import TELEMETRY
from repro.serve import (
    AsyncFrontend,
    ContinuousEngine,
    EngineReplica,
    EngineRouter,
    LatencyHistogram,
    PoolConfig,
    Request,
    ServeMetrics,
)

MAX_LEN = 32


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    """Every test starts and ends with tracing disabled."""
    obs.install(None)
    yield
    obs.install(None)


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab, 3 + i % 5).tolist(),
                    max_tokens=2 + i % 3, stop_tokens=())
            for i in range(n)]


class FakeClock:
    """Deterministic strictly-increasing clock."""

    def __init__(self, dt=1.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


# ---------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------

def test_disabled_fast_path_allocates_nothing():
    assert obs.current_tracer() is None
    # the no-op span is a shared singleton: same object every call
    s1 = obs.span("anything", x=1)
    s2 = obs.span("else")
    assert s1 is s2 is obs.NULL_SPAN
    with s1 as inner:
        assert inner is obs.NULL_SPAN
        inner.set(a=1).event("e")
    obs.event("nothing")     # all no-ops, no error
    obs.annotate(a=2)


def test_span_nesting_and_parent_links():
    tr = obs.Tracer(clock=FakeClock())
    with tr.span("outer") as outer:
        with tr.span("inner") as inner:
            pass
    spans = tr.spans()
    # completion order: children land before parents
    assert [s.name for s in spans] == ["inner", "outer"]
    by_name = {s.name: s for s in spans}
    assert by_name["outer"].parent_id is None
    assert by_name["inner"].parent_id == by_name["outer"].span_id
    assert inner.span_id != outer.span_id


def test_injectable_clock_durations():
    tr = obs.Tracer(clock=FakeClock(dt=1.0))
    with tr.span("a"):
        pass                       # t0=1, t1=2
    (rec,) = tr.spans("a")
    assert rec.t0 == 1.0 and rec.t1 == 2.0 and rec.duration_s == 1.0


def test_ring_buffer_capacity_bounds_memory():
    tr = obs.Tracer(capacity=8, clock=FakeClock())
    for i in range(20):
        with tr.span(f"s{i}"):
            pass
    recs = tr.records()
    assert len(recs) == 8
    assert recs[0].name == "s12" and recs[-1].name == "s19"


def test_events_parent_to_open_span_and_attrs():
    tr = obs.Tracer(clock=FakeClock())
    tr.event("free")                      # outside any span
    with tr.span("work") as sp:
        tr.event("mark", k="v")
        sp.set(extra=1)
    free, mark = tr.events("free")[0], tr.events("mark")[0]
    assert free.span_id is None
    assert mark.span_id == sp.span_id and mark.attrs == {"k": "v"}
    assert tr.spans("work")[0].attrs["extra"] == 1


def test_add_span_synthetic_with_parent():
    tr = obs.Tracer()
    root = tr.add_span("request", 1.0, 5.0, status="done")
    child = tr.add_span("request.queue", 1.0, 2.0, parent_id=root.span_id)
    assert child.parent_id == root.span_id
    assert root.attrs == {"status": "done"}
    assert root.duration_s == 4.0


def test_install_global_and_scoped_precedence():
    g, s = obs.Tracer(), obs.Tracer()
    prev = obs.install(g)
    assert prev is None
    try:
        assert obs.current_tracer() is g
        with obs.activate(s):
            assert obs.current_tracer() is s     # scoped wins
        assert obs.current_tracer() is g
    finally:
        obs.install(None)
    assert obs.current_tracer() is None


def test_repro_use_tracer_scopes_activation():
    tr = obs.Tracer()
    assert obs.current_tracer() is None
    with repro.use(tracer=tr):
        assert obs.current_tracer() is tr
        with obs.span("inside"):
            pass
    assert obs.current_tracer() is None
    assert [s.name for s in tr.spans()] == ["inside"]


def test_tracer_thread_safety_independent_stacks():
    tr = obs.Tracer()
    obs.install(tr)
    barrier = threading.Barrier(4)

    def work(i):
        barrier.wait()
        for j in range(25):
            with obs.span(f"outer{i}"):
                with obs.span(f"inner{i}"):
                    pass

    with ThreadPoolExecutor(4) as ex:
        list(ex.map(work, range(4)))
    obs.install(None)
    assert len(tr.spans()) == 4 * 25 * 2
    # each thread nests on its own stack: every inner's parent is an
    # outer of the *same* worker index, recorded on the same thread
    by_id = {s.span_id: s for s in tr.spans()}
    for s in tr.spans():
        if s.name.startswith("inner"):
            parent = by_id[s.parent_id]
            assert parent.name == "outer" + s.name[len("inner"):]
            assert parent.thread == s.thread


# ---------------------------------------------------------------------
# chrome export
# ---------------------------------------------------------------------

def test_chrome_round_trip(tmp_path):
    tr = obs.Tracer(clock=FakeClock(dt=0.5))
    with tr.span("outer", op="matmul"):
        with tr.span("inner"):
            pass
        tr.event("mark", k=1)
    path = tmp_path / "trace.json"
    n = obs.export_chrome(tr, str(path))
    trace = obs.chrome.load(str(path))
    assert obs.chrome.validate(trace) == n
    events = trace["traceEvents"]
    assert trace["displayTimeUnit"] == "ms"
    complete = {e["name"]: e for e in events if e["ph"] == "X"}
    assert set(complete) == {"outer", "inner"}
    assert complete["outer"]["args"]["op"] == "matmul"
    # timestamps are microseconds relative to the earliest record
    assert complete["outer"]["dur"] == pytest.approx(2.0e6)
    assert complete["inner"]["ts"] >= 0
    instants = [e for e in events if e["ph"] == "i"]
    assert len(instants) == 1 and instants[0]["name"] == "mark"


def test_chrome_validate_rejects_malformed():
    with pytest.raises(ValueError):
        obs.chrome.validate({"nope": []})
    with pytest.raises(ValueError):
        obs.chrome.validate({"traceEvents": [{"name": "x"}]})


def test_chrome_summarize_and_cli(tmp_path, capsys):
    tr = obs.Tracer(clock=FakeClock())
    for _ in range(3):
        with tr.span("step"):
            pass
    assert tr.summary()["step"]["count"] == 3
    path = tmp_path / "t.json"
    obs.export_chrome(tr, str(path))
    table = obs.summarize(obs.chrome.load(str(path)))
    assert "step" in table and "count" in table
    from repro.obs.__main__ import main as obs_main
    obs_main(["summarize", str(path)])
    out = capsys.readouterr().out
    assert "step" in out and str(path) in out


# ---------------------------------------------------------------------
# flops accounting
# ---------------------------------------------------------------------

def test_op_cost_matmul_and_quant_bytes():
    c = obs.op_cost("matmul", 64, 32, 16, jnp.float32)
    assert c.flops == 2 * 64 * 32 * 16
    assert c.bytes == 64 * 16 * 4 + 16 * 32 * 4 + 64 * 32 * 4
    q = obs.op_cost("matmul", 64, 32, 16, jnp.int8, quant="int8")
    assert q.flops == c.flops
    assert q.bytes == 64 * 16 * 1 + 16 * 32 * 1 + 64 * 32 * 4
    assert q.intensity > c.intensity


def test_op_cost_batch_and_attention():
    b = obs.op_cost("brgemm", 8, 8, 8, jnp.float32, batch=16)
    assert b.flops == 16 * 2 * 8 * 8 * 8
    fa = obs.op_cost("flash_attention", 128, 128, 64, jnp.float32)
    assert fa.flops == 4 * 128 * 128 * 64
    bwd = obs.op_cost("flash_attention_bwd", 128, 128, 64, jnp.float32)
    assert bwd.flops == 10 * 128 * 128 * 64
    with pytest.raises(ValueError):
        obs.op_cost("nonsense", 1, 1, 1, jnp.float32)


# ---------------------------------------------------------------------
# dispatch telemetry
# ---------------------------------------------------------------------

def test_dispatch_resolution_counts():
    TELEMETRY.reset()
    with repro.use(backend="xla"):
        assert dispatch.resolve("brgemm") == "xla"
        dispatch.resolve("matmul")
    snap = TELEMETRY.snapshot()
    assert snap["op_dispatch"][("brgemm", "xla")] == 1
    assert snap["op_dispatch"][("matmul", "xla")] == 1
    assert snap["fallbacks"] == {}


def test_fallback_reason_counted_and_traced():
    dispatch.register("obs_fake_op", "pallas", lambda: None,
                      available=lambda: False)
    dispatch.register("obs_fake_op", "xla", lambda: None)
    tr = obs.Tracer()
    try:
        TELEMETRY.reset()
        with repro.use(backend="pallas", tracer=tr):
            assert dispatch.resolve("obs_fake_op") == "xla"
        snap = TELEMETRY.snapshot()
        assert snap["fallbacks"] == {"pallas_unavailable": 1}
        assert snap["op_dispatch"][("obs_fake_op", "xla")] == 1
        (ev,) = tr.events("dispatch")
        assert ev.attrs["fallback_from"] == "pallas"
        assert ev.attrs["backend"] == "xla"
    finally:
        dispatch._REGISTRY.pop("obs_fake_op", None)


def test_blocks_source_heuristic_then_cache_hit():
    dispatch.clear_tuning_cache()
    TELEMETRY.reset()
    tr = obs.Tracer()
    with repro.use(tracer=tr):
        b1 = dispatch.resolve_blocks("matmul", 640, 640, 640, jnp.float32,
                                     backend="pallas")
        b2 = dispatch.resolve_blocks("matmul", 640, 640, 640, jnp.float32,
                                     backend="pallas")
    assert b1 == b2
    snap = TELEMETRY.snapshot()
    assert snap["blocks_source"] == {"heuristic": 1, "cache-hit": 1}
    assert snap["cache_misses"] == 1 and snap["cache_hits"] == 1
    ev1, ev2 = tr.events("resolve_blocks")
    assert ev1.attrs["source"] == "heuristic"
    assert ev2.attrs["source"] == "cache-hit"
    # the event carries the roofline coordinates of the problem
    assert ev1.attrs["flops"] == 2.0 * 640 ** 3
    assert ev1.attrs["intensity"] > 0
    dispatch.clear_tuning_cache()


def test_blocks_event_carries_quant_tag():
    dispatch.clear_tuning_cache()
    tr = obs.Tracer()
    with repro.use(tracer=tr):
        dispatch.resolve_blocks("matmul", 64, 64, 64, jnp.int8,
                                backend="pallas", quant="int8")
    (ev,) = tr.events("resolve_blocks")
    assert ev.attrs["quant"] == "int8"
    assert ev.attrs["dtype"] == "int8"
    dispatch.clear_tuning_cache()


def test_autotune_unified_stats_and_measured_source(monkeypatch):
    monkeypatch.delenv(dispatch.TUNING_CACHE_ENV, raising=False)
    dispatch.clear_tuning_cache()
    TELEMETRY.reset()
    assert autotune.STATS.searches == 0
    tr = obs.Tracer()
    a = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                    jnp.float32)
    from repro.kernels.brgemm.ops import matmul
    with repro.use(backend="pallas", interpret=True,
                   blocks_policy="autotune", tracer=tr):
        jax.block_until_ready(matmul(a, a))
    # STATS is a property proxy over TELEMETRY: one source of truth
    assert autotune.STATS.searches == TELEMETRY.autotune["searches"] >= 1
    assert autotune.STATS.measured == TELEMETRY.autotune["measured"] >= 1
    assert autotune.STATS.snapshot() == dict(TELEMETRY.autotune)
    assert TELEMETRY.snapshot()["blocks_source"].get(
        "autotune-measured", 0) >= 1
    # writes through the proxy land in the shared store too
    autotune.STATS.searches += 1
    assert TELEMETRY.autotune["searches"] == autotune.STATS.searches
    # per-candidate measurement spans, each stamped with its rate
    searches = tr.spans("autotune.search")
    measures = tr.spans("autotune.measure")
    assert len(searches) >= 1 and len(measures) >= 1
    assert searches[0].attrs["op"] == "matmul"
    assert "best" in searches[0].attrs
    assert all(m.attrs["seconds"] > 0 for m in measures)
    dispatch.clear_tuning_cache()


def test_prometheus_telemetry_families_always_present():
    TELEMETRY.reset()
    from repro.serve.metrics import render_prometheus
    # headers are emitted even with zero samples => stable families
    text = render_prometheus([({"replica": "r0"}, ServeMetrics())])
    for fam in ("repro_op_dispatch_total", "repro_backend_fallbacks_total",
                "repro_tuning_cache_hits_total",
                "repro_tuning_cache_misses_total",
                "repro_blocks_source_total",
                "repro_autotune_searches_total"):
        assert f"# TYPE {fam} counter" in text
    TELEMETRY.record_dispatch("matmul", "xla")
    text = render_prometheus([({"replica": "r0"}, ServeMetrics())])
    assert 'repro_op_dispatch_total{op="matmul",backend="xla"} 1' in text
    TELEMETRY.reset()


# ---------------------------------------------------------------------
# latency histograms
# ---------------------------------------------------------------------

def test_histogram_observe_quantile_merge():
    h = LatencyHistogram(bounds=(0.01, 0.1, 1.0))
    assert h.quantile(0.5) == 0.0                 # empty
    for v in (0.005, 0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count == 5
    assert h.total_s == pytest.approx(5.56)
    assert h.quantile(0.0) <= h.quantile(0.5) <= h.quantile(1.0)
    assert h.quantile(1.0) == 1.0                 # overflow -> last bound
    other = LatencyHistogram(bounds=(0.01, 0.1, 1.0))
    other.observe(0.05, n=3)
    merged = h + other
    assert merged.count == 8
    assert merged.counts[1] == 1 + 3
    with pytest.raises(ValueError):
        h + LatencyHistogram(bounds=(1.0, 2.0))


def test_histogram_prometheus_cumulative_buckets():
    h = LatencyHistogram(bounds=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5, n=2)
    h.observe(7.0)
    lines = h.prometheus_lines("repro_serve_ttft_seconds",
                               '{replica="r0"}')
    text = "\n".join(lines)
    assert 'le="0.1"} 1' in text
    assert 'le="1.0"} 3' in text                  # cumulative
    assert 'le="+Inf"} 4' in text
    assert text.count('replica="r0"') == len(lines)
    assert "_sum" in text and "_count" in text


def test_serve_metrics_snapshot_has_percentiles():
    m = ServeMetrics()
    m.ttft_hist.observe(0.02)
    m.ttft_hist.observe(0.2)
    m.token_latency_hist.observe(0.004, n=10)
    snap = m.snapshot()
    assert snap["ttft_p50_s"] > 0
    assert snap["ttft_p99_s"] >= snap["ttft_p50_s"]
    assert snap["token_latency_p50_s"] > 0


# ---------------------------------------------------------------------
# engine + serve integration
# ---------------------------------------------------------------------

def test_engine_ttft_breakdown_telescopes_exactly(dense):
    cfg, params = dense
    clock = FakeClock(dt=0.25)
    eng = ContinuousEngine(cfg, params,
                           PoolConfig(n_slots=2, max_len=MAX_LEN),
                           clock=clock)
    out = eng.serve(_requests(cfg, 4))
    assert all(len(v) for v in out.values())
    for state in eng.scheduler.finished.values():
        bd = state.ttft_breakdown
        assert bd is not None
        assert bd["queue_s"] >= 0
        assert bd["prefill_s"] > 0 and bd["first_decode_s"] > 0
        assert sum(bd.values()) == pytest.approx(state.ttft_s, abs=1e-12)
    # every first token landed in the TTFT histogram
    assert eng.metrics.ttft_hist.count == 4
    assert eng.metrics.token_latency_hist.count == eng.metrics.slot_steps


def test_engine_request_spans_under_tracer(dense):
    cfg, params = dense
    eng = ContinuousEngine(cfg, params,
                           PoolConfig(n_slots=2, max_len=MAX_LEN))
    tr = obs.Tracer()
    obs.install(tr)
    try:
        eng.serve(_requests(cfg, 3))
    finally:
        obs.install(None)
    names = {s.name for s in tr.spans()}
    assert {"prefill", "decode", "request", "request.queue",
            "request.prefill", "request.first_decode"} <= names
    reqs = tr.spans("request")
    assert len(reqs) == 3
    by_id = {s.span_id: s for s in tr.spans()}
    for child in tr.spans("request.queue"):
        assert by_id[child.parent_id].name == "request"
        assert child.attrs["trace"] == by_id[child.parent_id].attrs["trace"]
    for r in reqs:
        assert r.attrs["trace"] == f"req{r.attrs['request_id']}"
        assert r.attrs["finish_reason"] == "length"
        # the children telescope across the request span's TTFT
        kids = [s for s in tr.spans() if s.parent_id == r.span_id]
        assert sum(k.duration_s for k in kids) == pytest.approx(
            r.attrs["ttft_s"], abs=1e-9)
    assert tr.events("engine.submit")


def test_router_lifecycle_events_and_trace_ids(dense):
    cfg, params = dense
    pool = lambda: PoolConfig(n_slots=2, max_len=MAX_LEN)  # noqa: E731
    flaky = ContinuousEngine(cfg, params, pool())
    calls = [0]
    orig = flaky.step

    def boom():
        calls[0] += 1
        if calls[0] == 2:
            raise RuntimeError("injected")
        return orig()

    flaky.step = boom
    router = EngineRouter(
        [EngineReplica("stable", ContinuousEngine(cfg, params, pool())),
         EngineReplica("flaky", flaky)])
    tr = obs.Tracer()
    obs.install(tr)
    try:
        out = router.serve(_requests(cfg, 4))
    finally:
        obs.install(None)
    assert all(len(v) for v in out.values())
    assert len(tr.events("router.submit")) == 4
    assert tr.events("replica.quarantine")[0].attrs["replica"] == "flaky"
    assert tr.events("router.requeue")
    finishes = tr.events("request.finish")
    assert {e.attrs["trace"] for e in finishes} == \
        {f"t{tid}" for tid in out}
    assert all(e.attrs["status"] == "completed" for e in finishes)
    # the engine-side request spans carry the router's ticket trace ids
    req_traces = {s.attrs["trace"] for s in tr.spans("request")}
    assert req_traces <= {f"t{tid}" for tid in out}


def test_frontend_propagates_tracer_into_executor(dense):
    cfg, params = dense
    eng = ContinuousEngine(cfg, params,
                           PoolConfig(n_slots=2, max_len=MAX_LEN))
    router = EngineRouter([EngineReplica("r0", eng)])
    tr = obs.Tracer()

    async def main():
        with repro.use(tracer=tr):
            async with AsyncFrontend(router) as fe:
                handles = [await fe.submit(r)
                           for r in _requests(cfg, 3)]
                return [await h for h in handles]

    results = asyncio.run(main())
    assert all(r.status == "completed" for r in results)
    # spans were recorded from the executor thread, not the loop thread
    prefills = tr.spans("prefill")
    assert prefills
    assert any(s.thread != threading.get_ident() for s in prefills)
    assert len(tr.spans("request")) == 3


def test_http_shim_generate_metrics_and_400(dense):
    import urllib.error
    import urllib.request

    from repro.serve import HttpFrontend

    cfg, params = dense
    eng = ContinuousEngine(cfg, params,
                           PoolConfig(n_slots=2, max_len=MAX_LEN))
    router = EngineRouter([EngineReplica("r0", eng)])
    with HttpFrontend(router) as hf:
        body = json.dumps({"prompt": [1, 2, 3], "max_tokens": 4,
                           "stop_tokens": []}).encode()
        req = urllib.request.Request(
            hf.url + "/generate", data=body,
            headers={"Content-Type": "application/json"})
        out = json.loads(urllib.request.urlopen(req).read())
        assert out["status"] == "completed"
        assert len(out["tokens"]) == 4
        assert out["ttft_s"] > 0

        met = urllib.request.urlopen(hf.url + "/metrics")
        assert met.headers["Content-Type"].startswith("text/plain")
        text = met.read().decode()
        assert "repro_serve_ttft_seconds_bucket" in text
        assert "repro_op_dispatch_total" in text

        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(urllib.request.Request(
                hf.url + "/generate", data=b'{"prompt": []}',
                headers={"Content-Type": "application/json"}))
        assert e.value.code == 400
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(hf.url + "/nope")
        assert e.value.code == 404


def test_request_from_payload_validation():
    from repro.serve import request_from_payload
    req, tier, dl = request_from_payload(
        {"prompt": [1, 2], "max_tokens": 3, "temperature": 0.5,
         "tier": "fp32", "deadline_s": 2.5})
    assert req.prompt == [1, 2] and req.temperature == 0.5
    assert tier == "fp32" and dl == 2.5
    for bad in ({"prompt": []}, {"prompt": "hi"}, {"prompt": [1], "x": 1},
                {"prompt": [1], "max_tokens": 0},
                {"prompt": [1], "stop_tokens": "no"}):
        with pytest.raises(ValueError):
            request_from_payload(bad)
