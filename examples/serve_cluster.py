"""Serving as a service: two engine tiers behind the async front-end.

    PYTHONPATH=src python examples/serve_cluster.py

Two `ContinuousEngine` replicas — an fp32 tier and a bf16-accumulation
tier over the same weights — sit behind an `EngineRouter` with a bounded
waiting queue.  An `AsyncFrontend` runs the router in the background
while concurrent client coroutines submit requests:

  * most requests route by least queue depth across both tiers,
  * two request tier-affinity onto the bf16 replica,
  * one arrives with `deadline_s` so short it times out mid-queue,
  * one is cancelled by its client after the first streamed token,
  * a late burst overflows `max_waiting` and gets rejected.

Every handle resolves with a terminal status (completed / timeout /
cancelled / rejected), and the run ends with the merged cluster metrics
in Prometheus text exposition format.
"""
import asyncio
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from repro import configs                                     # noqa: E402
from repro.models import api                                  # noqa: E402
from repro.serve import (                                     # noqa: E402
    AsyncFrontend,
    ContinuousEngine,
    EngineReplica,
    EngineRouter,
    PoolConfig,
    Request,
)

PROMPT_LENS = (4, 11, 6, 16, 5, 9, 13, 7)
MAX_TOKENS = (3, 8, 2, 6, 9, 2, 5, 4)


def make_requests(cfg, n):
    rng = np.random.default_rng(0)
    return [
        Request(prompt=rng.integers(
                    0, cfg.vocab, PROMPT_LENS[i % len(PROMPT_LENS)]).tolist(),
                max_tokens=MAX_TOKENS[i % len(MAX_TOKENS)], stop_tokens=())
        for i in range(n)
    ]


async def client(frontend, name, request, **submit_kw):
    handle = await frontend.submit(request, **submit_kw)
    tokens = []
    async for tok in handle:
        tokens.append(tok)
        if name == "cancelled" and len(tokens) == 1:
            await handle.cancel()
    result = await handle
    placed = frontend.router.tickets[handle.request_id].replica \
        if handle.request_id is not None else None
    print(f"  {name:<10s} -> {result.status:<9s} "
          f"replica={placed.name if placed else '-':<6s} "
          f"tokens={result.tokens}")
    return result


async def main():
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pool = lambda: PoolConfig(n_slots=2, max_len=48)          # noqa: E731

    router = EngineRouter(
        [EngineReplica("fp32", ContinuousEngine(cfg, params, pool()),
                       tier="fp32"),
         EngineReplica("bf16", ContinuousEngine(cfg, params, pool(),
                                                accum_dtype="bfloat16"),
                       tier="bf16")],
        max_waiting=3, admission="reject")

    reqs = make_requests(cfg, 10)
    async with AsyncFrontend(router) as frontend:
        print("--- concurrent clients over two tiers "
              "(least-depth routing, bf16 affinity for two)")
        tasks = [client(frontend, f"client-{i}", reqs[i]) for i in range(3)]
        tasks += [client(frontend, "cancelled", reqs[3])]
        tasks += [client(frontend, f"bf16-{i}", reqs[4 + i], tier="bf16")
                  for i in range(2)]
        tasks += [client(frontend, "deadline", reqs[6], deadline_s=1e-4)]
        # late burst into an already-loaded cluster: backlog > max_waiting
        tasks += [client(frontend, f"burst-{i}", reqs[8 + i])
                  for i in range(2)]
        results = await asyncio.gather(*tasks)

    by_status = {}
    for r in results:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    print(f"statuses: {by_status}")

    metrics = router.metrics()
    agg = metrics.aggregate().snapshot()
    print(f"cluster: {agg['tokens_generated']} tokens, "
          f"mean wall-clock ttft="
          f"{(agg['mean_ttft_s'] or 0) * 1e3:.1f}ms")
    print("--- prometheus exposition (first 14 lines)")
    for line in metrics.to_prometheus().splitlines()[:14]:
        print(f"  {line}")


if __name__ == "__main__":
    asyncio.run(main())
