"""Paper workload (Sec. 4.2.1): GNMT-style 4-layer LSTM LM training.

    PYTHONPATH=src python examples/train_lstm_gnmt.py

Every GEMM in the LSTM cells is the batch-reduce building block (Alg 2);
this is the end-to-end driver form of the paper's distributed GNMT run,
scaled to CPU (the paper trains to BLEU 22.7 on WMT16; here we verify the
loss decreases on a synthetic copy task).
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.models import lstm_lm                              # noqa: E402
from repro.train import optimizer as opt                      # noqa: E402


def main():
    cfg = lstm_lm.LSTMLMCfg(vocab=128, d_model=64, n_layers=4)
    params = lstm_lm.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt.SGDMCfg(lr=0.3, momentum=0.9, grad_clip=1.0)
    state = opt.sgdm_init(params, ocfg)

    rng = np.random.default_rng(0)

    def make_batch():
        # learnable structure: next token = current token + 1 (mod vocab)
        start = rng.integers(0, cfg.vocab, size=(16, 1))
        seq = (start + np.arange(33)) % cfg.vocab
        return {"tokens": jnp.asarray(seq[:, :-1], jnp.int32),
                "labels": jnp.asarray(seq[:, 1:], jnp.int32)}

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(
            lstm_lm.loss_fn, has_aux=True)(params, batch, cfg)
        params, state, _ = opt.sgdm_update(params, grads, state, ocfg)
        return params, state, loss

    losses = []
    for i in range(60):
        params, state, loss = step(params, state, make_batch())
        losses.append(float(loss))
        if i % 10 == 0:
            print(f"step {i:3d} loss {losses[-1]:.4f}")
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'LEARNED' if losses[-1] < losses[0] * 0.7 else 'no progress'})")
    assert losses[-1] < losses[0] * 0.7


if __name__ == "__main__":
    main()
