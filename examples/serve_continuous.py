"""Continuous batching: mixed-length requests joining mid-stream, served
at two tiers.

    PYTHONPATH=src python examples/serve_continuous.py

Eight requests with ragged prompt/output lengths go through a 3-slot pool:
the first wave prefills immediately, the rest queue and join as slots free
up (watch queue depth / occupancy in the step log).  The same workload is
then served at a second tier — same weights, different execution context
(xla backend, bf16 accumulation) — to show per-tier `repro.use` scoping:
each engine's jit entry points resolve their own backend and tuned blocks.
Request 0 registers a streaming `on_token` callback, so its tokens print
the moment the step that generated them finishes.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from repro import configs                                     # noqa: E402
from repro.models import api                                  # noqa: E402
from repro.serve import (                                     # noqa: E402
    ContinuousEngine,
    PoolConfig,
    Request,
)

PROMPT_LENS = (4, 11, 6, 16, 5, 9, 13, 7)
MAX_TOKENS = (3, 8, 2, 6, 9, 2, 5, 4)


def make_requests(cfg):
    rng = np.random.default_rng(0)
    return [
        Request(prompt=rng.integers(0, cfg.vocab, pl).tolist(),
                max_tokens=mt, stop_tokens=())
        for pl, mt in zip(PROMPT_LENS, MAX_TOKENS)
    ]


def serve_tier(name, cfg, params, **tier):
    eng = ContinuousEngine(
        cfg, params,
        PoolConfig(n_slots=3, max_len=48, prefill_bucket=8), **tier)

    def stream(rid, tok, finished):
        print(f"    stream r{rid}: token={tok}"
              + (" <eos-of-stream>" if finished else ""))

    reqs = make_requests(cfg)
    ids = [eng.submit(reqs[0], on_token=stream)]
    ids += [eng.submit(r) for r in reqs[1:]]
    print(f"--- tier {name}: {tier or 'hardware defaults'}")
    while eng.scheduler.has_work():
        events = eng.step()
        done = [rid for rid, _, fin in events if fin]
        print(f"  step {eng.metrics.steps:2d}: "
              f"running={eng.scheduler.n_running} "
              f"queued={eng.scheduler.queue_depth} "
              f"occupancy={eng.pool.occupancy:.2f}"
              + (f" finished={done}" if done else ""))
    out = {rid: eng.scheduler.finished[rid].generated for rid in ids}
    m = eng.metrics.snapshot()
    print(f"  {m['tokens_generated']} tokens, "
          f"{m['tokens_per_s']:.1f} tok/s, "
          f"occupancy={m['occupancy']:.2f}, "
          f"mean ttft={m['mean_ttft_steps']:.1f} steps")
    return out


def main():
    cfg = configs.get("smollm-135m").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    out_a = serve_tier("A (default)", cfg, params)
    out_b = serve_tier("B (xla, bf16 accum)", cfg, params,
                       backend="xla", accum_dtype="bfloat16")

    same = sum(out_a[r] == out_b[r] for r in out_a)
    print(f"tiers agree on {same}/{len(out_a)} requests "
          f"(bf16 accumulation may legitimately flip near-ties)")
    for rid in sorted(out_a):
        print(f"  request {rid}: {out_a[rid]}")


if __name__ == "__main__":
    main()
