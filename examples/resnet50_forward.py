"""Paper workload (Sec. 4.2.2): ResNet-50 on the direct-conv primitive.

    PYTHONPATH=src python examples/resnet50_forward.py

Runs a width-reduced ResNet-50 forward + one training step; every conv is
the batch-reduce direct convolution (Alg 4).
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.models import resnet                               # noqa: E402


def main():
    cfg = resnet.ResNetCfg(n_classes=10, width=8, stage_blocks=(1, 1, 1, 1))
    params = resnet.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64, 3))
    logits = resnet.forward(params, x, cfg)
    print("logits:", logits.shape, "finite:",
          bool(np.isfinite(np.asarray(logits)).all()))

    labels = jnp.asarray([1, 3])

    def loss_fn(p):
        lg = resnet.forward(p, x, cfg)
        return -jax.nn.log_softmax(lg)[jnp.arange(2), labels].mean()

    loss, grads = jax.value_and_grad(loss_fn)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g ** 2) for g in jax.tree.leaves(grads)))
    print(f"loss {float(loss):.4f}  grad-norm {float(gnorm):.4f}")
    assert np.isfinite(float(loss)) and np.isfinite(float(gnorm))


if __name__ == "__main__":
    main()
