"""Quickstart: train a reduced smollm-135m for a few steps on CPU.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API path a user takes: pick an assigned arch
config, reduce it, build the mesh/shardings, stream synthetic data, train
with AdamW + checkpointing, then resume.
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

import repro                                                 # noqa: E402
from repro import configs                                    # noqa: E402
from repro.configs.shapes import ShapeCfg                    # noqa: E402
from repro.launch.mesh import make_mesh                      # noqa: E402
from repro.launch.train import run                           # noqa: E402


def main():
    cfg = configs.get("smollm-135m").reduced()
    shape = ShapeCfg("quickstart", "train", seq_len=64, global_batch=8)
    mesh = make_mesh((1, 1), ("data", "model"))
    # The execution context scopes backend selection for everything below
    # (on CPU this resolves to the XLA reference path anyway; on TPU it
    # forces it — handy for A/B'ing against the Pallas kernels).
    with repro.use(backend="xla"), tempfile.TemporaryDirectory() as ckpt_dir:
        _, losses = run(cfg, shape, mesh=mesh, steps=10, ckpt_dir=ckpt_dir,
                        save_every=5, log_every=2)
        print(f"\ntrained 10 steps: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
        # resume from the step-5 checkpoint and continue to 14
        _, losses2 = run(cfg, shape, mesh=mesh, steps=14, ckpt_dir=ckpt_dir,
                         log_every=2)
        print(f"resumed and continued: final loss {losses2[-1]:.3f}")


if __name__ == "__main__":
    main()
