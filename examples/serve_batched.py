"""Batched serving: prefill a prompt batch, then decode tokens.

    PYTHONPATH=src python examples/serve_batched.py

Exercises the production serving path (decode_32k/long_500k shapes use the
same engine): KV/ring/recurrent caches per architecture family.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402

from repro import configs                                     # noqa: E402
from repro.models import api                                  # noqa: E402
from repro.serve.engine import Engine, ServeConfig            # noqa: E402


def main():
    for arch in ("smollm-135m", "xlstm-1.3b", "recurrentgemma-9b"):
        cfg = configs.get(arch).reduced()
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, ServeConfig(max_len=64, temperature=0.0))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                                     cfg.vocab, jnp.int32)
        out = eng.generate({"tokens": prompts}, n_tokens=8)
        print(f"{arch:20s} family={cfg.family:6s} "
              f"generated {out.shape} -> {out[0].tolist()}")


if __name__ == "__main__":
    main()
